"""Serving profiles: the latency model behind every Captain.

The paper's captains serve real latency-sensitive models (object
detection, face recognition — §3.3.2/§5); this module is the layer that
connects those models to the control plane.  A :class:`ServingProfile`
owns a captain's per-request latency model behind ONE API with two
backends (the mamba-jax kernel-interface idiom — SNIPPETS §1–2: one
entry point, enum-dispatched modes):

* ``SURROGATE`` — analytic: a calibrated per-family frame time plus an
  affine batch-occupancy step model whose fixed/variable split comes
  from a roofline cost estimate (``telemetry/hlo_cost`` over the
  compiled forward, or the parameter-count estimate when nothing is
  compiled).  Pure arithmetic — cheap enough for tier-1 and the
  100k-user fused tick.
* ``REAL`` — actual jitted compute: a :class:`~repro.serving.engine.
  ServeEngine` decode step with ``SlotScheduler`` continuous batching
  for causal (LLM-decode) families, a jitted batched frame forward for
  the vision families.  ``bench_heterogeneity`` calibrates the
  surrogate against it and records the constants this module consumes.

The tick paths consume only :meth:`ServingProfile.request_ms`, which is
**linear in** ``proc_scale`` with a unit time fixed at profile
construction — the fused device tick bakes ``request_ms(1.0)`` into its
static per-node array and multiplies by the workload scale on device,
so host and device latencies stay identical by construction.  Real-mode
measurements never feed the tick; they feed calibration and the
heartbeat ``decode_ms`` telemetry field.
"""
from __future__ import annotations

import enum
import json
import pathlib
from typing import Dict, Optional

# reference per-frame service time (ms) of the paper's D6 anchor node
# (speed factor 1.0, Table 5) — the scale all node speed factors are
# expressed against
REF_FRAME_MS = 30.0

# per-family frame/step time (ms) at speed factor 1.0, used when no
# calibration artifact has been recorded yet (satellite: bench's derive
# hook writes measured constants that override these)
FALLBACK_MS = {
    "armada-detector": 30.0,
    "armada-facerec": 12.0,
    "llm-decode": 45.0,
}

# model family -> backing architecture in the repro.configs registry
FAMILY_ARCH = {
    "armada-detector": "armada-detector",
    "armada-facerec": "armada-facerec",
    "llm-decode": "qwen3-1.7b",
}

FAMILIES = tuple(FAMILY_ARCH)


class ProfileMode(enum.Enum):
    SURROGATE = "surrogate"
    REAL = "real"


# --------------------------------------------------------------- calibration

def calibration_path() -> pathlib.Path:
    """Default location of the bench runner's merged results."""
    return pathlib.Path(__file__).resolve().parents[3] \
        / "artifacts" / "bench" / "results.json"


_CAL_CACHE: Dict[str, object] = {"path": None, "table": None}


def load_calibration(path=None) -> Dict[str, Dict[str, float]]:
    """Per-family calibration constants recorded by bench_heterogeneity's
    ``derive`` hook (rows named ``table5/calibration/<family>``, derived
    fields ``k=v`` semicolon-joined).  Missing/unreadable artifacts give
    an empty table — profiles fall back to :data:`FALLBACK_MS`."""
    p = pathlib.Path(path) if path is not None else calibration_path()
    if _CAL_CACHE["path"] == p and _CAL_CACHE["table"] is not None:
        return _CAL_CACHE["table"]          # type: ignore[return-value]
    table: Dict[str, Dict[str, float]] = {}
    try:
        rows = json.loads(p.read_text())
    except (OSError, ValueError):
        rows = []
    for row in rows if isinstance(rows, list) else []:
        name = str(row.get("name", ""))
        if not name.startswith("table5/calibration/"):
            continue
        kv: Dict[str, float] = {}
        for part in str(row.get("derived", "")).split(";"):
            key, _, val = part.partition("=")
            try:
                kv[key.strip()] = float(val)
            except ValueError:
                pass
        if kv.get("ms_per_frame", 0.0) > 0.0:
            table[name.rsplit("/", 1)[1]] = kv
    _CAL_CACHE.update(path=p, table=table)
    return table


def reset_calibration_cache() -> None:
    _CAL_CACHE.update(path=None, table=None)


# ------------------------------------------------------------ analytic cost

_FIXED_FRAC_CACHE: Dict[str, float] = {}


def analytic_cost(cfg, tokens: Optional[int] = None):
    """Roofline :class:`~repro.telemetry.hlo_cost.Cost` for one batch-1
    forward straight from the model config — no compile.  FLOPs follow
    the 2·N·D rule; bytes are one full sweep over the (active) weights,
    the term that dominates small-batch serving."""
    from repro.telemetry.hlo_cost import Cost
    n = cfg.param_count(active_only=True)
    if tokens is None:
        tokens = (cfg.num_patches + 8) if cfg.num_patches else 1
    cost = Cost()
    cost.flops = 2.0 * n * tokens
    cost.add_bytes("parameter-sweep", 4.0 * n)
    return cost


def compiled_cost(compiled):
    """Cost via the while-trip-count-aware HLO walker, for profiles that
    have a compiled real backend."""
    from repro.telemetry.hlo_cost import analyze_compiled
    return analyze_compiled(compiled)


def fixed_fraction(model_id: str, cost=None) -> float:
    """Roofline estimate of the batch-independent share of one serving
    step: the weight-sweep (bytes) time is paid once per step regardless
    of how many batch slots are occupied, while compute scales with the
    occupied slots.  Feeds the surrogate's affine step model
    ``t(b) = unit·(fixed + (1-fixed)·b)``."""
    if cost is None:
        if model_id in _FIXED_FRAC_CACHE:
            return _FIXED_FRAC_CACHE[model_id]
        arch = FAMILY_ARCH.get(model_id)
        if arch is None:
            return 0.0
        from repro.configs import get_config
        cost = analytic_cost(get_config(arch))
    from repro.config import V5E
    t_flops = cost.flops / V5E.peak_flops
    t_bytes = cost.bytes / V5E.hbm_bw
    frac = min(t_bytes / max(t_bytes + t_flops, 1e-30), 0.95)
    if model_id in FAMILY_ARCH:
        _FIXED_FRAC_CACHE[model_id] = frac
    return frac


# ----------------------------------------------------------------- profile

class ServingProfile:
    """Per-captain serving latency model (dual-mode, one API).

    ``unit_ms`` — the effective per-request service time at batch 1 —
    is fixed at construction: calibrated per-family frame time (artifact
    or fallback) times the node's ``speed_factor``.  ``request_ms`` is
    linear in ``proc_scale`` so the device tick's static per-node scalar
    reproduces it exactly.
    """

    def __init__(self, model_id: str = "armada-detector",
                 mode=ProfileMode.SURROGATE, *,
                 speed_factor: float = 1.0,
                 unit_ms: Optional[float] = None,
                 calibration: Optional[Dict] = None):
        if model_id not in FAMILY_ARCH and unit_ms is None:
            raise ValueError(f"unknown model family {model_id!r} "
                             f"(known: {sorted(FAMILY_ARCH)}) — pass "
                             "unit_ms= for ad-hoc profiles")
        self.model_id = model_id
        self.mode = ProfileMode(mode)
        self.speed_factor = float(speed_factor)
        cal = calibration if calibration is not None else load_calibration()
        fam = cal.get(model_id, {})
        base = unit_ms if unit_ms is not None else \
            fam.get("ms_per_frame", FALLBACK_MS.get(model_id, REF_FRAME_MS))
        self.unit_ms = float(base) * self.speed_factor
        frac = fam.get("fixed_frac")
        if frac is None:
            frac = fixed_fraction(model_id)
        self.fixed_frac = min(max(float(frac), 0.0), 0.95)
        self._real = None               # _RealDecode | _RealFrame

    # ------------------------------------------------------------- tick API

    def request_ms(self, proc_scale: float = 1.0) -> float:
        """Effective per-request service time (ms).  Linear in
        ``proc_scale`` by contract — see the module docstring."""
        return self.unit_ms * proc_scale

    def estimate_step_ms(self, n_active: int = 1) -> float:
        """Surrogate serving-step estimate with ``n_active`` occupied
        batch slots: affine in occupancy, with the batch-independent
        share from the roofline split (``fixed_fraction``)."""
        n = max(int(n_active), 1)
        return self.unit_ms * (self.fixed_frac + (1.0 - self.fixed_frac) * n)

    def step_ms(self, n_active: int = 1) -> float:
        """One serving step at the given occupancy — measured wall time
        in REAL mode, the analytic estimate in SURROGATE mode."""
        if self.mode is ProfileMode.REAL and self._real is not None:
            return self._real.step(n_active)
        return self.estimate_step_ms(n_active)

    def measured_ms(self) -> Optional[float]:
        """Measured decode/frame EMA from the real backend (``None`` in
        surrogate mode) — surfaced through captain heartbeats so the
        surrogate can be sanity-checked against serving reality."""
        return self._real.ema() if self._real is not None else None

    # ------------------------------------------------------------ real mode

    def attach_real(self, *, reduce_layers: Optional[int] = None,
                    max_batch: int = 4, max_seq: int = 64,
                    seed: int = 0) -> "ServingProfile":
        """Switch to REAL mode: build the jitted backend (a ServeEngine
        for causal families, a batched frame forward for vision
        families).  ``reduce_layers`` swaps in the tiny same-family
        config for CPU-feasible tests."""
        from repro.config import reduced
        from repro.configs import get_config
        cfg = get_config(FAMILY_ARCH.get(self.model_id, self.model_id))
        if reduce_layers is not None:
            cfg = reduced(cfg, num_layers=reduce_layers)
        if cfg.family == "vlm" and not cfg.attention.causal:
            self._real = _RealFrame(cfg, max_batch=max_batch, seed=seed)
        else:
            self._real = _RealDecode(cfg, max_batch=max_batch,
                                     max_seq=max_seq, seed=seed)
        self.mode = ProfileMode.REAL
        return self

    def real_cost(self):
        """HLO-walker Cost of the real backend's step (None until
        the backend has compiled)."""
        return self._real.cost() if self._real is not None else None


def attach_profiles(captains, *, families=FAMILIES,
                    ref_ms: float = REF_FRAME_MS,
                    calibration: Optional[Dict] = None) -> None:
    """Heterogeneous-fleet helper: assign the model families round-robin
    over the captains (deterministic in captain order), preserving each
    node's relative speed (``spec.proc_ms / ref_ms``) so existing
    topologies keep their latency ordering."""
    for i, cap in enumerate(captains):
        fam = families[i % len(families)]
        cap.profile = ServingProfile(
            fam, speed_factor=cap.spec.proc_ms / ref_ms,
            calibration=calibration)


# ------------------------------------------------------------ real backends

class _EmaMixin:
    """decode/frame-time EMA with the ServeEngine smoothing constants."""

    _ema: Optional[float] = None

    def _fold(self, dt_ms: float) -> float:
        self._ema = dt_ms if self._ema is None \
            else 0.3 * dt_ms + 0.7 * self._ema
        return dt_ms

    def ema(self) -> Optional[float]:
        return self._ema


class _RealFrame(_EmaMixin):
    """Vision families (detector / facerec): one jitted ``hidden_states``
    forward over a batch of ``n`` frames per step.  Non-causal frame
    models have no decode loop — a serving step IS the batched forward."""

    def __init__(self, cfg, *, max_batch: int = 4, seed: int = 0):
        import jax
        from repro.models.api import build_model, make_batch
        self.cfg = cfg
        self.max_batch = max_batch
        model = build_model(cfg)
        self.params = model.init(jax.random.PRNGKey(seed))
        self._apply = jax.jit(lambda p, b: model.hidden_states(p, b)[0])
        batch1 = make_batch(cfg, "train", 1, cfg.num_patches + 8,
                            seed=seed)
        self._batches = {1: batch1}
        self._compiled = None
        self._warm: set = set()

    def _batch(self, n: int):
        import jax
        b = self._batches.get(n)
        if b is None:
            b = jax.tree.map(
                lambda x: x.repeat(n, axis=0) if hasattr(x, "ndim")
                and x.ndim and x.shape[0] == 1 else x, self._batches[1])
            self._batches[n] = b
        return b

    def step(self, n_active: int = 1) -> float:
        import time

        import jax
        n = min(max(int(n_active), 1), self.max_batch)
        batch = self._batch(n)
        if n not in self._warm:
            jax.block_until_ready(self._apply(self.params, batch))
            self._warm.add(n)
        t0 = time.perf_counter()
        jax.block_until_ready(self._apply(self.params, batch))
        return self._fold((time.perf_counter() - t0) * 1e3)

    def cost(self):
        if self._compiled is None:
            self._compiled = compiled_cost(
                self._apply.lower(self.params, self._batches[1]).compile())
        return self._compiled


class _RealDecode(_EmaMixin):
    """Causal (LLM-decode) family: a real ServeEngine with SlotScheduler
    continuous batching — one step decodes every occupied slot."""

    def __init__(self, cfg, *, max_batch: int = 4, max_seq: int = 64,
                 seed: int = 0):
        import jax
        from repro.models.api import build_model
        from repro.serving.engine import ServeEngine
        self.cfg = cfg
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        # eos_id outside the tiny vocab: requests run to max_new_tokens,
        # keeping slots occupied for as many steps as the caller wants
        self.engine = ServeEngine(cfg, params, max_batch=max_batch,
                                  max_seq=max_seq, eos_id=-1)
        self._n_submitted = 0

    def step(self, n_active: int = 1) -> float:
        # occupancy is monotone: profiling requests never finish (eos -1,
        # unbounded max_new_tokens), so measure ascending batch sizes
        eng = self.engine
        n = min(max(int(n_active), 1), eng.max_batch)
        sched = eng.scheduler
        for _ in range(n - len(sched.active()) - len(sched.queue)):
            self._n_submitted += 1
            eng.submit(f"prof-{self._n_submitted}",
                       [1 + self._n_submitted % 17],
                       max_new_tokens=1 << 30)
        eng.step()
        return self._fold(eng.last_decode_ms)

    def ema(self) -> Optional[float]:
        return self.engine.decode_ms_ema

    def cost(self):
        return None
