"""Cargo-backed sessions: extract/attach one slot's generation state.

Armada forbids hard client state on (volatile) serving nodes — §2.4.  A
session blob holds the request's prompt, generated tokens, and its slice of
the KV/recurrent cache; it can be written to the Cargo layer and re-attached
on ANY other replica of the same architecture, making mid-generation
failover lossless.  For SSM/hybrid archs the blob carries O(1) recurrent
state instead of KV pages (DESIGN.md §4).
"""
from __future__ import annotations

import pickle
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.batching import GenRequest


def export_slot(engine, req: GenRequest) -> bytes:
    """Serialize one slot's cache slice + request progress."""
    slot = req.slot
    assert slot is not None

    cache = {
        key: np.asarray(jax.lax.dynamic_slice_in_dim(
            c, slot, 1, axis=engine.cache_batch_axis[key]))
        for key, c in engine.cache.items()
    }
    blob = {
        "cache": cache,
        "request_id": req.request_id,
        "prompt": req.prompt,
        "generated": req.generated,
        "max_new_tokens": req.max_new_tokens,
        "arch": engine.cfg.name,
    }
    return pickle.dumps(blob)


def import_session(engine, data: bytes) -> GenRequest:
    """Attach a session blob to another engine replica.

    With a free slot the saved cache slice is spliced in immediately.
    With every slot busy the request **queues** (scheduler FIFO order,
    behind any waiting fresh requests) carrying its cache slice in
    ``resume_cache``; the engine's admit path re-splices it on the next
    free slot instead of prefilling — occupied slots are never touched,
    and no session is dropped under load."""
    blob = pickle.loads(data)
    assert blob["arch"] == engine.cfg.name, "cross-arch session"
    req = GenRequest(blob["request_id"], blob["prompt"],
                     blob["max_new_tokens"],
                     generated=list(blob["generated"]))
    free = engine.scheduler.free_slots()
    if not free:
        req.resume_cache = blob["cache"]
        engine.scheduler.submit(req)
        return req
    slot = free[0]
    sub = jax.tree.map(jnp.asarray, blob["cache"])
    engine.cache = engine._splice(engine.cache, sub, slot)
    req.slot = slot
    engine.scheduler.slots[slot] = req
    return req
