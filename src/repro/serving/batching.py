"""Continuous-batching scheduler: slot assignment over a fixed decode batch.

Invariants (property-tested in tests/test_serving.py):
* a slot serves at most one request at a time
* every admitted request eventually maps to exactly one slot
* per-slot cache length == prompt length + tokens generated so far
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class GenRequest:
    request_id: str
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    session_id: Optional[str] = None        # Cargo-backed session (failover)
    # imported session queued while every slot was busy: the saved cache
    # slice to re-splice on admission (instead of a fresh prefill, which
    # would lose the generated-token cache state)
    resume_cache: Optional[Dict] = None


class SlotScheduler:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: List[GenRequest] = []
        self.slots: List[Optional[GenRequest]] = [None] * max_batch
        self.finished: List[GenRequest] = []

    def submit(self, req: GenRequest):
        self.queue.append(req)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[tuple]:
        """Assign queued requests to free slots; returns [(slot, request)]."""
        placed = []
        for slot in self.free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            req.slot = slot
            self.slots[slot] = req
            placed.append((slot, req))
        return placed

    def active(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None]

    def complete(self, req: GenRequest):
        req.done = True
        self.finished.append(req)
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None

    def drain(self) -> bool:
        return not self.queue and not any(self.slots)
