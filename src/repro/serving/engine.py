"""ServeEngine: a real jitted serving replica (prefill + decode + batching).

One engine == one Armada service replica.  Decode runs over a fixed
``max_batch``-slot cache; prefilled sequences are spliced into free slots
(continuous batching).  No hard client state lives here beyond the cache —
sessions can be exported/imported (repro.serving.session) so an Armada
client can fail over to another replica mid-generation, satisfying the
paper's zero-downtime requirement.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.models.api import build_model
from repro.serving.batching import GenRequest, SlotScheduler


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, serve: ServeConfig = None,
                 max_batch: int = 4, max_seq: int = 256, eos_id: int = 1,
                 greedy: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.scheduler = SlotScheduler(max_batch)
        self.cache = self.model.init_cache(max_batch, max_seq, "float32")
        self.steps = 0
        # measured decode wall time: last step + EMA.  Surfaced through
        # ServingProfile.measured_ms() -> Captain.heartbeat()["decode_ms"]
        # so real-mode captains report serving reality, and the surrogate
        # can be sanity-checked against it (bench_heterogeneity).
        self.decode_ms_ema: Optional[float] = None
        self.last_decode_ms: float = 0.0

        model = self.model
        # authoritative batch-axis index per cache leaf (size-based guessing
        # breaks when num_layers == max_batch)
        from repro.models.api import cache_axes
        axes = cache_axes(model, self.cache)
        batch_ax = {k: ax.index("batch") for k, ax in axes.items()}
        self.cache_batch_axis = batch_ax

        @jax.jit
        def _prefill(params, tokens, lengths):
            return model.prefill(params, {"tokens": tokens,
                                          "lengths": lengths},
                                 max_seq=max_seq)

        @jax.jit
        def _decode(params, cache, tokens):
            return model.decode_step(params, cache, {"tokens": tokens})

        @jax.jit
        def _splice(cache, sub, slot):
            out = {}
            for key, c in cache.items():
                s = sub[key]
                idx = [0] * c.ndim
                idx[batch_ax[key]] = slot
                out[key] = jax.lax.dynamic_update_slice(
                    c, s.astype(c.dtype), tuple(idx))
            return out

        self._prefill = _prefill
        self._decode = _decode
        self._splice = _splice

    # ----------------------------------------------------------- requests

    def submit(self, request_id: str, prompt: List[int],
               max_new_tokens: int = 16):
        self.scheduler.submit(GenRequest(request_id, list(prompt),
                                         max_new_tokens))

    def _admit(self):
        for slot, req in self.scheduler.admit():
            if req.resume_cache is not None:
                # imported session that queued while every slot was busy:
                # re-splice its saved cache slice — a prefill would rebuild
                # the cache from the prompt alone and corrupt the
                # mid-generation state
                sub = jax.tree.map(jnp.asarray, req.resume_cache)
                self.cache = self._splice(self.cache, sub, slot)
                req.resume_cache = None
                continue
            toks = np.zeros((1, self.max_seq // 2), np.int32)
            L = min(len(req.prompt), toks.shape[1])
            toks[0, :L] = req.prompt[:L]
            logits, sub = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray([L], jnp.int32))
            self.cache = self._splice(self.cache, sub, slot)
            first = int(jnp.argmax(logits[0]))
            req.generated.append(first)

    # --------------------------------------------------------------- step

    def step(self) -> Dict[str, List[int]]:
        """Admit + one decode step for all active slots. Returns newly
        finished request ids -> full generations."""
        self._admit()
        active = self.scheduler.active()
        if not active:
            return {}
        toks = np.zeros((self.max_batch, 1), np.int32)
        for r in active:
            toks[r.slot, 0] = r.generated[-1] if r.generated else 0
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        self.last_decode_ms = dt
        self.decode_ms_ema = dt if self.decode_ms_ema is None else \
            0.3 * dt + 0.7 * self.decode_ms_ema
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done = {}
        for r in list(active):
            tok = int(nxt[r.slot])
            r.generated.append(tok)
            if tok == self.eos_id or len(r.generated) >= r.max_new_tokens:
                done[r.request_id] = list(r.generated)
                self.scheduler.complete(r)
        return done

    def run_until_drained(self, max_steps: int = 10_000):
        out = {}
        for _ in range(max_steps):
            out.update(self.step())
            if self.scheduler.drain():
                break
        return out

    # ------------------------------------------------------------ sessions

    def export_session(self, request_id: str):
        from repro.serving.session import export_slot
        for r in self.scheduler.active():
            if r.request_id == request_id:
                return export_slot(self, r)
        raise KeyError(request_id)
