"""Serving substrate: jitted engines with continuous batching + sessions."""
from repro.serving.engine import ServeEngine  # noqa: F401
