"""Application Manager (paper §3.2): service lifecycle, the candidate-list
half of 2-step selection (Algorithm 1), and demand-driven auto-scaling.

Selection runs through the batched ``SelectionEngine``
(``repro.core.selection``): ``candidate_list`` keeps the single-user API,
``candidate_lists`` scores a whole user batch against the replica set in
one vectorized pass (exposed as ``Beacon.query_service_batch``).

Auto-scaling: 3 replicas at deploy time (fault-tolerance floor), then more
wherever real users concentrate — the AM groups active users by reduced-
precision geohash (batch Morton encoding, one pass over all users) and
asks Spinner for capacity in overloaded regions.  One *global* autoscale
tick batches the capacity probe across every deployed service (a single
Morton pass over all users of all services) and plans multi-replica
spawns per overloaded region in one pass, instead of one task per tick
per region per service.

User tracking accepts both scalar ``Client`` objects and vectorized
``ClientPool``s: anything exposing ``active_locs() -> (k, 2) ndarray``
contributes its whole population to the demand grouping.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import geohash
from repro.core.cluster import Topology
from repro.core.selection import SelectionEngine
from repro.core.sim import Simulator
from repro.core.spinner import Image, Spinner

REGION_PRECISION = 3            # coarse geohash cells for autoscale grouping
MAX_SPAWN_PER_REGION = 3        # multi-replica planning cap per tick


@dataclass
class ServiceSpec:
    service_id: str
    image: Image
    workload_scale: float = 1.0            # × node per-frame reference time
    locations: List[Tuple[float, float]] = field(default_factory=list)
    need_storage: bool = False
    storage_capacity_mb: float = 100.0
    consistency: str = "eventual"          # "strong" | "eventual"
    data_source: str = "Cloud"
    min_replicas: int = 3


@dataclass
class Task:
    task_id: str
    service_id: str
    captain: Optional[object] = None
    status: str = "pending"
    ready_at: Optional[float] = None


class ApplicationManager:
    def __init__(self, sim: Simulator, topo: Topology, spinner: Spinner,
                 cargo_manager=None, *, top_n: int = 3,
                 scale_check_s: float = 2.0,
                 overload_ratio: float = 1.5,
                 shard_precision: Optional[int] = None):
        self.sim = sim
        self.topo = topo
        self.spinner = spinner
        self.cargo_manager = cargo_manager
        self.top_n = top_n
        self.scale_check_s = scale_check_s
        self.overload_ratio = overload_ratio
        self.services: Dict[str, ServiceSpec] = {}
        self.tasks: Dict[str, List[Task]] = {}
        self.users: Dict[str, List[object]] = {}
        self._ids = itertools.count()
        self.autoscale_enabled = True
        self.scale_events: List[dict] = []
        # shard_precision partitions selection state by coarse geohash
        # region (paper §3.1's per-region Beacon replicas); queries and
        # invalidations are routed per shard inside the engine
        self.engine = SelectionEngine(top_n=top_n,
                                      shard_precision=shard_precision)
        self._autoscale_scheduled = False

    # ----------------------------------------------------------- deployment

    def deploy_service(self, spec: ServiceSpec, selection: str = "armada"):
        self.services[spec.service_id] = spec
        self.tasks[spec.service_id] = []
        self.users[spec.service_id] = []
        locs = spec.locations or [next(iter(
            self.spinner.captains.values())).spec.loc]
        for i in range(spec.min_replicas):
            self._spawn_task(spec, locs[i % len(locs)], selection)
        if spec.need_storage and self.cargo_manager is not None:
            self.cargo_manager.store_register(spec)
        self._schedule_autoscale(spec.service_id)

    def _spawn_task(self, spec: ServiceSpec, location,
                    selection: str = "armada") -> Optional[Task]:
        task = Task(f"{spec.service_id}/t{next(self._ids)}", spec.service_id)
        # Beacon-scoped scheduling: a partitioned / dead fault domain's
        # captains are hidden from selection — keep autoscale from landing
        # replicas on nodes this Beacon group cannot reach.
        hidden = self.engine.hidden_nodes
        pf = (lambda c: c.node_id not in hidden) if hidden else None
        dt = self.spinner.deploy_task(task, spec.image, location,
                                      selection=selection,
                                      on_ready=self._task_ready,
                                      policy_filter=pf)
        if dt is None:
            return None
        self.tasks[spec.service_id].append(task)
        self.engine.invalidate(spec.service_id)
        return task

    def register_task(self, task: Task):
        """Out-of-band task insertion (cloud baseline replicas, benchmark
        fixtures): append to the service's task list AND route through
        engine invalidation, so device-resident ``packed_static`` caches
        rebuild for the affected region instead of relying on the lazy
        fingerprint check alone."""
        self.tasks.setdefault(task.service_id, []).append(task)
        self.engine.invalidate(task.service_id)

    def _task_ready(self, task: Task):
        self.sim.log("task_ready", task=task.task_id,
                     node=task.captain.node_id)
        # storage layer follows compute expansion (paper §3.4 auto-scaling)
        spec = self.services[task.service_id]
        if spec.need_storage and self.cargo_manager is not None:
            self.cargo_manager.on_new_task(spec, task)

    # ----------------------------------------------- service discovery (Alg 1)

    def candidate_list(self, service_id: str, user_loc, user_net: str,
                       top_n: Optional[int] = None) -> List[Task]:
        """Step 1 of 2-step selection: score nearby running replicas."""
        return self.engine.candidate_list(
            service_id, self.tasks.get(service_id, ()), user_loc, user_net,
            top_n=top_n)

    def candidate_lists(self, service_id: str, user_locs, user_nets,
                        top_n: Optional[int] = None) -> List[List[Task]]:
        """Batched Algorithm 1: one vectorized U×N scoring pass, per-user
        top-k.  ``user_nets`` may be a single net-type string."""
        return self.engine.candidate_lists(
            service_id, self.tasks.get(service_id, ()), user_locs,
            user_nets, top_n=top_n)

    def candidate_indices(self, service_id: str, user_locs, user_nets,
                          top_n: Optional[int] = None):
        """Index-space batched Algorithm 1: ``(U, k)`` int32 positions into
        ``self.tasks[service_id]``, padded with -1 (the ClientPool path —
        no Task-list materialization)."""
        return self.engine.candidate_indices(
            service_id, self.tasks.get(service_id, ()), user_locs,
            user_nets, top_n=top_n)

    # -------------------------------------------------------------- users

    def user_join(self, service_id: str, client):
        self.users[service_id].append(client)

    def user_leave(self, service_id: str, client):
        if client in self.users.get(service_id, ()):
            self.users[service_id].remove(client)

    # ---------------------------------------------------------- auto-scaling

    def _schedule_autoscale(self, service_id: Optional[str] = None):
        """One global tick covers every service (``service_id`` kept for
        API compatibility; the first deployment arms the loop)."""
        if self._autoscale_scheduled:
            return
        self._autoscale_scheduled = True
        self.sim.after(self.scale_check_s * 1000.0, self._autoscale_tick)

    def _autoscale_tick(self):
        self._autoscale_scheduled = False
        if not self.services:
            return
        if self.autoscale_enabled:
            self._autoscale_all()
        self._schedule_autoscale()

    def _capacity(self, tasks: List[Task]) -> int:
        seen, cap = set(), 0
        for t in tasks:
            if t.captain and t.captain.alive and t.status == "running" \
                    and t.captain.node_id not in seen:
                seen.add(t.captain.node_id)
                cap += t.captain.spec.slots
            elif t.status == "deploying":
                cap += 1                      # in-flight capacity
        return cap

    def _service_user_locs(self, service_id: str) -> np.ndarray:
        """(k, 2) locations of every active user of a service — scalar
        clients contribute one row, ClientPools their whole population."""
        parts = []
        for c in self.users.get(service_id, ()):
            if hasattr(c, "active_locs"):
                locs = c.active_locs()
                if len(locs):
                    parts.append(np.asarray(locs, np.float64))
            else:
                parts.append(np.asarray([c.loc], np.float64))
        if not parts:
            return np.empty((0, 2))
        return np.concatenate(parts, axis=0)

    def _autoscale_all(self):
        """Demand-driven scaling for ALL services in one batched pass.

        The capacity probe is batched across services: user locations of
        every service are Morton-encoded in one ``encode_batch`` call
        (likewise for placed tasks), then each overloaded (service,
        region) cell gets a multi-replica spawn plan — enough capacity to
        clear the overload ratio, capped at ``MAX_SPAWN_PER_REGION`` per
        tick so demand spikes can't stampede the scheduler.
        """
        sids, u_parts, t_parts, placed_by_sid = [], [], [], {}
        for sid in self.services:
            locs = self._service_user_locs(sid)
            if not len(locs):
                continue
            placed = [t for t in self.tasks[sid]
                      if t.captain is not None
                      and t.status in ("running", "deploying")]
            sids.append(sid)
            u_parts.append(locs)
            placed_by_sid[sid] = placed
            t_parts.append(np.asarray(
                [t.captain.spec.loc for t in placed], np.float64)
                if placed else np.empty((0, 2)))
        if not sids:
            return
        # ONE Morton pass over all users / all placed tasks of all services
        all_users = np.concatenate(u_parts, axis=0)
        all_tasks = np.concatenate(t_parts, axis=0)
        u_codes_all = geohash.encode_batch(all_users[:, 0], all_users[:, 1],
                                           REGION_PRECISION)
        t_codes_all = geohash.encode_batch(all_tasks[:, 0], all_tasks[:, 1],
                                           REGION_PRECISION)
        u_bounds = np.cumsum([0] + [len(p) for p in u_parts])
        t_bounds = np.cumsum([0] + [len(p) for p in t_parts])
        for i, sid in enumerate(sids):
            self._autoscale_service(
                sid, u_parts[i], u_codes_all[u_bounds[i]:u_bounds[i + 1]],
                placed_by_sid[sid],
                t_codes_all[t_bounds[i]:t_bounds[i + 1]])

    def _autoscale_service(self, service_id: str, user_locs: np.ndarray,
                           user_codes: np.ndarray, placed: List[Task],
                           t_codes: np.ndarray):
        spec = self.services[service_id]
        region_codes, first_seen, inverse, counts = np.unique(
            user_codes, return_index=True, return_inverse=True,
            return_counts=True)
        n_regions = len(region_codes)
        loc_sums = np.zeros((n_regions, 2))
        np.add.at(loc_sums, inverse, user_locs)
        code_to_region = {int(c): r for r, c in enumerate(region_codes)}
        task_buckets: List[List[Task]] = [[] for _ in region_codes]
        for t, tc in zip(placed, t_codes):
            r = code_to_region.get(int(tc))
            if r is not None:
                task_buckets[r].append(t)
        # visit regions in first-user order (the pre-refactor dict grouping
        # order), so spawn contention resolves exactly as before
        for r in np.argsort(first_seen, kind="stable"):
            code = region_codes[r]
            n_users = int(counts[r])
            cap = self._capacity(task_buckets[r]) or 1e-9
            if n_users / cap <= self.overload_ratio:
                continue
            # multi-replica plan: close the whole capacity deficit in one
            # pass (each spawned replica claims its node slot immediately,
            # so consecutive spawns spread across captains)
            deficit = int(np.ceil(n_users / self.overload_ratio - cap))
            n_spawn = max(1, min(deficit, MAX_SPAWN_PER_REGION))
            centroid = (float(loc_sums[r, 0]) / n_users,
                        float(loc_sums[r, 1]) / n_users)
            spawned = 0
            for _ in range(n_spawn):
                if self._spawn_task(spec, centroid) is None:
                    break
                spawned += 1
            if spawned:
                gh = geohash.code_to_str(int(code), REGION_PRECISION)
                self.scale_events.append(
                    {"t": self.sim.now, "service": service_id,
                     "region": gh, "users": n_users, "cap": cap,
                     "spawned": spawned})
                self.sim.log("autoscale_up", service=service_id,
                             region=gh, n=spawned)

    # ------------------------------------------------------------ shrink

    def scale_down(self, service_id: str):
        spec = self.services[service_id]
        tasks = [t for t in self.tasks[service_id] if t.status == "running"]
        if len(tasks) <= spec.min_replicas:
            return
        # only probe captains that are still alive — a failed captain's
        # queue is gone, so load() would report a bogus idle node — and
        # still Beacon-visible: a node whose fault domain's Beacon died
        # looks idle (its users handed off) but cannot be reached by the
        # control plane; reclaiming it would destroy the very replicas
        # the heartbeat replay is about to bring back
        hidden = self.engine.hidden_nodes
        idle = [t for t in tasks
                if t.captain is not None and t.captain.alive
                and t.captain.node_id not in hidden
                and t.captain.load() == 0]
        if idle:
            victim = idle[-1]
            self.spinner.cancel_task(victim)
            self.engine.invalidate(service_id)
            self.sim.log("autoscale_down", task=victim.task_id)
