"""Application Manager (paper §3.2): service lifecycle, the candidate-list
half of 2-step selection (Algorithm 1), and demand-driven auto-scaling.

Auto-scaling: 3 replicas at deploy time (fault-tolerance floor), then more
wherever real users concentrate — the AM groups active users by reduced-
precision geohash and asks Spinner for capacity in overloaded regions.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import geohash
from repro.core.cluster import Topology
from repro.core.sim import Simulator
from repro.core.spinner import Image, Spinner

_NET_AFFINITY = {
    ("ethernet", "ethernet"): 1.0, ("ethernet", "wifi"): 0.7,
    ("wifi", "ethernet"): 0.7, ("wifi", "wifi"): 0.6,
    ("lte", "lte"): 0.5, ("lte", "wifi"): 0.4, ("wifi", "lte"): 0.4,
    ("lte", "ethernet"): 0.5, ("ethernet", "lte"): 0.5,
}


@dataclass
class ServiceSpec:
    service_id: str
    image: Image
    workload_scale: float = 1.0            # × node per-frame reference time
    locations: List[Tuple[float, float]] = field(default_factory=list)
    need_storage: bool = False
    storage_capacity_mb: float = 100.0
    consistency: str = "eventual"          # "strong" | "eventual"
    data_source: str = "Cloud"
    min_replicas: int = 3


@dataclass
class Task:
    task_id: str
    service_id: str
    captain: Optional[object] = None
    status: str = "pending"
    ready_at: Optional[float] = None


class ApplicationManager:
    def __init__(self, sim: Simulator, topo: Topology, spinner: Spinner,
                 cargo_manager=None, *, top_n: int = 3,
                 scale_check_s: float = 2.0,
                 overload_ratio: float = 1.5):
        self.sim = sim
        self.topo = topo
        self.spinner = spinner
        self.cargo_manager = cargo_manager
        self.top_n = top_n
        self.scale_check_s = scale_check_s
        self.overload_ratio = overload_ratio
        self.services: Dict[str, ServiceSpec] = {}
        self.tasks: Dict[str, List[Task]] = {}
        self.users: Dict[str, List[object]] = {}
        self._ids = itertools.count()
        self.autoscale_enabled = True
        self.scale_events: List[dict] = []

    # ----------------------------------------------------------- deployment

    def deploy_service(self, spec: ServiceSpec, selection: str = "armada"):
        self.services[spec.service_id] = spec
        self.tasks[spec.service_id] = []
        self.users[spec.service_id] = []
        locs = spec.locations or [next(iter(
            self.spinner.captains.values())).spec.loc]
        for i in range(spec.min_replicas):
            self._spawn_task(spec, locs[i % len(locs)], selection)
        if spec.need_storage and self.cargo_manager is not None:
            self.cargo_manager.store_register(spec)
        self._schedule_autoscale(spec.service_id)

    def _spawn_task(self, spec: ServiceSpec, location,
                    selection: str = "armada") -> Optional[Task]:
        task = Task(f"{spec.service_id}/t{next(self._ids)}", spec.service_id)
        dt = self.spinner.deploy_task(task, spec.image, location,
                                      selection=selection,
                                      on_ready=self._task_ready)
        if dt is None:
            return None
        self.tasks[spec.service_id].append(task)
        return task

    def _task_ready(self, task: Task):
        self.sim.log("task_ready", task=task.task_id,
                     node=task.captain.node_id)
        # storage layer follows compute expansion (paper §3.4 auto-scaling)
        spec = self.services[task.service_id]
        if spec.need_storage and self.cargo_manager is not None:
            self.cargo_manager.on_new_task(spec, task)

    # ----------------------------------------------- service discovery (Alg 1)

    def candidate_list(self, service_id: str, user_loc, user_net: str,
                       top_n: Optional[int] = None) -> List[Task]:
        """Step 1 of 2-step selection: score nearby running replicas."""
        running = [t for t in self.tasks.get(service_id, ())
                   if t.status == "running" and t.captain is not None
                   and t.captain.alive]
        if not running:
            return []
        items = [(t.task_id, t.captain.spec.loc) for t in running]
        local_ids = set(geohash.proximity_search(user_loc, items,
                                                 precision=4))
        local = [t for t in running if t.task_id in local_ids] or running
        w1, w2, w3 = 0.5, 0.2, 0.3

        def score(t: Task) -> float:
            c = t.captain
            resources = c.free_fraction()
            aff = _NET_AFFINITY.get((c.spec.net_type, user_net), 0.5)
            d = geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                    user_loc[0], user_loc[1])
            prox = 1.0 / (1.0 + d / 10.0)
            return w1 * resources + w2 * aff + w3 * prox

        local.sort(key=score, reverse=True)
        return local[:top_n or self.top_n]

    # -------------------------------------------------------------- users

    def user_join(self, service_id: str, client):
        self.users[service_id].append(client)

    def user_leave(self, service_id: str, client):
        if client in self.users.get(service_id, ()):
            self.users[service_id].remove(client)

    # ---------------------------------------------------------- auto-scaling

    def _schedule_autoscale(self, service_id: str):
        self.sim.after(self.scale_check_s * 1000.0, self._autoscale_tick,
                       service_id)

    def _autoscale_tick(self, service_id: str):
        if service_id not in self.services:
            return
        if self.autoscale_enabled:
            self._autoscale(service_id)
        self._schedule_autoscale(service_id)

    def _capacity(self, tasks: List[Task]) -> int:
        seen, cap = set(), 0
        for t in tasks:
            if t.captain and t.captain.alive and t.status == "running" \
                    and t.captain.node_id not in seen:
                seen.add(t.captain.node_id)
                cap += t.captain.spec.slots
            elif t.status == "deploying":
                cap += 1                      # in-flight capacity
        return cap

    def _autoscale(self, service_id: str):
        spec = self.services[service_id]
        clients = self.users.get(service_id, ())
        if not clients:
            return
        # group active users by coarse geohash region
        regions: Dict[str, List] = {}
        for c in clients:
            gh = geohash.encode(*c.loc, precision=3)
            regions.setdefault(gh, []).append(c)
        for gh, users in regions.items():
            tasks_here = [
                t for t in self.tasks[service_id]
                if t.captain is not None and t.status in
                ("running", "deploying")
                and geohash.encode(*t.captain.spec.loc, precision=3) == gh]
            cap = self._capacity(tasks_here) or 1e-9
            if len(users) / cap > self.overload_ratio:
                centroid = (
                    sum(u.loc[0] for u in users) / len(users),
                    sum(u.loc[1] for u in users) / len(users))
                t = self._spawn_task(spec, centroid)
                if t is not None:
                    self.scale_events.append(
                        {"t": self.sim.now, "service": service_id,
                         "region": gh, "users": len(users), "cap": cap})
                    self.sim.log("autoscale_up", service=service_id,
                                 region=gh)

    # ------------------------------------------------------------ shrink

    def scale_down(self, service_id: str):
        spec = self.services[service_id]
        tasks = [t for t in self.tasks[service_id] if t.status == "running"]
        if len(tasks) <= spec.min_replicas:
            return
        idle = [t for t in tasks if t.captain.load() == 0]
        if idle:
            victim = idle[-1]
            self.spinner.cancel_task(victim)
            self.sim.log("autoscale_down", task=victim.task_id)
