"""Vectorized client pool: U live users as structure-of-arrays state.

The paper's evaluation is client-driven — 2-step selection, periodic
probing with per-candidate latency EMAs, two-round confirmed switches,
and zero-downtime failover.  ``repro.core.client.Client`` runs one user
per Python object; this module runs the whole population through shared
array state so the end-to-end simulator scales to 100k+ users
(``benchmarks/bench_client_scale.py``).

Layering:

* **Pure policy functions** (`ema_fold`, `switch_decide`,
  `failover_pick`, `mode_filter`) — the client-side half of the paper's
  algorithms as array transforms over SoA state.  They are shared
  verbatim by the scalar ``Client`` (U=1 rows) and the pool, and take an
  ``xp`` module so the per-tick EMA/switch update can run under
  ``jax.numpy`` (a later step can fuse it into ``kernels/geo_topk``'s
  scoring pass).
* **``ClientPool``** — SoA state (candidate index matrix, per-(user,
  node) EMA table, pending-switch/downtime arrays, per-user mode codes
  for the paper's six baselines) driven by pool-level simulator events:
  one ``candidate_indices`` call and one vectorized EMA/switch update
  per probe tick for the entire population.

Two data-plane transports:

* ``transport="events"`` — every request still rides the per-request
  ``Captain.arrive`` path, and all RNG draws happen in exactly the order
  U scalar ``Client`` objects would make them (batched via
  ``Simulator.jitter_batch``, which is bit-identical to sequential
  draws).  A pool in this mode reproduces scalar clients **bit-for-bit**
  — samples, EMA trajectories, and switch decisions
  (tests/test_client_pool.py pins this on the paper's Fig. 8/10
  scenarios).  The control plane (selection, switch, failover decisions)
  is vectorized; the data plane stays event-accurate.
* ``transport="fluid"`` — requests are aggregated per node per tick
  through ``Captain.arrive_batch``: a fluid multi-slot queue model gives
  every request a queueing delay from the node's backlog trajectory, and
  EMAs are folded in vectorized arrival-order rounds.  Statistically
  faithful (not bit-for-bit) and scales to 100k users × 1k nodes.

The fluid transport runs its probe tick in one of two modes:
``tick="host"`` (numpy policy update, optionally geo_topk-backed
selection) or ``tick="device"`` — the whole tick as one jitted device
program over resident SoA state (``repro.core.fused_tick``): scoring →
candidate top-k → EMA fold → switch decision → failover pick with no
numpy round-trips.  The device tick reproduces the host tick's decision
stream exactly (same fp32 scoring inputs, same xp-generic policy
functions) and is pinned against it in tests/test_fused_tick.py.

When the ``SelectionEngine`` is region-sharded (``shard_precision`` on
the ``ApplicationManager``/``ArmadaSystem``), both tick modes route each
user chunk to its home-region shard transparently — the host tick
through the engine's sharded query paths, the device tick through
per-shard fused scoring with a fixed-capacity cross-shard border pass
(``shard_border_cap``); decisions stay identical to the unsharded pool.
The same routing carries the multi-Beacon handoff: when a region's
Beacon fault domain fails (``ArmadaSystem.fail_beacon``), the engine's
ownership map re-points that region at the nearest live Beacon, so the
pool's batched refresh — numpy, kernel, and fused device tick alike —
hands the affected users off to the adopting shard without any per-user
bookkeeping, and re-homes them when the Beacon recovers.  Nodes whose
registration died with the Beacon drop out of the schedulable mask (a
dynamic input — no jit-shape change) until their heartbeat replay
lands; the data plane keeps serving actives throughout
(tests/test_beacon_failover.py pins host/device decision identity
across a kill/recover cycle).

Scalar-parity notes (events transport) — the pool intentionally mirrors
seed-code quirks so equivalence is exact: a user whose *initial*
candidate query is empty retries at 500 ms but never activates (no frame
loop, no probe tick); a user whose whole candidate set dies re-enters
initial selection *and* gains a second probe-tick chain; connection-break
notifications replay in warm-connection insertion order.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import geohash
from repro.core.captain import Request
from repro.core.selection import net_index

# Step-1 wide candidate list size: baselines filter the WIDE list before
# trimming to TopN, so a "dedicated-only" client can't leak onto volunteer
# nodes.  Shared by the scalar Client and the pool path (keeps baseline
# filters consistent — previously hardcoded at client.py:95).
WIDE_TOP_N = 64

RECONNECT_DELAY_MS = 2000.0

# paper baselines (client.py module docstring); array state keeps these as
# int8 codes so a single pool can mix modes per user
MODES = ("armada", "geo", "dedicated", "cloud", "reconnect", "edge2cloud")
MODE_INDEX = {m: i for i, m in enumerate(MODES)}
(MODE_ARMADA, MODE_GEO, MODE_DEDICATED, MODE_CLOUD, MODE_RECONNECT,
 MODE_EDGE2CLOUD) = range(6)


@dataclass
class LatencySample:
    t: float
    ms: float
    node: str
    is_probe: bool = False


# ---------------------------------------------------------------------------
# Pure policy functions (shared by scalar Client and ClientPool)
# ---------------------------------------------------------------------------

def ema_fold(prev, ms, alpha: float, xp=np):
    """One latency-EMA step per row; NaN ``prev`` means no prior sample
    (``Client._on_response`` semantics, same operand order bit-for-bit)."""
    has = ~xp.isnan(prev)
    return xp.where(has, alpha * ms + (1 - alpha) * prev, ms)


def switch_decide(cand_task, cand_ema, active_task, active_ema,
                  pending_task, pend_ema, pend_alive, margin: float, xp=np):
    """Two-round confirmed switch (``Client._maybe_switch``, vectorized).

    Rows are users; ``cand_task`` is a (U, C) int array padded with -1,
    ``cand_ema`` the matching EMA values (NaN unknown), ``active_task``
    the current task per user (-1 none), ``active_ema`` the active
    node's EMA (NaN if unknown).  ``pending_task`` is the task a first
    better-round nominated (-1 none); the caller supplies the pending
    target's current standing — ``pend_ema`` from its EMA table (NaN no
    sample) and ``pend_alive`` (False when -1 or the task died) —
    because the pending target is judged on its OWN merit, not through
    the candidate list.

    Round 1 nominates the instantaneous EMA-argmin; round 2 confirms
    against the NOMINATED task — "is my pending target still better
    than my active?" — not against a fresh argmin, and not through
    candidate-list membership.  Both stricter rules starve convergence
    with hundreds of near-tied candidates: load-feedback in the
    scoring rotates the candidate set every tick, so the nomination
    never reappears (neither as argmin nor as a member) and no user can
    ever leave a drowned node (the bench_serving_selection thin-node
    case).  A pending target that went stale — dead or no longer
    margin-better — falls back to a fresh nomination.

    Returns ``(confirm, target_task, new_pending)``: users to switch,
    the task to switch to (the confirmed pending target for confirmed
    rows, the fresh argmin otherwise), and the updated pending state.
    Pure in ``xp`` — runs under numpy or jax.numpy unchanged.
    """
    valid = cand_task >= 0
    known = valid & ~xp.isnan(cand_ema)
    eligible = valid.any(axis=1) & known.any(axis=1) & (active_task >= 0)
    masked = xp.where(known, cand_ema, xp.inf)
    best_slot = xp.argmin(masked, axis=1)
    rows = xp.arange(cand_task.shape[0])
    best_ema = masked[rows, best_slot]
    best_task = cand_task[rows, best_slot]
    better = (eligible & (best_task != active_task)
              & ~xp.isnan(active_ema) & (best_ema < margin * active_ema))
    # round 2: the pending nomination confirms on its own merit.  NOT
    # gated on ``eligible`` — under full rotation this tick's fresh
    # candidates are all still unprobed (every EMA NaN), and requiring a
    # known candidate would block confirmation forever
    has_pend = (pending_task >= 0) & pend_alive & ~xp.isnan(pend_ema)
    confirm = (has_pend & (pending_task != active_task)
               & (active_task >= 0) & ~xp.isnan(active_ema)
               & (pend_ema < margin * active_ema))
    target_task = xp.where(confirm, pending_task, best_task)
    new_pending = xp.where(
        confirm, -1, xp.where(better, best_task,
                              xp.where(eligible, -1, pending_task)))
    return confirm, target_task, new_pending


def failover_pick(cand_task, cand_ema, xp=np):
    """Post-break target: best known-EMA candidate, else the first
    remaining candidate, else -1 (``Client.on_connection_break``'s armada
    branch).  Returns the winning slot per row."""
    valid = cand_task >= 0
    known = valid & ~xp.isnan(cand_ema)
    masked = xp.where(known, cand_ema, xp.inf)
    best = xp.argmin(masked, axis=1)
    first = xp.argmax(valid, axis=1)
    slot = xp.where(known.any(axis=1), best, first)
    return xp.where(valid.any(axis=1), slot, -1)


def compact_rows(values: np.ndarray, keep: np.ndarray,
                 width: Optional[int] = None) -> np.ndarray:
    """Per-row left-compaction: kept entries of ``values`` slide left in
    order, rows are right-padded with -1 and truncated to ``width``."""
    u, w = values.shape
    width = w if width is None else width
    rank = keep.cumsum(axis=1) - 1
    out = np.full((u, width), -1, np.int32)
    take = keep & (rank < width)
    rows, cols = np.nonzero(take)
    out[rows, rank[rows, cols]] = values[rows, cols]
    return out


def mode_filter(wide_idx: np.ndarray, modes: np.ndarray, top_n: int,
                task_cloud: np.ndarray, task_dedicated: np.ndarray,
                task_lat: np.ndarray, task_lon: np.ndarray,
                user_lat: np.ndarray, user_lon: np.ndarray) -> np.ndarray:
    """Baseline filters over the WIDE list, then trim to TopN
    (``Client._apply_mode_filter`` + ``[:top_n]``, vectorized).

    ``wide_idx``: (U, W) ranked task indices padded with -1; attribute
    arrays are indexed by task.  Returns (U, top_n) padded with -1,
    preserving rank order.
    """
    u, _ = wide_idx.shape
    valid = wide_idx >= 0
    safe = np.where(valid, wide_idx, 0)
    keep = valid.copy()

    is_ded = modes == MODE_DEDICATED
    if is_ded.any():
        ded_ok = valid & task_dedicated[safe] & ~task_cloud[safe]
        use = is_ded & ded_ok.any(axis=1)          # "ded or cands"
        keep = np.where(use[:, None], ded_ok, keep)
    is_cloud = modes == MODE_CLOUD
    if is_cloud.any():
        keep = np.where(is_cloud[:, None], valid & task_cloud[safe], keep)
    is_geo = modes == MODE_GEO
    if is_geo.any():
        # same argument order as the scalar path: distance(node, user)
        d = geohash.distance_km_batch(task_lat[safe], task_lon[safe],
                                      user_lat[:, None], user_lon[:, None])
        d = np.where(valid, d, np.inf)
        g = np.argmin(d, axis=1)
        rows = np.arange(u)
        geo_keep = np.zeros_like(keep)
        geo_keep[rows, g] = valid[rows, g]
        keep = np.where(is_geo[:, None], geo_keep, keep)

    return compact_rows(wide_idx, keep, top_n)


# ---------------------------------------------------------------------------
# Per-(user, node) EMA table
# ---------------------------------------------------------------------------

class _EmaTable:
    """Fixed-width per-user map node -> EMA, grown on demand.

    Mirrors ``Client.ema`` (a per-user dict): NaN value == key absent
    (``pop`` NaNs the value but keeps the slot, so a node that returns
    later reuses it — semantically identical to dict pop + re-insert).
    Memory is O(U * distinct-nodes-ever-probed-per-user), not O(U * N).
    """

    def __init__(self, n_users: int, k0: int = 8):
        self.nodes = np.full((n_users, k0), -1, np.int32)
        self.vals = np.full((n_users, k0), np.nan)

    def _grow(self):
        u, k = self.nodes.shape
        self.nodes = np.concatenate(
            [self.nodes, np.full((u, k), -1, np.int32)], axis=1)
        self.vals = np.concatenate(
            [self.vals, np.full((u, k), np.nan)], axis=1)

    def ensure(self, rows: np.ndarray, nodes: np.ndarray):
        """Reserve a slot for (row, node).  Rows must be unique."""
        if rows.size == 0:
            return
        eq = self.nodes[rows] == nodes[:, None]
        miss = ~eq.any(axis=1)
        while miss.any():
            sub = self.nodes[rows[miss]]
            free = sub == -1
            if not free.any(axis=1).all():
                self._grow()
                continue
            self.nodes[rows[miss], free.argmax(axis=1)] = nodes[miss]
            break

    def get(self, rows: np.ndarray, nodes: np.ndarray) -> np.ndarray:
        """EMA per (row, node); NaN when absent."""
        if rows.size == 0:
            return np.empty(0)
        eq = self.nodes[rows] == nodes[:, None]
        v = self.vals[rows, eq.argmax(axis=1)]
        return np.where(eq.any(axis=1), v, np.nan)

    def get_matrix(self, rows: np.ndarray, node_mat: np.ndarray) -> np.ndarray:
        out = np.empty(node_mat.shape)
        for c in range(node_mat.shape[1]):
            out[:, c] = self.get(rows, node_mat[:, c])
        return out

    def fold(self, rows: np.ndarray, nodes: np.ndarray, ms: np.ndarray,
             alpha: float):
        """Apply one EMA step per (row, node) pair.  (row, node) pairs must
        be unique within one call; rows may repeat with distinct nodes."""
        if rows.size == 0:
            return
        # allocate any missing slots one unique-row batch at a time
        eq = self.nodes[rows] == nodes[:, None]
        miss = np.nonzero(~eq.any(axis=1))[0]
        while miss.size:
            uniq, first = np.unique(rows[miss], return_index=True)
            self.ensure(uniq, nodes[miss[first]])
            handled = np.zeros(miss.size, bool)
            handled[first] = True
            miss = miss[~handled]
        eq = self.nodes[rows] == nodes[:, None]
        slots = eq.argmax(axis=1)
        prev = self.vals[rows, slots]
        self.vals[rows, slots] = ema_fold(prev, ms, alpha)

    def pop(self, rows: np.ndarray, node: int):
        """``ema.pop(node_id, None)`` for every row."""
        if rows.size == 0:
            return
        eq = self.nodes[rows] == node
        vals = self.vals[rows]
        vals[eq] = np.nan
        self.vals[rows] = vals

    def as_dict(self, row: int, node_ids: List[str]) -> Dict[str, float]:
        out = {}
        for n, v in zip(self.nodes[row], self.vals[row]):
            if n >= 0 and not np.isnan(v):
                out[node_ids[n]] = float(v)
        return out


# synthetic base-RTT model constants — the fused device tick
# (core/fused_tick.py) recomputes this model on device from these same
# values, so edit them here, not there
RTT_LAST_MILE_MS = 6.0
RTT_MS_PER_KM = 0.05
RTT_CLOUD_PENALTY_MS = 55.0


def default_rtt_model(user_lat, user_lon, node_lat, node_lon, node_cloud):
    """Synthetic base RTT for users without explicit Topology entries:
    last-mile floor + propagation by great-circle distance, plus a transit
    penalty into the cloud."""
    d = geohash.distance_km_batch(user_lat, user_lon, node_lat, node_lon)
    return RTT_LAST_MILE_MS + RTT_MS_PER_KM * d \
        + np.where(node_cloud, RTT_CLOUD_PENALTY_MS, 0.0)


# ---------------------------------------------------------------------------
# Incremental refresh
# ---------------------------------------------------------------------------

class _RefreshTracker:
    """Host-side dirty-set bookkeeping for incremental candidate refresh
    (``ClientPool(refresh_period_ms=...)``).

    A user is rescored only when it is *dirty*:

    * **region epoch** — its serving shard's node set changed (churn
      recovery, autoscale spawn, hidden/ownership/locality change):
      diffed from ``SelectionEngine.region_epoch`` / ``epoch_all``;
    * **route change** — Beacon handoff / re-home moved the user to a
      different serving shard (``owner_version`` diff of routed codes);
    * **pool event** — its candidate set or active replica was touched
      by a connection break (``mark``), or it lost every candidate;
    * **staleness** — its per-user refresh deadline fired.  Deadlines
      are staggered over ``STAGGER`` deterministic phase lanes so a
      population never rescores in one burst, and re-armed only when
      the user is *actually* refreshed — a deadline deferred by a
      discovery window (masks compose by AND) fires exactly once.

    One tracker instance drives every tick path (numpy, geo_topk
    kernel, fused device tick, mesh): the mask is computed host-side
    from the same inputs, so the paths stay decision-identical.
    """

    STAGGER = 64

    def __init__(self, pool: "ClientPool", period_ms: float):
        self.pool = pool
        self.period = float(period_ms)
        u = pool.n_users
        self.marks = np.zeros(u, bool)
        lane = (np.arange(u) % self.STAGGER) + 1
        self.next_refresh = pool.sim.now \
            + self.period * lane / float(self.STAGGER)
        self._seen_all: Optional[int] = None
        self._seen_region: Dict[int, int] = {}
        self._routes: Optional[np.ndarray] = None
        self._route_owner_version = -1
        # stats for benchmarks: per-tick dirty counts (post-gating) and
        # device sparse-capacity overflows (fell back to the dense scan)
        self.dirty_counts: List[int] = []
        self.fallbacks = 0

    def mark(self, users) -> None:
        self.marks[users] = True

    def note_refreshed(self, refreshed, now: float) -> None:
        """``refreshed`` users were rescored this tick: clear their event
        marks and re-arm their staleness deadlines."""
        self.marks[refreshed] = False
        self.next_refresh[refreshed] = now + self.period

    def dirty_mask(self, now: float) -> np.ndarray:
        """(U,) bool — users whose candidates may be stale.  Forces the
        engine's lazy view/shard rebuild *before* reading epochs, so the
        host and device paths observe identical marks no matter which
        rebuilt last."""
        pool = self.pool
        eng = pool.am.engine
        pool._view()
        sv = eng.shard_view(pool.service_id,
                            pool.am.tasks.get(pool.service_id, ())) \
            if eng.shard_precision is not None else None
        dirty = self.marks.copy()
        if sv is not None and \
                eng.owner_version != self._route_owner_version:
            routes = sv.route(pool._user_codes())
            if self._routes is not None:
                dirty |= routes != self._routes
            self._routes = routes
            self._route_owner_version = eng.owner_version
        engine_dirty = self._engine_dirty(eng)
        if engine_dirty is True:
            dirty[:] = True
        elif engine_dirty is not False:
            dirty |= engine_dirty
        dirty |= self.next_refresh <= now
        return dirty

    def _engine_dirty(self, eng):
        """Epoch diff vs the last-seen snapshot: False / True (all) / a
        (U,) bool mask of users routed to a bumped region."""
        if self._seen_all is None:
            # first tick: adopt the epochs that produced the initial
            # selection — nothing is stale yet
            self._seen_all = eng.epoch_all
            self._seen_region = dict(eng.region_epoch)
            return False
        if eng.epoch_all != self._seen_all:
            self._seen_all = eng.epoch_all
            self._seen_region = dict(eng.region_epoch)
            return True
        changed = [c for c, e in eng.region_epoch.items()
                   if self._seen_region.get(c, 0) != e]
        if not changed:
            return False
        self._seen_region = dict(eng.region_epoch)
        if self._routes is None:
            return True
        return np.isin(self._routes,
                       np.asarray(changed, self._routes.dtype))


# ---------------------------------------------------------------------------
# ClientPool
# ---------------------------------------------------------------------------

class ClientPool:
    """U users of one service as SoA state driven by pool-level events.

    ``client_ids`` names Topology endpoints (locations/net types/RTTs come
    from the topology, exactly like scalar clients); alternatively pass
    ``locs`` (U, 2) and ``nets`` for synthetic populations at scales where
    materializing per-user NodeSpecs is wasteful (RTTs then come from
    ``rtt_model``).

    All users start together (``pool.start()`` — one simulator event); for
    staggered cohorts, use several pools.
    """

    def __init__(self, sim, topo, am, service_id: str, *,
                 client_ids: Optional[Sequence[str]] = None,
                 locs=None, nets="wifi", mode="armada",
                 frame_interval_ms: float = 0.0,
                 probe_period_ms: float = 2000.0, ema_alpha: float = 0.4,
                 switch_margin: float = 0.95, workload_scale: float = 1.0,
                 transport: str = "events",
                 selection_backend: str = "numpy",
                 tick: str = "host",
                 rtt_model: Callable = default_rtt_model,
                 record_samples: bool = True,
                 latency_hist: bool = False,
                 shard_border_cap: Optional[int] = None,
                 ema_slots: Optional[int] = None,
                 mesh=None,
                 refresh_period_ms: Optional[float] = None,
                 refresh_cap: Optional[int] = None,
                 data_profile=None):
        if transport not in ("events", "fluid"):
            raise ValueError(f"unknown transport {transport!r}")
        if data_profile is not None and transport != "fluid":
            raise ValueError(
                "data_profile=... folds a per-window Cargo access term "
                "into the fluid latency model — the events transport "
                "models per-request I/O through Cargo.read/write instead")
        if refresh_period_ms is not None:
            if transport != "fluid":
                raise ValueError(
                    "refresh_period_ms=... (incremental refresh) needs "
                    "transport='fluid' — the events transport derives its "
                    "probe sends from the refresh plan, so skipping a "
                    "refresh would skip probing too")
            if refresh_period_ms <= 0:
                raise ValueError("refresh_period_ms must be > 0")
        elif refresh_cap is not None:
            raise ValueError("refresh_cap sizes the device tick's sparse "
                             "refresh buffer — pass refresh_period_ms too")
        if mesh is not None and tick != "device":
            raise ValueError("mesh=... shards the fused device tick "
                             "across devices — pass tick='device'")
        if selection_backend not in ("numpy", "geo_topk"):
            raise ValueError(
                f"unknown selection_backend {selection_backend!r}")
        if selection_backend == "geo_topk" and transport == "events":
            raise ValueError("geo_topk backend is fp32 — only the "
                             "statistical fluid transport may use it")
        if tick not in ("host", "device"):
            raise ValueError(f"unknown tick {tick!r}")
        if tick == "device":
            # the fused device tick covers the paper's armada policy on
            # synthetic (locs-based) populations; baselines and topology
            # endpoints stay on the host tick
            if transport != "fluid":
                raise ValueError("tick='device' needs transport='fluid'")
            if selection_backend != "geo_topk":
                raise ValueError("tick='device' scores through geo_topk — "
                                 "pass selection_backend='geo_topk'")
            if client_ids is not None:
                raise ValueError("tick='device' needs locs-based users "
                                 "(RTTs from rtt_model, not the topology)")
            if rtt_model is not default_rtt_model:
                raise ValueError("tick='device' computes default_rtt_model "
                                 "on device; custom models need tick='host'")
            if mode != "armada" and (isinstance(mode, str) or
                                     any(m != "armada" for m in mode)):
                raise ValueError("tick='device' fuses the armada policy "
                                 "only; baselines run tick='host'")
        if transport == "fluid" and not \
                0 < frame_interval_ms <= probe_period_ms:
            # scalar semantics for interval 0 are back-to-back saturating
            # frames (an unbounded train the fluid window can't model), and
            # an interval longer than the window floors to zero frames —
            # refuse both rather than silently send probes only
            raise ValueError(
                "fluid transport needs 0 < frame_interval_ms <= "
                "probe_period_ms")
        self.sim = sim
        self.topo = topo
        self.am = am
        self.service_id = service_id
        self.transport = transport
        self.selection_backend = selection_backend
        self.tick_mode = tick
        self._dev = None                    # FusedTickDriver (device tick)
        self.frame_interval = frame_interval_ms
        self.probe_period = probe_period_ms
        self.alpha = ema_alpha
        self.switch_margin = switch_margin
        self.workload_scale = workload_scale
        self.rtt_model = rtt_model
        self.record_samples = record_samples
        # device tick + region-sharded engine: rows reserved for the
        # cross-shard border pass (None = FusedTickDriver's U/8 default)
        self.shard_border_cap = shard_border_cap
        # device tick: per-user EMA node slots (None = driver default);
        # raise for scenarios where users sample many distinct nodes —
        # e.g. a long partition scoring a region against remote metros
        self.ema_slots = ema_slots
        # device tick: shard the population across a device mesh — a
        # jax.sharding.Mesh with one axis, or an int device count
        # (resolved against jax.devices() at start)
        self.mesh = mesh
        # incremental candidate refresh: rescore only dirty users, at
        # most every refresh_period_ms per user (None = every tick, the
        # bit-for-bit historical semantics); refresh_cap bounds the device
        # tick's sparse gather (None = driver default, U/8)
        self.refresh_period = refresh_period_ms
        self.refresh_cap = refresh_cap
        self._rt: Optional[_RefreshTracker] = None
        # in-situ data plane: per-request Cargo access profile
        # (``repro.core.storage.cargo_manager.DataProfile``).  Every tick
        # path folds the same host-computed per-user ``data_ms`` into the
        # frame latency model — see ``_data_node_ms``
        self.data_profile = data_profile
        self._data_reps = None          # (nearest, reps) of the last tick
        # client-side Beacon discovery (engine.discovery_ms): bootstrap
        # pays one window before the first selection; a handoff charges
        # per-user windows that gate candidate refreshes only
        self._discovered = False
        self._disc_until: Optional[np.ndarray] = None
        self._disc_route: Optional[np.ndarray] = None
        self._disc_codes: Optional[np.ndarray] = None
        self._disc_owner_version = -1

        if client_ids is not None:
            self.client_ids: Optional[List[str]] = list(client_ids)
            self.locs = np.asarray(
                [topo.nodes[c].loc for c in self.client_ids], np.float64)
            self.net_ix = np.asarray(
                [net_index(topo.nodes[c].net_type) for c in self.client_ids],
                np.int64)
        else:
            self.client_ids = None
            self.locs = np.asarray(locs, np.float64).reshape(-1, 2)
            if isinstance(nets, str):
                self.net_ix = np.full(len(self.locs), net_index(nets),
                                      np.int64)
            else:
                self.net_ix = np.asarray(
                    [net_index(n) for n in nets], np.int64)
        self.n_users = len(self.locs)
        u = self.n_users
        if isinstance(mode, str):
            self.modes = np.full(u, MODE_INDEX[mode], np.int8)
        else:
            self.modes = np.asarray([MODE_INDEX[m] for m in mode], np.int8)

        top_n = am.top_n
        self.top_n = top_n
        self.running = np.zeros(u, bool)
        self.ticking = np.zeros(u, bool)        # main probe-tick membership
        self.cand_task = np.full((u, top_n), -1, np.int32)
        self.active = np.full(u, -1, np.int32)
        self.pending = np.full(u, -1, np.int32)
        self.downtime_until = np.zeros(u)
        self.ema_tab = _EmaTable(u)

        # node registry: node_id string <-> small int, + captain handles
        self._node_of: Dict[str, int] = {}
        self._node_ids: List[str] = []
        self._node_caps: List[object] = []
        # warm-connection mirror: node idx -> ordered {user: None}; replay
        # order for break notifications == scalar insertion order
        self._conn: Dict[int, Dict[int, None]] = {}
        self._watched: set = set()              # fluid: captains we joined
        self._rtt_cache: Dict[Tuple[int, int], float] = {}

        # per-task derived arrays, rebuilt when the replica set fingerprint
        # changes (tracked by SelectionEngine's service_view cache)
        self._last_view = None
        self.task_node = np.empty(0, np.int32)

        # metrics
        self.switch_t: List[float] = []
        self.switch_user: List[int] = []
        self.switch_from: List[str] = []
        self.switch_to: List[str] = []
        self.sample_u: List[int] = []
        self.sample_t: List[float] = []
        self.sample_ms: List[float] = []
        self.sample_node: List[int] = []
        self.sample_probe: List[bool] = []
        # fluid aggregates
        self.frame_count = np.zeros(u, np.int64)
        self.frame_sum = np.zeros(u)
        self.requests_sent = 0
        self.ticks_run = 0
        self.failovers = 0
        self._fluid_buf: List[Tuple] = []       # (users, nodes, ms, rounds)
        # frame-latency histogram (latency_hist=True): log-spaced bins
        # 1 ms .. ~100 s, ~5% wide — tail quantiles / SLO-violation
        # fractions at population scale without per-sample records.  The
        # top decade exists for saturation studies: a drowned node's
        # fluid backlog reaches tens of seconds, and p99 must resolve
        # there rather than clip at the final edge.
        # Fed by the fluid transport's flush and the device tick's
        # per-window latency stash (bench_serving_selection).
        self._lat_edges: Optional[np.ndarray] = None
        self._lat_hist: Optional[np.ndarray] = None
        if latency_hist:
            self._lat_edges = np.concatenate(
                [[0.0], np.logspace(0.0, 5.0, 230), [np.inf]])
            self._lat_hist = np.zeros(self._lat_edges.size - 1, np.int64)
        # per-phase wall time (ms) accumulated across ticks, so benchmark
        # runs can attribute where a tick goes (selection / policy /
        # transport on the host tick; fused_tick / transport on device)
        self.phase_ms: Dict[str, float] = {}

    # ------------------------------------------------------------- control

    def phase_add(self, name: str, t0: float) -> None:
        """Accumulate wall time since ``t0`` under phase ``name``."""
        self.phase_ms[name] = self.phase_ms.get(name, 0.0) \
            + (time.perf_counter() - t0) * 1e3

    def start(self):
        """Start every user (one simulator event; schedule with
        ``sim.at(t, pool.start)`` like a scalar client's ``start``)."""
        dms = float(getattr(self.am.engine, "discovery_ms", 0.0))
        if dms > 0 and not self._discovered:
            # bootstrap Beacon discovery: one window before the first
            # selection can be requested (previously free)
            self._discovered = True
            self.sim.after(dms, self.start)
            return
        self.running[:] = True
        self.am.user_join(self.service_id, self)
        if self.refresh_period is not None:
            self._rt = _RefreshTracker(self, self.refresh_period)
        sel = np.arange(self.n_users)
        if self.transport == "events":
            plan = self._refresh(sel, initial=True)
            self._dispatch(plan)
            if self.ticking.any():
                self.sim.after(self.probe_period, self._probe_tick)
        elif self.tick_mode == "device":
            self._start_device(sel)
        else:
            self._start_fluid(sel)

    def _start_device(self, sel: np.ndarray):
        """Host-side initial selection (same code path as the host tick),
        then hand the probe-tick chain to the fused device driver — the
        single-device one, or the mesh-sharded one (``mesh=...``)."""
        from repro.core.fused_tick import FusedTickDriver, MeshTickDriver
        self._refresh(sel, initial=True)
        kw = {} if self.ema_slots is None else {"ema_slots": self.ema_slots}
        if self.mesh is not None:
            mesh = self.mesh
            if isinstance(mesh, int):
                import jax
                from jax.sharding import Mesh
                if not 1 <= mesh <= len(jax.devices()):
                    raise ValueError(
                        f"mesh={mesh} devices requested, "
                        f"{len(jax.devices())} available")
                mesh = Mesh(np.asarray(jax.devices()[:mesh]), ("users",))
            self._dev = MeshTickDriver(self, mesh, **kw)
        else:
            self._dev = FusedTickDriver(self, **kw)
        self._dev.init_state()
        self._dev.tick()

    def stop(self, users: Optional[Sequence[int]] = None):
        if self.transport == "fluid":
            self._flush_fluid()             # don't drop the open window
            if self._dev is not None:
                self._dev.flush()
        if users is None:
            self.running[:] = False
        else:
            stopped = np.asarray(users)
            self.running[stopped] = False
            # release the cohort's warm connections (scalar Client.stop
            # discards its connections immediately)
            gone = set(int(u) for u in stopped)
            for nix in list(self._conn):
                d = self._conn[nix]
                for u in gone:
                    d.pop(u, None)
                if not d:
                    del self._conn[nix]
                    cap = self._node_caps[nix]
                    if cap is not None:
                        cap.connections.discard(self)
        if self._dev is not None:
            self._dev.set_running(self.running)
        if not self.running.any():
            self.am.user_leave(self.service_id, self)
            for nix, d in self._conn.items():
                d.clear()
                self._node_caps[nix].connections.discard(self)
            for nix in self._watched:          # fluid-transport watches
                cap = self._node_caps[nix]
                if cap is not None:
                    cap.connections.discard(self)
            self._watched.clear()

    # ------------------------------------------------------ registry/views

    def _view(self):
        tasks = self.am.tasks.get(self.service_id, ())
        view = self.am.engine.service_view(self.service_id, tasks)
        if view is not self._last_view:
            self._last_view = view
            tn = np.full(len(view.tasks), -1, np.int32)
            for i, nid in enumerate(view.node_ids):
                if nid is not None:
                    tn[i] = self._node_ix(nid, view.tasks[i].captain)
            self.task_node = tn
        return view

    def _node_ix(self, node_id: str, captain) -> int:
        ix = self._node_of.get(node_id)
        if ix is None:
            ix = len(self._node_ids)
            self._node_of[node_id] = ix
            self._node_ids.append(node_id)
            self._node_caps.append(captain)
        elif captain is not None:
            self._node_caps[ix] = captain
        return ix

    def _base_rtts(self, users: np.ndarray, tasks: np.ndarray) -> np.ndarray:
        """Unjittered RTT per (user, task) pair."""
        nodes = self.task_node[tasks]
        if self.client_ids is not None:
            out = np.empty(len(users))
            for i, (u, n) in enumerate(zip(users, nodes)):
                key = (int(u), int(n))
                v = self._rtt_cache.get(key)
                if v is None:
                    v = self.topo.rtt(self.client_ids[u], self._node_ids[n])
                    self._rtt_cache[key] = v
                out[i] = v
            return out
        view = self._last_view
        safe = np.where(tasks >= 0, tasks, 0)
        return self.rtt_model(self.locs[users, 0], self.locs[users, 1],
                              view.lat[safe], view.lon[safe],
                              view.cloud[safe])

    # --------------------------------------------- candidate refresh (both)

    def _refresh(self, sel: np.ndarray, *, initial: bool = False,
                 activate_first: bool = False) -> List[Tuple]:
        """Candidate refresh for users ``sel``: ONE batched selection call,
        vectorized mode filter, warm-connection bookkeeping, EMA slot
        reservation.  Returns the send plan — ``(user, probe_tasks,
        frame_task)`` tuples in user order — which ``_dispatch`` turns
        into requests with scalar-identical RNG draw order.
        """
        sel = np.asarray(sel)
        sel = sel[self.running[sel]]                # scalar: if not running
        if sel.size == 0:
            return []
        nets = self.net_ix[sel]
        # baseline filters need the WIDE list; the armada-family modes are
        # a pure trim, so top_n suffices (identical result, k/WIDE the work)
        filtering = np.isin(self.modes[sel],
                            (MODE_GEO, MODE_DEDICATED, MODE_CLOUD))
        wide_k = WIDE_TOP_N if filtering.any() else self.top_n
        if self.selection_backend == "geo_topk":
            wide = self.am.engine.candidate_indices_kernel(
                self.service_id, self.am.tasks.get(self.service_id, ()),
                self.locs[sel], nets, top_n=wide_k)
        else:
            wide = self.am.candidate_indices(
                self.service_id, self.locs[sel], nets, top_n=wide_k)
        view = self._view()
        new = mode_filter(wide, self.modes[sel], self.top_n, view.cloud,
                          view.dedicated, view.lat, view.lon,
                          self.locs[sel, 0], self.locs[sel, 1])

        old = self.cand_task[sel]
        if self.transport == "events":
            self._update_connections(sel, old, new)
        else:
            self._watch_nodes(new)
        self.cand_task[sel] = new

        # reserve EMA slots for every (user, candidate-node) pair so later
        # vectorized folds never race on allocation
        for c in range(new.shape[1]):
            has = new[:, c] >= 0
            if has.any():
                self.ema_tab.ensure(sel[has],
                                    self.task_node[new[has, c]])

        empty = ~(new >= 0).any(axis=1)
        if empty.any():
            # scalar: sim.after(500, _refresh_candidates) — non-initial, so
            # an initially-empty user never activates (quirk kept for
            # parity); one pool event carries the whole subset in order
            self.sim.after(500.0, self._retry, sel[empty].tolist())
        found = sel[~empty]
        if found.size == 0:
            return []

        if initial:
            # provisional best by base RTT until probes return
            cand = self.cand_task[found]
            valid = cand >= 0
            safe = np.where(valid, cand, 0)
            flat_rtt = self._base_rtts(
                np.repeat(found, cand.shape[1]), safe.ravel()
            ).reshape(cand.shape)
            flat_rtt = np.where(valid, flat_rtt, np.inf)
            best = np.argmin(flat_rtt, axis=1)
            self.active[found] = cand[np.arange(len(found)), best]
            self.ticking[found] = True
        if activate_first:
            cand = self.cand_task[found]
            self.active[found] = cand[:, 0]
        if self.transport != "events":
            return []                       # fluid: traffic is per-tick
        plan: List[Tuple] = []
        for u in found:
            probes = [int(t) for t in self.cand_task[u] if t >= 0]
            frame = int(self.active[u]) if (initial or activate_first) else -1
            plan.append((int(u), probes, frame))
        return plan

    def _update_connections(self, sel, old, new):
        """Mirror scalar warm-connection bookkeeping per user, preserving
        the insertion order scalar clients would produce."""
        for i, u in enumerate(sel):
            u = int(u)
            new_set = {int(t) for t in new[i] if t >= 0}
            for t in old[i]:
                if t >= 0 and t not in new_set:
                    self._conn_discard(u, int(t))
            for t in new[i]:
                if t >= 0:
                    self._conn_add(u, int(t))

    def _conn_add(self, u: int, task: int):
        nix = int(self.task_node[task])
        if nix < 0 or self._node_caps[nix] is None:
            return
        d = self._conn.setdefault(nix, {})
        if not d:
            self._node_caps[nix].connections.add(self)
        d[u] = None

    def _conn_discard(self, u: int, task: int):
        nix = int(self.task_node[task])
        d = self._conn.get(nix)
        if d is not None:
            d.pop(u, None)

    def _watch_nodes(self, new):
        """Fluid transport: join the break-notification list of every
        captain hosting a candidate (affected users are computed from the
        candidate matrix at break time — no per-user bookkeeping)."""
        self.watch_node_indices(np.unique(self.task_node[new[new >= 0]]))

    def watch_node_indices(self, nixes):
        """Watch captains by node index (fused-tick driver entry point)."""
        for nix in nixes:
            nix = int(nix)
            if nix >= 0 and nix not in self._watched:
                cap = self._node_caps[nix]
                if cap is not None:
                    cap.connections.add(self)
                    self._watched.add(nix)

    def _retry(self, users: List[int]):
        plan = self._refresh(np.asarray(users, np.int64))
        self._dispatch(plan)

    # ------------------------------------------------- events-mode driving

    def _dispatch(self, plan: List[Tuple]):
        """Turn a send plan into per-request events.  The jitter draws for
        all requests happen in ONE ``jitter_batch`` whose element order is
        exactly the scalar clients' sequential draw order."""
        if not plan or self.transport != "events":
            return
        view = self._last_view
        metas: List[Tuple[int, int, bool]] = []
        for u, probes, frame in plan:
            for t in probes:
                cap = view.tasks[t].captain
                if cap is None or not cap.alive:   # scalar: skip, no draw
                    continue
                metas.append((u, t, True))
            if frame >= 0:
                cap = view.tasks[frame].captain
                if cap is not None and cap.alive:
                    metas.append((u, frame, False))
        if not metas:
            return
        us = np.array([m[0] for m in metas])
        ts = np.array([m[1] for m in metas])
        rtts = self.sim.jitter_batch(self._base_rtts(us, ts), 0.08)
        now = self.sim.now
        for (u, t, is_probe), rtt in zip(metas, rtts):
            task = view.tasks[t]
            rtt = float(rtt)
            req = Request(client=self, task_id=task.task_id, sent_at=now,
                          rtt=rtt, node_id=task.captain.node_id,
                          proc_scale=self.workload_scale,
                          is_probe=is_probe, on_done=self._on_response_ev,
                          user_ix=u)
            self.sim.at(now + rtt / 2, task.captain.arrive, req)
            self.requests_sent += 1

    def _probe_tick(self):
        sel = np.nonzero(self.running & self.ticking)[0]
        if sel.size == 0:
            return                               # all chains dead
        self._dispatch(self._refresh(sel))
        self._switch_step(sel)
        self.ticks_run += 1
        self.sim.after(self.probe_period, self._probe_tick)

    def _aux_tick(self, users: List[int]):
        """Extra per-cohort probe chain (scalar grows one whenever a user
        re-enters initial selection after total candidate loss)."""
        alive = [u for u in users if self.running[u]]
        if not alive:
            return
        sel = np.asarray(alive, np.int64)
        self._dispatch(self._refresh(sel))
        self._switch_step(sel)
        self.sim.after(self.probe_period, self._aux_tick, alive)

    def _switch_step(self, sel: np.ndarray):
        """One vectorized two-round switch update for ``sel``."""
        sel = sel[self.running[sel]]
        if sel.size == 0:
            return
        cand = self.cand_task[sel]
        safe = np.where(cand >= 0, cand, 0)
        cand_node = np.where(cand >= 0, self.task_node[safe], -1)
        cand_ema = self.ema_tab.get_matrix(sel, cand_node)
        act = self.active[sel]
        act_node = np.where(act >= 0, self.task_node[
            np.where(act >= 0, act, 0)], -1)
        act_ema = np.where(act >= 0, self.ema_tab.get(sel, act_node), np.nan)
        pend = self.pending[sel]
        pend_safe = np.where(pend >= 0, pend, 0)
        pend_node = np.where(pend >= 0, self.task_node[pend_safe], -1)
        pend_ema = np.where(pend >= 0, self.ema_tab.get(sel, pend_node),
                            np.nan)
        pend_alive = (pend >= 0) & self._view().alive_mask()[pend_safe]
        confirm, target, new_pending = switch_decide(
            cand, cand_ema, act, act_ema, pend, pend_ema, pend_alive,
            self.switch_margin)
        self.pending[sel] = new_pending
        if confirm.any():
            rows = np.nonzero(confirm)[0]
            users = sel[rows]
            to_task = target[rows]
            now = self.sim.now
            for u, frm, to in zip(users, act_node[rows],
                                  self.task_node[to_task]):
                self.switch_t.append(now)
                self.switch_user.append(int(u))
                self.switch_from.append(self._node_ids[frm])
                self.switch_to.append(self._node_ids[to])
            self.active[users] = to_task

    def _on_response_ev(self, req: Request):
        u = req.user_ix
        if not self.running[u]:
            return
        ms = self.sim.now - req.sent_at
        nix = self._node_of[req.node_id]
        row = np.array([u])
        self.ema_tab.fold(row, np.array([nix]), np.array([ms]), self.alpha)
        if self.record_samples:
            self.sample_u.append(u)
            self.sample_t.append(self.sim.now)
            self.sample_ms.append(ms)
            self.sample_node.append(nix)
            self.sample_probe.append(req.is_probe)
        if req.is_probe:
            return
        self.frame_count[u] += 1
        self.frame_sum[u] += ms
        if self.frame_interval > 0:
            self.sim.after(self.frame_interval, self._send_frame_ev, u)
        else:
            self._send_frame_ev(u)

    def _send_frame_ev(self, u: int):
        if not self.running[u] or self.active[u] < 0:
            return
        t = int(self.active[u])
        # _last_view is safe here without a fingerprint re-check: task
        # lists only append, so position t keeps naming the same Task the
        # active index was assigned from (scalar clients likewise hold the
        # Task object itself) — keeps the per-frame path O(1)
        view = self._last_view
        cap = view.tasks[t].captain
        if cap is None or not cap.alive:
            return
        rtt = self.sim.jitter(
            float(self._base_rtts(np.array([u]), np.array([t]))[0]), 0.08)
        req = Request(client=self, task_id=view.tasks[t].task_id,
                      sent_at=self.sim.now, rtt=rtt, node_id=cap.node_id,
                      proc_scale=self.workload_scale, is_probe=False,
                      on_done=self._on_response_ev, user_ix=u)
        self.sim.at(self.sim.now + rtt / 2, cap.arrive, req)
        self.requests_sent += 1

    # ---------------------------------------------------------- failover

    def on_connection_break(self, node_id: str):
        """A node with warm connections failed.  One notification covers
        the whole pool; users are replayed in warm-connection insertion
        order — the order U scalar clients would have been notified in."""
        nix = self._node_of.get(node_id)
        if nix is None:
            return
        if self._rt is not None:
            # dirty-mark every user whose candidate set or active replica
            # touched the dead node.  Computed on the host mirrors on
            # every path (the device mirrors are post-last-tick state), so
            # the mark set is identical host == device — a superset of the
            # fused program's own death hit, never a miss
            safe_c = np.where(self.cand_task >= 0, self.cand_task, 0)
            c_hit = (self.cand_task >= 0) & (self.task_node[safe_c] == nix)
            safe_a = np.where(self.active >= 0, self.active, 0)
            a_hit = (self.active >= 0) & (self.task_node[safe_a] == nix)
            self._rt.mark(self.running & (c_hit.any(axis=1) | a_hit))
        if self._dev is not None:
            # device tick: queue the break; the fused program replays the
            # queue in arrival order at the next tick (or flush), which
            # is when the fluid data plane next acts anyway
            self._watched.discard(nix)
            self._dev.on_break(nix)
            return
        if self.transport == "events":
            order = [u for u in self._conn.pop(nix, {}) if self.running[u]]
        else:
            self._watched.discard(nix)
            cand_hit = (self.cand_task >= 0) & (
                self.task_node[np.where(self.cand_task >= 0,
                                        self.cand_task, 0)] == nix)
            act = self.active
            act_hit = (act >= 0) & (self.task_node[
                np.where(act >= 0, act, 0)] == nix)
            order = np.nonzero(self.running & (cand_hit.any(axis=1)
                                               | act_hit))[0].tolist()
        if not order:
            return
        rows = np.asarray(order, np.int64)
        self.ema_tab.pop(rows, nix)

        view = self._view()
        t_alive = view.alive_mask()
        cand = self.cand_task[rows]
        keep = (cand >= 0) & t_alive[np.where(cand >= 0, cand, 0)]
        # compact surviving candidates, preserving rank order
        self.cand_task[rows] = compact_rows(cand, keep)

        act = self.active[rows]
        act_dead = (act < 0) | ~t_alive[np.where(act >= 0, act, 0)]
        if not act_dead.any():
            return
        m = self.modes[rows]
        is_rec = act_dead & (m == MODE_RECONNECT)
        is_e2c = act_dead & (m == MODE_EDGE2CLOUD)
        cloud_task = self._first_cloud_task(view) if is_e2c.any() else -1
        if cloud_task < 0:
            is_e2c[:] = False                     # fall through to armada
        is_arm = act_dead & ~is_rec & ~is_e2c

        now = self.sim.now
        # reconnect baseline: drop, wait, re-query (Fig 10a)
        if is_rec.any():
            rec = rows[is_rec]
            self.active[rec] = -1
            self.downtime_until[rec] = now + RECONNECT_DELAY_MS
            self.sim.after(RECONNECT_DELAY_MS, self._reconnect_batch,
                           rec.tolist())
        # edge-to-cloud baseline: jump onto the cloud replica (Fig 10b)
        if is_e2c.any():
            e2c = rows[is_e2c]
            self.active[e2c] = cloud_task
            self.ema_tab.ensure(e2c, np.full(e2c.size, int(
                self.task_node[cloud_task])))
            self.failovers += int(is_e2c.sum())
        # armada: instant switch to the best remaining warm candidate
        arm_rows = rows[is_arm]
        arm_frame = np.full(arm_rows.size, -1, np.int64)
        arm_empty: List[int] = []
        if arm_rows.size:
            cand = self.cand_task[arm_rows]
            safe = np.where(cand >= 0, cand, 0)
            cand_node = np.where(cand >= 0, self.task_node[safe], -1)
            slot = failover_pick(cand, self.ema_tab.get_matrix(arm_rows,
                                                               cand_node))
            has = slot >= 0
            picked = cand[np.arange(arm_rows.size), np.where(has, slot, 0)]
            self.active[arm_rows[has]] = picked[has]
            arm_frame[has] = picked[has]
            arm_empty = arm_rows[~has].tolist()
            self.failovers += int(has.sum())

        if self.transport == "fluid":
            # fluid data plane resumes at the next tick; re-run initial
            # selection then for users who lost every candidate
            if arm_empty:
                self.sim.after(0.0, self._retry_fluid, arm_empty)
            return

        # events: replay frame sends / re-initialization in user order
        empties = set(arm_empty)
        empty_plan: Dict[int, Tuple] = {}
        if empties:
            esel = np.asarray(sorted(empties, key=order.index), np.int64)
            # _refresh(initial) marks users as main-chain members; restore —
            # scalar users keep whatever chains they had and gain one NEW
            # chain (the aux cohort below), phase-locked to this break
            was_ticking = self.ticking[esel].copy()
            sub = self._refresh(esel, initial=True)
            self.ticking[esel] = was_ticking
            empty_plan = {p[0]: p for p in sub}
            revived = [p[0] for p in sub]
            if revived:
                self.sim.after(self.probe_period, self._aux_tick, revived)
        arm_set = {int(u): f for u, f in zip(arm_rows, arm_frame)}
        e2c_set = set(rows[is_e2c].tolist())
        plan: List[Tuple] = []
        for u in order:
            if u in e2c_set:
                self._conn_add(u, cloud_task)
                plan.append((u, [], cloud_task))
            elif u in empties:
                if u in empty_plan:
                    plan.append(empty_plan[u])
            elif u in arm_set and arm_set[u] >= 0:
                plan.append((u, [], int(arm_set[u])))
        self._dispatch(plan)

    def _first_cloud_task(self, view) -> int:
        for i, t in enumerate(view.tasks):
            if (t.status == "running" and t.captain is not None
                    and view.cloud[i]):
                return i
        return -1

    def _reconnect_batch(self, users: List[int]):
        sel = np.asarray(users, np.int64)
        if self.transport == "events":
            self._dispatch(self._refresh(sel, activate_first=True))
        else:
            sel = sel[self.running[sel]]
            if sel.size:
                self._refresh(sel, activate_first=True)

    # -------------------------------------------------- fluid-mode driving

    def _start_fluid(self, sel: np.ndarray):
        self._refresh(sel, initial=True)
        self._tick_fluid(first=True)

    def _tick_fluid(self, first: bool = False):
        now = self.sim.now
        t0 = time.perf_counter()
        self._flush_fluid()
        self.phase_add("policy", t0)
        sel = np.nonzero(self.running & self.ticking)[0]
        if sel.size:
            if not first:
                if self._rt is not None:
                    t0 = time.perf_counter()
                    dirty = self._rt.dirty_mask(now)
                    self.phase_add("refresh_track", t0)
                else:
                    dirty = None
                t0 = time.perf_counter()
                r_ok = self._discovery_refresh_mask()
                r_sel = sel if r_ok is None else sel[r_ok[sel]]
                if dirty is not None:
                    # incremental: refresh only the dirty subset (the
                    # discovery gate above composes by AND — a deferred
                    # user stays marked and refreshes when it opens)
                    r_sel = r_sel[dirty[r_sel]]
                    self._rt.dirty_counts.append(int(r_sel.size))
                if r_sel.size:
                    self._refresh(r_sel)
                    if dirty is not None:
                        self._rt.note_refreshed(r_sel, now)
                self.phase_add("selection", t0)
            t0 = time.perf_counter()
            self._switch_step(sel)
            self.phase_add("policy", t0)
            t0 = time.perf_counter()
            self._traffic_fluid(sel, now)
            self.phase_add("transport", t0)
            self.ticks_run += 1
        if (self.running & self.ticking).any():
            self.sim.after(self.probe_period, self._tick_fluid)

    def _traffic_fluid(self, sel: np.ndarray, now: float):
        """One window of probe + frame traffic, aggregated per node through
        ``Captain.arrive_batch``'s fluid queue model."""
        view = self._last_view
        window = self.probe_period
        t_alive = view.alive_mask()

        cand = self.cand_task[sel]
        ok = (cand >= 0) & t_alive[np.where(cand >= 0, cand, 0)]
        p_rows, p_cols = np.nonzero(ok)
        p_users = sel[p_rows]
        p_tasks = cand[p_rows, p_cols]
        p_tau = np.zeros(p_users.size)

        act = self.active[sel]
        f_ok = (act >= 0) & t_alive[np.where(act >= 0, act, 0)] \
            & (self.frame_interval > 0)
        n_f = int(window // self.frame_interval) \
            if self.frame_interval > 0 else 0
        f_sel = sel[f_ok]
        f_act = act[f_ok]
        f_users = np.repeat(f_sel, n_f)
        f_tasks = np.repeat(f_act, n_f)
        f_tau = np.tile((np.arange(n_f) + 0.5) * self.frame_interval,
                        f_sel.size)

        users = np.concatenate([p_users, f_users])
        tasks = np.concatenate([p_tasks, f_tasks]).astype(np.int64)
        taus = np.concatenate([p_tau, f_tau])
        if users.size == 0:
            return
        nodes = self.task_node[tasks]

        # per-node fluid admission (one arrive_batch per node with traffic)
        counts = np.bincount(nodes, minlength=len(self._node_ids))
        work0 = np.zeros(len(self._node_ids))
        net_rate = np.zeros(len(self._node_ids))
        slots = np.ones(len(self._node_ids))
        proc = np.zeros(len(self._node_ids))
        for nix in np.nonzero(counts)[0]:
            cap = self._node_caps[nix]
            w0, in_rate, cap_rate = cap.arrive_batch(
                int(counts[nix]), self.workload_scale, window, now)
            work0[nix] = w0
            net_rate[nix] = in_rate - cap_rate
            slots[nix] = max(cap.spec.slots, 1)
            proc[nix] = cap.request_ms()    # serving-profile unit time

        wait = np.maximum(0.0, work0[nodes] + net_rate[nodes] * taus) \
            / slots[nodes]
        rtt = self.sim.jitter_batch(self._base_rtts(users, tasks), 0.08)
        proc_ms = self.sim.jitter_batch(
            proc[nodes] * self.workload_scale, 0.06)
        back = self.sim.jitter_batch(rtt / 2, 0.08)
        lat = rtt / 2 + wait + np.maximum(proc_ms, 0.1) + back
        data = self._data_node_ms()
        if data is not None:
            # in-situ data access rides the frame (request) path only —
            # probes stay pure network/queue measurements
            f_nodes = nodes[p_users.size:]
            lat[p_users.size:] += data[f_nodes]
            self._charge_reads(f_nodes, window)
        self.requests_sent += users.size

        is_probe = np.zeros(users.size, bool)
        is_probe[:p_users.size] = True
        rounds = f_tau_index(p_users.size, f_sel.size, n_f)
        self._fluid_buf.append((users, nodes, lat, is_probe, rounds))

    def _flush_fluid(self):
        """Fold the previous window's responses into the EMA table in
        vectorized arrival-order rounds: probes first, then frame k for
        every user (k = 1..n_f) — each round touches unique (user, node)
        pairs, so one ``fold`` per round reproduces sequential EMA
        semantics exactly."""
        if not self._fluid_buf:
            return
        for users, nodes, lat, is_probe, rounds in self._fluid_buf:
            pr = is_probe
            # two replicas co-located on one captain give a user two probes
            # to the SAME node — split those into occurrence-rank rounds so
            # fold() never sees a duplicate (user, node) pair
            p_rank = _dup_rank(users[pr].astype(np.int64)
                               * len(self._node_ids) + nodes[pr])
            for k in range(int(p_rank.max()) + 1 if p_rank.size else 0):
                m = p_rank == k
                self.ema_tab.fold(users[pr][m], nodes[pr][m], lat[pr][m],
                                  self.alpha)
            fr = ~pr
            if fr.any():
                f_users, f_nodes, f_lat = users[fr], nodes[fr], lat[fr]
                f_round = rounds[fr]
                for k in range(int(f_round.max()) + 1):
                    m = f_round == k
                    self.ema_tab.fold(f_users[m], f_nodes[m], f_lat[m],
                                      self.alpha)
                np.add.at(self.frame_count, f_users, 1)
                np.add.at(self.frame_sum, f_users, f_lat)
                if self._lat_hist is not None:
                    self._lat_hist += np.histogram(
                        f_lat, bins=self._lat_edges)[0]
        self._fluid_buf.clear()

    def _retry_fluid(self, users: List[int]):
        sel = np.asarray(users, np.int64)
        self._refresh(sel, initial=True)

    # --------------------------------------------- in-situ data plane (fluid)

    def _data_node_ms(self) -> Optional[np.ndarray]:
        """(n_nodes,) per-NODE Cargo access latency for this window, or
        None when the pool has no ``data_profile`` (or the service no
        alive placement).  Computed host-side once per tick from each
        node's nearest alive replica + measured read EMA
        (``CargoManager.data_ms_for_nodes``) and gathered per user by
        active node — the same single-injection idiom as the queueing
        fold, so host, geo_topk, device, and mesh ticks consume
        identical values by construction."""
        if self.data_profile is None:
            return None
        cm = getattr(self.am, "cargo_manager", None)
        if cm is None or not self._node_ids:
            return None
        n = len(self._node_ids)
        lats = np.zeros(n)
        lons = np.zeros(n)
        has_loc = np.zeros(n, bool)
        for i, cap in enumerate(self._node_caps):
            if cap is not None:
                lats[i], lons[i] = cap.spec.loc
                has_loc[i] = True
        out = cm.data_ms_for_nodes(self.service_id, self.data_profile,
                                   lats, lons)
        if out is None:
            self._data_reps = None
            return None
        ms, nearest, reps = out
        self._data_reps = (nearest, reps)
        # nodes without a captain handle never serve frames; zero them so
        # a stray gather can't inject a garbage latency
        return np.where(has_loc, ms, 0.0)

    def _charge_reads(self, f_nodes: np.ndarray, window: float):
        """Report this window's aggregated frame reads to the Cargo
        Manager: each frame charges ``reads_per_request`` reads to the
        nearest replica of its serving node (the read-throughput signal
        behind hot-store auto-scaling)."""
        reads = float(self.data_profile.reads_per_request)
        if self._data_reps is None or reads <= 0 or f_nodes.size == 0:
            return
        nearest, reps = self._data_reps
        counts = np.bincount(nearest[f_nodes], minlength=len(reps)) * reads
        self.am.cargo_manager.note_read_load(self.service_id, reps,
                                             counts, window)

    def _user_codes(self) -> np.ndarray:
        """Full-precision Morton codes of the user locations (cached) —
        shared by the discovery gate and the refresh tracker's routing."""
        if self._disc_codes is None:
            from repro.core.selection import CODE_PRECISION
            self._disc_codes = geohash.encode_batch(
                self.locs[:, 0], self.locs[:, 1], CODE_PRECISION)
        return self._disc_codes

    def _discovery_refresh_mask(self) -> Optional[np.ndarray]:
        """(U,) bool gate for the candidate refresh, or None when Beacon
        discovery is free (``engine.discovery_ms == 0``).  A user whose
        serving region changed (Beacon handoff / re-home, detected via
        ``owner_version``) must re-discover its Beacon first: candidate
        refreshes are suppressed until ``now + discovery_ms`` while
        probes and frames keep flowing to the stale candidates — the
        same gate feeds both the host tick and the fused device tick."""
        eng = self.am.engine
        dms = float(getattr(eng, "discovery_ms", 0.0))
        if dms <= 0:
            return None
        if eng.owner_version != self._disc_owner_version:
            view = eng.shard_view(self.service_id,
                                  self.am.tasks.get(self.service_id, ()))
            if view is not None:
                route = view.route(self._user_codes())
                if self._disc_route is not None:
                    changed = route != self._disc_route
                    if changed.any():
                        if self._disc_until is None:
                            self._disc_until = np.zeros(self.n_users)
                        self._disc_until[changed] = self.sim.now + dms
                self._disc_route = route
            self._disc_owner_version = eng.owner_version
        if self._disc_until is None or \
                not (self._disc_until > self.sim.now).any():
            return None
        return self._disc_until <= self.sim.now

    # ------------------------------------------------------------- metrics

    def reset_stats(self):
        """Zero the aggregate frame stats (and the latency histogram when
        enabled) — call at a measurement-window start on aggregate-only
        (fluid / record_samples=False) pools.  bench_serving_selection
        resets at flash-crowd end so tail quantiles describe the
        recovery phase selection actually controls, not the flash whose
        pile-up predates any load signal."""
        self._flush_fluid()                 # open window belongs to the past
        if self._dev is not None:
            self._dev.reset_aggregates()
        self.frame_count[:] = 0
        self.frame_sum[:] = 0.0
        if self._lat_hist is not None:
            self._lat_hist[:] = 0

    def active_locs(self) -> np.ndarray:
        """(k, 2) locations of running users (ApplicationManager's
        autoscale user-grouping protocol)."""
        return self.locs[self.running]

    def data_local_fraction(self, users=None) -> float:
        """Fraction of the given users (default: all) whose ACTIVE
        replica sits within ``DATA_LOCAL_RADIUS_KM`` of one of the
        service's Cargo replicas — the in-situ-data-access success rate
        (paper §3.4).  nan when the service has no data-locality entry
        in the engine or none of the users is active."""
        entry = self.am.engine.data_locality.get(self.service_id)
        if entry is None:
            return float("nan")
        locs, _ = entry
        view = self._view()
        bits = view.locality_bits(locs)
        act = self.active if users is None \
            else self.active[np.asarray(users, np.int64)]
        ok = act >= 0
        if not ok.any():
            return float("nan")
        return float(bits[act[ok]].mean())

    @property
    def dirty_counts(self) -> Optional[List[int]]:
        """Per-tick refreshed-user counts under incremental refresh
        (``None`` when ``refresh_period_ms`` is unset)."""
        return self._rt.dirty_counts if self._rt is not None else None

    def active_node(self, u: int) -> Optional[str]:
        t = int(self.active[u])
        if t < 0:
            return None
        return self._last_view.node_ids[t] if self._last_view else None

    def ema_of(self, u: int) -> Dict[str, float]:
        if self._dev is not None:
            return self._dev.ema_dict(u)
        if self.transport == "fluid":
            self._flush_fluid()         # match device-tick flush semantics
        return self.ema_tab.as_dict(u, self._node_ids)

    def samples_of(self, u: int) -> List[LatencySample]:
        return [LatencySample(t, ms, self._node_ids[n], p)
                for uu, t, ms, n, p in zip(
                    self.sample_u, self.sample_t, self.sample_ms,
                    self.sample_node, self.sample_probe) if uu == u]

    def switches_of(self, u: int) -> List[dict]:
        return [{"t": t, "from": f, "to": to}
                for t, uu, f, to in zip(self.switch_t, self.switch_user,
                                        self.switch_from, self.switch_to)
                if uu == u]

    def mean_latency(self, u: Optional[int] = None,
                     since: float = 0.0) -> float:
        if self.transport == "fluid" or not self.record_samples:
            self._flush_fluid()             # include the open window
            if self._dev is not None:
                self._dev.sync_aggregates()
            if since > 0.0:
                raise ValueError(
                    "mean_latency(since=...) needs per-sample records — "
                    "aggregate-only pools track whole-run means (call "
                    "reset_stats() at the window start instead)")
            if u is None:
                tot = self.frame_count.sum()
                return float(self.frame_sum.sum() / tot) if tot else \
                    float("nan")
            c = self.frame_count[u]
            return float(self.frame_sum[u] / c) if c else float("nan")
        us = np.asarray(self.sample_u)
        if us.size == 0:
            return float("nan")
        ts = np.asarray(self.sample_t)
        ms = np.asarray(self.sample_ms)
        pr = np.asarray(self.sample_probe)
        m = ~pr & (ts >= since)
        if u is not None:
            m &= us == u
        return float(ms[m].mean()) if m.any() else float("nan")

    def _hist_sync(self) -> np.ndarray:
        if self._lat_hist is None:
            raise ValueError("pool was built without latency_hist=True")
        self._flush_fluid()
        if self._dev is not None:
            self._dev.flush()
        return self._lat_hist

    def latency_quantile(self, q: float) -> float:
        """Approximate frame-latency quantile (e.g. ``q=0.99`` for p99)
        from the log-spaced histogram — the upper edge of the bin the
        quantile falls in (≤5% bin width).  Needs ``latency_hist=True``."""
        hist = self._hist_sync()
        cum = np.cumsum(hist)
        if cum[-1] == 0:
            return float("nan")
        i = int(np.searchsorted(cum, q * cum[-1]))
        return float(self._lat_edges[min(i + 1, self._lat_edges.size - 2)])

    def slo_violation_fraction(self, slo_ms: float) -> float:
        """Fraction of frame responses whose latency exceeded ``slo_ms``
        (counted over bins whose lower edge is ≥ ``slo_ms`` — snap the
        SLO to a bin edge for exact accounting)."""
        hist = self._hist_sync()
        tot = hist.sum()
        if tot == 0:
            return float("nan")
        bad = hist[self._lat_edges[:-1] >= slo_ms].sum()
        return float(bad / tot)


def _dup_rank(keys: np.ndarray) -> np.ndarray:
    """Occurrence index of each element among equal keys, preserving
    input order (0 for the first occurrence, 1 for the second, ...)."""
    if keys.size == 0:
        return keys
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    new_grp = np.empty(keys.size, bool)
    new_grp[0] = True
    new_grp[1:] = sorted_keys[1:] != sorted_keys[:-1]
    pos = np.arange(keys.size)
    starts = np.maximum.accumulate(np.where(new_grp, pos, 0))
    rank = np.empty(keys.size, np.int64)
    rank[order] = pos - starts
    return rank


def f_tau_index(n_probes: int, n_frame_users: int, n_f: int) -> np.ndarray:
    """Frame-round indices aligned with ``_traffic_fluid``'s request
    layout: after ``n_probes`` probe entries, frames are laid out user-major
    (user0 frame0..k, user1 frame0..k, ...)."""
    return np.concatenate([np.zeros(n_probes, np.int64),
                           np.tile(np.arange(n_f), n_frame_users)])
