"""Discrete-event simulator: virtual clock, event heap, seeded RNG.

The paper's experiments run 5-15 users against 3-7 nodes for minutes of
wall time; the simulator reproduces them in milliseconds, deterministically.
Latencies are virtual; the *compute* latencies are calibrated against real
jitted step times of the service models (benchmarks/bench_heterogeneity.py).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    args: tuple = field(compare=False, default=())
    cancelled: bool = field(compare=False, default=False)


class Simulator:
    def __init__(self, seed: int = 0, trace_enabled: bool = True):
        self.now = 0.0
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.rng = np.random.default_rng(seed)
        # large-scale runs (100k+ users) disable tracing so the trace list
        # doesn't grow without bound; benchmarks keep the default
        self.trace_enabled = trace_enabled
        self.trace: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- events

    def at(self, t: float, fn: Callable, *args) -> _Event:
        assert t >= self.now - 1e-9, (t, self.now)
        ev = _Event(t, next(self._seq), fn, args)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, dt: float, fn: Callable, *args) -> _Event:
        return self.at(self.now + dt, fn, *args)

    def cancel(self, ev: _Event):
        ev.cancelled = True

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        n = 0
        while self._heap and n < max_events:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            ev.fn(*ev.args)
            n += 1
        if until is not None:
            self.now = max(self.now, until)
        return n

    # -------------------------------------------------------------- trace

    def log(self, kind: str, **kw):
        if self.trace_enabled:
            self.trace.append({"t": self.now, "kind": kind, **kw})

    def jitter(self, base: float, frac: float = 0.1) -> float:
        """Multiplicative noise around ``base`` (deterministic via rng)."""
        return float(base * (1.0 + frac * self.rng.standard_normal()))
