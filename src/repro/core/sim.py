"""Discrete-event simulator: virtual clock, event heap, seeded RNG.

The paper's experiments run 5-15 users against 3-7 nodes for minutes of
wall time; the simulator reproduces them in milliseconds, deterministically.
Latencies are virtual; the *compute* latencies are calibrated against real
jitted step times of the service models (benchmarks/bench_heterogeneity.py).
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    args: tuple = field(compare=False, default=())
    cancelled: bool = field(compare=False, default=False)


class Simulator:
    def __init__(self, seed: int = 0, trace_enabled: bool = True):
        self.now = 0.0
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._substreams: Dict[str, np.random.Generator] = {}
        # large-scale runs (100k+ users) disable tracing so the trace list
        # doesn't grow without bound; benchmarks keep the default
        self.trace_enabled = trace_enabled
        self.trace: List[Dict[str, Any]] = []
        self.truncated = False          # last run() hit max_events

    # ------------------------------------------------------------- events

    def at(self, t: float, fn: Callable, *args) -> _Event:
        assert t >= self.now - 1e-9, (t, self.now)
        ev = _Event(t, next(self._seq), fn, args)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, dt: float, fn: Callable, *args) -> _Event:
        return self.at(self.now + dt, fn, *args)

    def cancel(self, ev: _Event):
        ev.cancelled = True

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        """Drain the heap up to ``until``.  Returns the event count and sets
        ``self.truncated`` when the run stopped at ``max_events`` with work
        still pending — a capped run must not be mistaken for a converged
        one (benchmarks read the flag; a warning is also emitted)."""
        n = 0
        self.truncated = False
        while self._heap and n < max_events:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            ev.fn(*ev.args)
            n += 1
        if self._heap and n >= max_events and (
                until is None or self._heap[0].time <= until):
            self.truncated = True
            self.log("run_truncated", events=n,
                     pending=len(self._heap))
            warnings.warn(
                f"Simulator.run stopped at max_events={max_events} with "
                f"{len(self._heap)} events pending (t={self.now:.1f}) — "
                "results beyond this point are incomplete", RuntimeWarning,
                stacklevel=2)
        if until is not None:
            self.now = max(self.now, until)
        return n

    # -------------------------------------------------------------- trace

    def log(self, kind: str, **kw):
        if self.trace_enabled:
            self.trace.append({"t": self.now, "kind": kind, **kw})

    def jitter(self, base: float, frac: float = 0.1) -> float:
        """Multiplicative noise around ``base`` (deterministic via rng)."""
        return float(base * (1.0 + frac * self.rng.standard_normal()))

    def substream(self, name: str) -> np.random.Generator:
        """Named RNG stream forked deterministically from the seed.

        Control-plane injections (Beacon failures, heartbeat-replay
        stagger) draw here instead of ``self.rng`` so they never shift
        the data-plane jitter sequence — a run with an injected failure
        stays draw-for-draw comparable to the same run without it, and
        host/device tick runs that consume ``rng`` in pinned order stay
        in lockstep when failures are added."""
        gen = self._substreams.get(name)
        if gen is None:
            import zlib
            gen = np.random.default_rng(
                np.random.SeedSequence([self.seed & 0xFFFFFFFF,
                                        zlib.crc32(name.encode())]))
            self._substreams[name] = gen
        return gen

    def jitter_batch(self, base: np.ndarray, frac: float = 0.1) -> np.ndarray:
        """Vectorized ``jitter``: one draw per element, bit-identical to the
        same number of sequential ``jitter`` calls (numpy Generator fills
        arrays from the same bit stream), so batched senders stay on the
        scalar path's RNG sequence."""
        base = np.asarray(base, np.float64)
        return base * (1.0 + frac * self.rng.standard_normal(base.size)
                       .reshape(base.shape))
