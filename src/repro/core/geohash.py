"""GeoHash (paper §3.2, [14]): locality encoding for proximity search.

``geoProximitySearch`` uses *reduced precision* on purpose — the paper
widens the geographic cell so farther-but-faster nodes stay in the
candidate list in heterogeneous environments.
"""
from __future__ import annotations

import math
from typing import List, Tuple

_BASE32 = "0123456789bcdefghjkmnpqrstuvwxyz"
_DECODE = {c: i for i, c in enumerate(_BASE32)}


def encode(lat: float, lon: float, precision: int = 9) -> str:
    lat_lo, lat_hi = -90.0, 90.0
    lon_lo, lon_hi = -180.0, 180.0
    bits = []
    even = True
    while len(bits) < precision * 5:
        if even:
            mid = (lon_lo + lon_hi) / 2
            if lon >= mid:
                bits.append(1)
                lon_lo = mid
            else:
                bits.append(0)
                lon_hi = mid
        else:
            mid = (lat_lo + lat_hi) / 2
            if lat >= mid:
                bits.append(1)
                lat_lo = mid
            else:
                bits.append(0)
                lat_hi = mid
        even = not even
    chars = []
    for i in range(0, len(bits), 5):
        n = 0
        for b in bits[i:i + 5]:
            n = (n << 1) | b
        chars.append(_BASE32[n])
    return "".join(chars)


def decode(gh: str) -> Tuple[float, float, float, float]:
    """-> (lat, lon, lat_err, lon_err): cell center and half-sizes."""
    lat_lo, lat_hi = -90.0, 90.0
    lon_lo, lon_hi = -180.0, 180.0
    even = True
    for c in gh:
        n = _DECODE[c]
        for shift in range(4, -1, -1):
            bit = (n >> shift) & 1
            if even:
                mid = (lon_lo + lon_hi) / 2
                if bit:
                    lon_lo = mid
                else:
                    lon_hi = mid
            else:
                mid = (lat_lo + lat_hi) / 2
                if bit:
                    lat_lo = mid
                else:
                    lat_hi = mid
            even = not even
    return ((lat_lo + lat_hi) / 2, (lon_lo + lon_hi) / 2,
            (lat_hi - lat_lo) / 2, (lon_hi - lon_lo) / 2)


def common_prefix(a: str, b: str) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def distance_km(lat1, lon1, lat2, lon2) -> float:
    """Haversine."""
    r = 6371.0
    p1, p2 = math.radians(lat1), math.radians(lat2)
    dp = math.radians(lat2 - lat1)
    dl = math.radians(lon2 - lon1)
    a = (math.sin(dp / 2) ** 2
         + math.cos(p1) * math.cos(p2) * math.sin(dl / 2) ** 2)
    return 2 * r * math.asin(math.sqrt(a))


def proximity_search(origin: Tuple[float, float],
                     items: List[Tuple[str, Tuple[float, float]]],
                     precision: int = 4, min_hits: int = 4) -> List[str]:
    """IDs whose reduced-precision geohash cell matches the origin's.

    The precision is *reduced* until at least ``min_hits`` candidates are in
    the cell (paper: 'apply GeoHash with less precision ... so relatively
    far-away edge nodes will be evaluated in the same way as closer edge
    nodes to avoid excluding better-performing options')."""
    og = encode(*origin, precision=9)
    for p in range(precision, 0, -1):
        hits = [i for i, loc in items
                if common_prefix(encode(*loc, precision=9), og) >= p]
        if len(hits) >= min(min_hits, len(items)):
            return hits
    return [i for i, _ in items]
