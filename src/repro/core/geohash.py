"""GeoHash (paper §3.2, [14]): locality encoding for proximity search.

``geoProximitySearch`` uses *reduced precision* on purpose — the paper
widens the geographic cell so farther-but-faster nodes stay in the
candidate list in heterogeneous environments.

Two representations coexist:

* base32 strings (``encode``/``decode``) — the paper's wire format, kept
  for readability and the original scalar path;
* int64 Morton cell codes (``encode_batch``) — ``5 * precision`` bits of
  interleaved lon/lat, MSB-first, so "the first ``p`` base32 characters
  match" becomes ``(a ^ b) >> (5 * (precision - p)) == 0``.  All batch
  selection (SelectionEngine, autoscale region grouping, the geo_topk
  kernel) runs on these codes; no strings on the hot path.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import numpy as np

_BASE32 = "0123456789bcdefghjkmnpqrstuvwxyz"
_DECODE = {c: i for i, c in enumerate(_BASE32)}


def encode(lat: float, lon: float, precision: int = 9) -> str:
    lat_lo, lat_hi = -90.0, 90.0
    lon_lo, lon_hi = -180.0, 180.0
    bits = []
    even = True
    while len(bits) < precision * 5:
        if even:
            mid = (lon_lo + lon_hi) / 2
            if lon >= mid:
                bits.append(1)
                lon_lo = mid
            else:
                bits.append(0)
                lon_hi = mid
        else:
            mid = (lat_lo + lat_hi) / 2
            if lat >= mid:
                bits.append(1)
                lat_lo = mid
            else:
                bits.append(0)
                lat_hi = mid
        even = not even
    chars = []
    for i in range(0, len(bits), 5):
        n = 0
        for b in bits[i:i + 5]:
            n = (n << 1) | b
        chars.append(_BASE32[n])
    return "".join(chars)


def decode(gh: str) -> Tuple[float, float, float, float]:
    """-> (lat, lon, lat_err, lon_err): cell center and half-sizes."""
    lat_lo, lat_hi = -90.0, 90.0
    lon_lo, lon_hi = -180.0, 180.0
    even = True
    for c in gh:
        n = _DECODE[c]
        for shift in range(4, -1, -1):
            bit = (n >> shift) & 1
            if even:
                mid = (lon_lo + lon_hi) / 2
                if bit:
                    lon_lo = mid
                else:
                    lon_hi = mid
            else:
                mid = (lat_lo + lat_hi) / 2
                if bit:
                    lat_lo = mid
                else:
                    lat_hi = mid
            even = not even
    return ((lat_lo + lat_hi) / 2, (lon_lo + lon_hi) / 2,
            (lat_hi - lat_lo) / 2, (lon_hi - lon_lo) / 2)


def common_prefix(a: str, b: str) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def distance_km(lat1, lon1, lat2, lon2) -> float:
    """Haversine."""
    r = 6371.0
    p1, p2 = math.radians(lat1), math.radians(lat2)
    dp = math.radians(lat2 - lat1)
    dl = math.radians(lon2 - lon1)
    a = (math.sin(dp / 2) ** 2
         + math.cos(p1) * math.cos(p2) * math.sin(dl / 2) ** 2)
    return 2 * r * math.asin(math.sqrt(a))


# ---------------------------------------------------------------------------
# Vectorized primitives (int64 Morton cell codes)
# ---------------------------------------------------------------------------

_M1 = np.int64(0x5555555555555555)
_M2 = np.int64(0x3333333333333333)
_M4 = np.int64(0x0F0F0F0F0F0F0F0F)
_M8 = np.int64(0x00FF00FF00FF00FF)
_M16 = np.int64(0x0000FFFF0000FFFF)


def _part1by1(v: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of ``v`` so bit ``j`` lands at bit ``2j``."""
    v = (v | (v << 16)) & _M16
    v = (v | (v << 8)) & _M8
    v = (v | (v << 4)) & _M4
    v = (v | (v << 2)) & _M2
    v = (v | (v << 1)) & _M1
    return v


def _quantize(x: np.ndarray, lo: float, hi: float, bits: int) -> np.ndarray:
    q = np.floor((np.asarray(x, np.float64) - lo) / (hi - lo)
                 * float(1 << bits)).astype(np.int64)
    return np.clip(q, 0, (1 << bits) - 1)


def encode_batch(lats, lons, precision: int = 9) -> np.ndarray:
    """Morton cell codes for arrays of coordinates.

    Returns int64 codes of ``5 * precision`` bits (lon bit first, exactly
    the bit stream ``encode`` packs into base32).  Codes of equal precision
    are prefix-comparable: points share their first ``p`` geohash chars
    iff ``(a ^ b) >> (5 * (precision - p)) == 0``.
    """
    nbits = 5 * precision
    lon_bits = (nbits + 1) // 2
    lat_bits = nbits // 2
    lon_q = _quantize(lons, -180.0, 180.0, lon_bits)
    lat_q = _quantize(lats, -90.0, 90.0, lat_bits)
    # The bit stream starts with a lon bit; whether lon lands on even or
    # odd LSB offsets depends on the parity of the total bit count.
    if nbits % 2:
        return _part1by1(lon_q) | (_part1by1(lat_q) << np.int64(1))
    return (_part1by1(lon_q) << np.int64(1)) | _part1by1(lat_q)


def code_to_str(code: int, precision: int = 9) -> str:
    """Morton cell code -> base32 geohash string (``encode`` equivalent)."""
    chars = []
    for i in range(precision):
        shift = 5 * (precision - 1 - i)
        chars.append(_BASE32[(int(code) >> shift) & 0x1F])
    return "".join(chars)


def str_to_code(gh: str) -> int:
    """Base32 geohash string -> Morton cell code (int, 5*len(gh) bits)."""
    code = 0
    for c in gh:
        code = (code << 5) | _DECODE[c]
    return code


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Vectorized ``int.bit_length`` for non-negative int64 arrays."""
    x = np.asarray(x, np.int64)
    bl = np.zeros(x.shape, np.int64)
    nz = x > 0
    bl[nz] = np.floor(np.log2(x[nz].astype(np.float64))).astype(np.int64) + 1
    # guard libm rounding at exact powers of two
    bl = np.where((x >> np.clip(bl, 0, 63)) != 0, bl + 1, bl)
    too_big = (bl > 0) & ((x >> np.clip(bl - 1, 0, 63)) == 0)
    return np.where(too_big, bl - 1, bl)


def shared_prefix_chars(a, b, precision: int = 9) -> np.ndarray:
    """Broadcasted count of common leading base32 chars between code arrays.

    Parity target: ``common_prefix(encode(p1), encode(p2))`` for codes made
    by ``encode_batch(..., precision)``.
    """
    diff = np.bitwise_xor(np.asarray(a, np.int64), np.asarray(b, np.int64))
    return np.minimum(precision,
                      (5 * precision - _bit_length(diff)) // 5)


def distance_km_batch(lat1, lon1, lat2, lon2) -> np.ndarray:
    """Broadcasted haversine (same formula as ``distance_km``)."""
    r = 6371.0
    p1 = np.radians(np.asarray(lat1, np.float64))
    p2 = np.radians(np.asarray(lat2, np.float64))
    dp = np.radians(np.asarray(lat2, np.float64)
                    - np.asarray(lat1, np.float64))
    dl = np.radians(np.asarray(lon2, np.float64)
                    - np.asarray(lon1, np.float64))
    a = (np.sin(dp / 2) ** 2
         + np.cos(p1) * np.cos(p2) * np.sin(dl / 2) ** 2)
    return 2 * r * np.arcsin(np.sqrt(np.clip(a, 0.0, 1.0)))


def proximity_search(origin: Tuple[float, float],
                     items: List[Tuple[str, Tuple[float, float]]],
                     precision: int = 4, min_hits: int = 4) -> List[str]:
    """IDs whose reduced-precision geohash cell matches the origin's.

    The precision is *reduced* until at least ``min_hits`` candidates are in
    the cell (paper: 'apply GeoHash with less precision ... so relatively
    far-away edge nodes will be evaluated in the same way as closer edge
    nodes to avoid excluding better-performing options')."""
    og = encode(*origin, precision=9)
    for p in range(precision, 0, -1):
        hits = [i for i, loc in items
                if common_prefix(encode(*loc, precision=9), og) >= p]
        if len(hits) >= min(min_hits, len(items)):
            return hits
    return [i for i, _ in items]
