"""Cargo Manager (paper §3.4.1): storage registration, 2-step data-access-
point selection, and storage auto-scaling.

Store_Register allocates THREE data replicas near the service's expected
locations; Cargo_Discover hands a Captain a geo-ranked candidate list and
the Captain probes them (the same 2-step idea as service selection).  When
compute auto-scaling spawns replicas far from existing data, the manager
cascades a new data replica onto a nearby Cargo.

Data-locality feedback into selection (paper §3.4 in-situ data access):
whenever a service's replica placement changes — registration, storage
auto-scaling, a Cargo death, or a handoff re-placement — the manager
pushes the alive replica locations into the ``SelectionEngine``
(``set_data_locality``), so every tick path prefers compute nodes within
``DATA_LOCAL_RADIUS_KM`` of the service's store.  ``on_domain_handoff``
is the control-plane hook: when a Beacon partition or failure re-homes a
domain's users to an adopting region, the manager re-places a data
replica near that region so the handed-off users can land data-local.

Data plane (``DataProfile`` / ``data_ms_for_nodes``): a ``ClientPool``
built with a per-service data profile folds a per-user Cargo access term
into its request-latency model on every tick path.  The manager computes
the per-NODE cost — nearest-alive-replica hop (the synthetic RTT model
shared with the pool) + the replica's measured read EMA inflated by its
load, plus the write path's consistency cost (strong = synchronous
fan-out to the slowest peer) — and the pool gathers it per user by
active node.  The pool charges its aggregated per-window reads back
through ``note_read_load``; a replica whose read throughput crosses
``HOT_READ_RATE`` triggers storage auto-scaling the way hot Captains
trigger compute auto-scaling.

Capacity and in-flight bookkeeping: ``_rank_by_location`` filters on the
LIVE ``used_mb`` (kept current by ``Cargo._put``), in-flight copies are
tracked so concurrent handoffs can't double-place a replica, and a Cargo
whose stores outgrow its volume gets its largest multi-replica store
migrated off (``on_capacity_exceeded``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import geohash
from repro.core.cluster import Topology
from repro.core.selection import DATA_LOCAL_RADIUS_KM, W_DATA
from repro.core.sim import Simulator
from repro.core.storage.cargo import WRITE_MS, Cargo, record_mb

# reads/s on one replica before the manager splits the load onto a new
# replica nearby (storage auto-scaling's hot-store trigger)
HOT_READ_RATE = 200.0


@dataclass(frozen=True)
class DataProfile:
    """Per-request Cargo access of one service's serving path: how many
    reads/writes a request issues and under which consistency mode.
    Consumed by ``ClientPool(data_profile=...)`` through
    ``CargoManager.data_ms_for_nodes``."""
    reads_per_request: float = 1.0
    writes_per_request: float = 0.0
    consistency: str = "eventual"          # "strong" | "eventual"

    def __post_init__(self):
        if self.consistency not in ("strong", "eventual"):
            raise ValueError(
                f"unknown consistency {self.consistency!r}")


class CargoManager:
    def __init__(self, sim: Simulator, topo: Topology, *,
                 replicas: int = 3, top_n: int = 3,
                 locality_weight: float = W_DATA):
        self.sim = sim
        self.topo = topo
        self.replicas = replicas
        self.top_n = top_n
        self.locality_weight = locality_weight
        self.cargos: Dict[str, Cargo] = {}
        self.placements: Dict[str, List[Cargo]] = {}    # service -> replicas
        self.specs: Dict[str, object] = {}
        self.engine = None              # SelectionEngine (attach_engine)
        # in-flight bulk copies: service -> {target node_id: reason} —
        # consulted by placement so two concurrent handoffs (or a handoff
        # racing autoscale) can never double-place the same replica
        self._inflight: Dict[str, Dict[str, str]] = {}
        # Cargos with a capacity migration in flight (re-entry guard)
        self._evicting: set = set()

    # --------------------------------------------------------- registration

    def attach_engine(self, engine):
        """Wire the selection engine that receives data-locality pushes
        (done by ``ArmadaSystem``); replays current placements so a late
        attach is equivalent to an early one."""
        self.engine = engine
        for service_id in self.placements:
            self._push_locality(service_id)

    def _push_locality(self, service_id: str):
        """Publish the service's alive replica locations as a selection
        score preference (no-op until an engine is attached)."""
        if self.engine is None:
            return
        locs = tuple(sorted(
            (float(c.spec.loc[0]), float(c.spec.loc[1]))
            for c in self.placements.get(service_id, ()) if c.alive))
        self.engine.set_data_locality(service_id, locs,
                                      weight=self.locality_weight)

    def cargo_join(self, cargo: Cargo):
        self.cargos[cargo.node_id] = cargo
        cargo.capacity_cb = self.on_capacity_exceeded
        self.sim.log("cargo_join", node=cargo.node_id)

    def on_cargo_fail(self, cargo: Cargo):
        """A Cargo died: its replicas stop contributing data locality
        (``cargo_discover`` already skips dead nodes per call)."""
        for service_id, reps in self.placements.items():
            if any(c is cargo for c in reps):
                self._push_locality(service_id)

    def _rank_by_location(self, loc, need_mb: float,
                          exclude=()) -> List[Cargo]:
        ok = [c for c in self.cargos.values()
              if c.alive and c.node_id not in exclude
              and (c.capacity_mb - c.used_mb) >= need_mb]
        ok.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], loc[0], loc[1]))
        return ok

    def store_register(self, spec,
                       initial: Optional[Dict[str, bytes]] = None):
        """Allocate three replicas near the service's expected location."""
        loc = spec.locations[0] if spec.locations else (0.0, 0.0)
        ranked = self._rank_by_location(loc, spec.storage_capacity_mb)
        chosen = ranked[:self.replicas]
        for c in chosen:
            c.provision(spec.service_id, chosen, initial)
        self.placements[spec.service_id] = chosen
        self.specs[spec.service_id] = spec
        self.sim.log("store_register", service=spec.service_id,
                     cargos=[c.node_id for c in chosen])
        self._push_locality(spec.service_id)
        return chosen

    # ------------------------------------------------------------ discovery

    def cargo_discover(self, service_id: str, captain_loc) -> List[Cargo]:
        """Step 1: candidate list of data access points for a Captain."""
        reps = [c for c in self.placements.get(service_id, ())
                if c.alive]
        reps.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], captain_loc[0], captain_loc[1]))
        return reps[:self.top_n]

    # ------------------------------------------------------------ data plane

    def data_ms_for_nodes(self, service_id: str, profile: DataProfile,
                          lats: np.ndarray, lons: np.ndarray):
        """Vectorized per-node Cargo access cost for the pool's request-
        latency fold: for each compute-node location, the nearest alive
        replica's hop (same synthetic last-mile + distance RTT model the
        pool uses for users) plus its load-inflated measured read EMA,
        and the write path's consistency cost.

        Returns ``(ms, nearest, reps)`` — ``ms`` (N,) float per node,
        ``nearest`` (N,) index into ``reps`` (the alive replica each
        node would read from, for read-load charging) — or ``None`` when
        the service has no alive placement."""
        from repro.core.client_pool import (RTT_CLOUD_PENALTY_MS,
                                            RTT_LAST_MILE_MS, RTT_MS_PER_KM)
        reps = [c for c in self.placements.get(service_id, ()) if c.alive]
        if not reps:
            return None
        r_lat = np.asarray([c.spec.loc[0] for c in reps])
        r_lon = np.asarray([c.spec.loc[1] for c in reps])
        r_cloud = np.asarray([bool(c.spec.is_cloud) for c in reps])
        d = geohash.distance_km_batch(
            np.asarray(lats)[:, None], np.asarray(lons)[:, None],
            r_lat[None, :], r_lon[None, :])
        hop = RTT_LAST_MILE_MS + RTT_MS_PER_KM * d \
            + np.where(r_cloud[None, :], RTT_CLOUD_PENALTY_MS, 0.0)
        nearest = np.argmin(hop, axis=1)
        rtt = hop[np.arange(hop.shape[0]), nearest]
        read_ms = np.asarray([c.effective_read_ms() for c in reps])
        ms = profile.reads_per_request * (rtt + read_ms[nearest])
        if profile.writes_per_request > 0:
            sync = np.zeros(len(reps))
            if profile.consistency == "strong":
                # synchronous fan-out: the ack waits for the slowest peer
                for i, c in enumerate(reps):
                    sync[i] = max(
                        (self.topo.rtt(c.node_id, p.node_id) + WRITE_MS
                         for p in c.peers.get(service_id, ()) if p.alive),
                        default=0.0)
            ms = ms + profile.writes_per_request \
                * (rtt + WRITE_MS + sync[nearest])
        return ms, nearest, reps

    def note_read_load(self, service_id: str, reps: List[Cargo],
                       counts: np.ndarray, window_ms: float):
        """Charge one fluid window's aggregated reads (``counts`` aligned
        with ``reps``) and trigger hot-store auto-scaling when a replica's
        read throughput crosses ``HOT_READ_RATE``."""
        hot = None
        for c, n in zip(reps, counts):
            c.note_reads(float(n), window_ms)
            if c.read_rate > HOT_READ_RATE and \
                    (hot is None or c.read_rate > hot.read_rate):
                hot = c
        spec = self.specs.get(service_id)
        if hot is not None and spec is not None:
            # split the hot replica's read load: one more access point in
            # its locale (the hot replica itself doesn't count as "near")
            self._ensure_replica_near(spec, hot.spec.loc, "hot-read",
                                      split_from=hot)

    # --------------------------------------------------------- auto-scaling

    def _ensure_replica_near(self, spec, loc, reason: str, *,
                             split_from: Optional[Cargo] = None) -> bool:
        """Place one more data replica near ``loc`` unless an alive
        replica — or an in-flight copy — is already within
        ``DATA_LOCAL_RADIUS_KM``.  The copy is asynchronous
        (bulk-transfer model); locality re-publishes when it lands.
        ``split_from`` (hot-store scaling) exempts the overloaded
        replica from the nearby check so its locale gains a second
        access point.  Returns True when a copy was started."""
        service_id = spec.service_id
        reps = self.placements.get(service_id, [])
        if not reps:
            return False
        inflight = self._inflight.setdefault(service_id, {})
        near = [c for c in reps if c.alive and c is not split_from] \
            + [self.cargos[nid] for nid in inflight if nid in self.cargos]
        nearest = min(
            (geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                 loc[0], loc[1]) for c in near),
            default=float("inf"))
        if nearest <= DATA_LOCAL_RADIUS_KM:      # close enough / in flight
            return False
        ranked = self._rank_by_location(
            loc, spec.storage_capacity_mb,
            exclude=[c.node_id for c in reps] + list(inflight))
        if not ranked:
            return False
        new = ranked[0]
        src = next((c for c in reps if c.alive), None)
        if src is None:
            # no alive source: refuse rather than fabricate recovered
            # data from a dead Cargo's in-memory store
            self.sim.log("storage_scale_failed", service=service_id,
                         node=new.node_id, reason="no-alive-source")
            return False
        inflight[new.node_id] = reason
        data = dict(src.stores.get(service_id, {}))
        hop = self.topo.rtt(src.node_id, new.node_id)
        xfer = len(data) * 1.0e-3 + hop          # bulk copy model

        def _done():
            self._inflight.get(service_id, {}).pop(new.node_id, None)
            group = self.placements.get(service_id, [])
            if any(c is new for c in group):     # raced a re-placement
                return
            if not new.alive:
                self.sim.log("storage_scale_failed", service=service_id,
                             node=new.node_id, reason="target-died")
                return
            group = group + [new]
            new.provision(service_id, group, data)
            for c in group:
                c.peers[service_id] = [p for p in group if p is not c]
            self.placements[service_id] = group
            self.sim.log("storage_scale", service=service_id,
                         node=new.node_id, reason=reason)
            self._push_locality(service_id)

        self.sim.after(xfer, _done)
        return True

    def on_new_task(self, spec, task):
        """Compute layer grew: ensure low-latency data access nearby."""
        self._ensure_replica_near(spec, task.captain.spec.loc, "autoscale")

    def on_domain_handoff(self, loc) -> int:
        """A Beacon handoff (partition or failure) re-homed a domain's
        users near ``loc`` (the adopting region's centroid): re-place a
        data replica for every registered store that has none nearby, so
        post-handoff requests can land data-local.  Returns the number of
        copies started."""
        return sum(self._ensure_replica_near(self.specs[sid], loc,
                                             "handoff")
                   for sid in sorted(self.placements))

    # ------------------------------------------------------------- capacity

    def on_capacity_exceeded(self, cargo: Cargo):
        """A write pushed ``cargo`` past its volume: migrate its largest
        store that has another alive replica onto a Cargo with room,
        then drop the local copy.  A store this Cargo holds the only
        alive copy of is never evicted (the overflow is logged and
        tolerated — dropping it would lose data)."""
        if cargo.node_id in self._evicting or not cargo.alive:
            return
        victim = None
        for sid, store in cargo.stores.items():
            others = [c for c in self.placements.get(sid, ())
                      if c.alive and c is not cargo]
            if not others:
                continue
            mb = sum(record_mb(k, v) for k, v in store.items())
            if victim is None or mb > victim[1]:
                victim = (sid, mb, others)
        if victim is None:
            self.sim.log("storage_evict_failed", node=cargo.node_id,
                         reason="sole-replica")
            return
        sid, mb, others = victim
        self._evicting.add(cargo.node_id)
        inflight = self._inflight.setdefault(sid, {})
        ranked = self._rank_by_location(
            cargo.spec.loc, mb,
            exclude=[c.node_id for c in self.placements.get(sid, ())]
            + list(inflight))
        src = others[0]
        if not ranked:
            # nowhere to migrate: shed the local copy anyway when at
            # least two other alive replicas keep the store redundant
            if len(others) >= 2:
                self._drop_replica(sid, cargo, reason="capacity")
            else:
                self.sim.log("storage_evict_failed", node=cargo.node_id,
                             reason="no-capacity")
            self._evicting.discard(cargo.node_id)
            return
        new = ranked[0]
        inflight[new.node_id] = "capacity"
        data = dict(src.stores.get(sid, {}))
        xfer = len(data) * 1.0e-3 + self.topo.rtt(src.node_id, new.node_id)

        def _done():
            self._inflight.get(sid, {}).pop(new.node_id, None)
            self._evicting.discard(cargo.node_id)
            group = [c for c in self.placements.get(sid, [])
                     if c is not cargo]
            if new.alive and not any(c is new for c in group):
                group = group + [new]
                new.provision(sid, group, data)
            self._drop_replica(sid, cargo, reason="capacity",
                               group=group)

        self.sim.after(xfer, _done)

    def _drop_replica(self, sid: str, cargo: Cargo, *, reason: str,
                      group: Optional[List[Cargo]] = None):
        """Remove ``cargo`` from a service's replica group (capacity
        eviction): drop the store, re-link peers, republish locality."""
        if group is None:
            group = [c for c in self.placements.get(sid, [])
                     if c is not cargo]
        cargo.drop_store(sid)
        for c in group:
            c.peers[sid] = [p for p in group if p is not c]
        self.placements[sid] = group
        self.sim.log("storage_evict", service=sid, node=cargo.node_id,
                     reason=reason)
        self._push_locality(sid)
