"""Cargo Manager (paper §3.4.1): storage registration, 2-step data-access-
point selection, and storage auto-scaling.

Store_Register allocates THREE data replicas near the service's expected
locations; Cargo_Discover hands a Captain a geo-ranked candidate list and
the Captain probes them (the same 2-step idea as service selection).  When
compute auto-scaling spawns replicas far from existing data, the manager
cascades a new data replica onto a nearby Cargo.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core import geohash
from repro.core.cluster import Topology
from repro.core.sim import Simulator
from repro.core.storage.cargo import Cargo


class CargoManager:
    def __init__(self, sim: Simulator, topo: Topology, *,
                 replicas: int = 3, top_n: int = 3):
        self.sim = sim
        self.topo = topo
        self.replicas = replicas
        self.top_n = top_n
        self.cargos: Dict[str, Cargo] = {}
        self.placements: Dict[str, List[Cargo]] = {}    # service -> replicas
        self.specs: Dict[str, object] = {}

    # --------------------------------------------------------- registration

    def cargo_join(self, cargo: Cargo):
        self.cargos[cargo.node_id] = cargo
        self.sim.log("cargo_join", node=cargo.node_id)

    def _rank_by_location(self, loc, need_mb: float,
                          exclude=()) -> List[Cargo]:
        ok = [c for c in self.cargos.values()
              if c.alive and c.node_id not in exclude
              and (c.spec.storage_gb * 1024 - c.used_mb) >= need_mb]
        ok.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], loc[0], loc[1]))
        return ok

    def store_register(self, spec,
                       initial: Optional[Dict[str, bytes]] = None):
        """Allocate three replicas near the service's expected location."""
        loc = spec.locations[0] if spec.locations else (0.0, 0.0)
        ranked = self._rank_by_location(loc, spec.storage_capacity_mb)
        chosen = ranked[:self.replicas]
        for c in chosen:
            c.provision(spec.service_id, chosen, initial)
        self.placements[spec.service_id] = chosen
        self.specs[spec.service_id] = spec
        self.sim.log("store_register", service=spec.service_id,
                     cargos=[c.node_id for c in chosen])
        return chosen

    # ------------------------------------------------------------ discovery

    def cargo_discover(self, service_id: str, captain_loc) -> List[Cargo]:
        """Step 1: candidate list of data access points for a Captain."""
        reps = [c for c in self.placements.get(service_id, ())
                if c.alive]
        reps.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], captain_loc[0], captain_loc[1]))
        return reps[:self.top_n]

    # --------------------------------------------------------- auto-scaling

    def on_new_task(self, spec, task):
        """Compute layer grew: ensure low-latency data access nearby."""
        service_id = spec.service_id
        reps = self.placements.get(service_id, [])
        if not reps:
            return
        cap_loc = task.captain.spec.loc
        nearest = min(
            (geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                 cap_loc[0], cap_loc[1])
             for c in reps if c.alive), default=float("inf"))
        if nearest <= 50.0:                      # close enough
            return
        ranked = self._rank_by_location(
            cap_loc, spec.storage_capacity_mb,
            exclude=[c.node_id for c in reps])
        if not ranked:
            return
        new = ranked[0]
        src = reps[0]
        data = dict(src.stores.get(service_id, {}))
        hop = self.topo.rtt(src.node_id, new.node_id)
        xfer = len(data) * 1.0e-3 + hop          # bulk copy model

        def _done():
            group = reps + [new]
            new.provision(service_id, group, data)
            for c in group:
                c.peers[service_id] = [p for p in group if p is not c]
            self.placements[service_id] = group
            self.sim.log("storage_scale", service=service_id,
                         node=new.node_id)

        self.sim.after(xfer, _done)
