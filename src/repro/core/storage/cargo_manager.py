"""Cargo Manager (paper §3.4.1): storage registration, 2-step data-access-
point selection, and storage auto-scaling.

Store_Register allocates THREE data replicas near the service's expected
locations; Cargo_Discover hands a Captain a geo-ranked candidate list and
the Captain probes them (the same 2-step idea as service selection).  When
compute auto-scaling spawns replicas far from existing data, the manager
cascades a new data replica onto a nearby Cargo.

Data-locality feedback into selection (paper §3.4 in-situ data access):
whenever a service's replica placement changes — registration, storage
auto-scaling, a Cargo death, or a handoff re-placement — the manager
pushes the alive replica locations into the ``SelectionEngine``
(``set_data_locality``), so every tick path prefers compute nodes within
``DATA_LOCAL_RADIUS_KM`` of the service's store.  ``on_domain_handoff``
is the control-plane hook: when a Beacon partition or failure re-homes a
domain's users to an adopting region, the manager re-places a data
replica near that region so the handed-off users can land data-local.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core import geohash
from repro.core.cluster import Topology
from repro.core.selection import DATA_LOCAL_RADIUS_KM, W_DATA
from repro.core.sim import Simulator
from repro.core.storage.cargo import Cargo


class CargoManager:
    def __init__(self, sim: Simulator, topo: Topology, *,
                 replicas: int = 3, top_n: int = 3,
                 locality_weight: float = W_DATA):
        self.sim = sim
        self.topo = topo
        self.replicas = replicas
        self.top_n = top_n
        self.locality_weight = locality_weight
        self.cargos: Dict[str, Cargo] = {}
        self.placements: Dict[str, List[Cargo]] = {}    # service -> replicas
        self.specs: Dict[str, object] = {}
        self.engine = None              # SelectionEngine (attach_engine)

    # --------------------------------------------------------- registration

    def attach_engine(self, engine):
        """Wire the selection engine that receives data-locality pushes
        (done by ``ArmadaSystem``); replays current placements so a late
        attach is equivalent to an early one."""
        self.engine = engine
        for service_id in self.placements:
            self._push_locality(service_id)

    def _push_locality(self, service_id: str):
        """Publish the service's alive replica locations as a selection
        score preference (no-op until an engine is attached)."""
        if self.engine is None:
            return
        locs = tuple(sorted(
            (float(c.spec.loc[0]), float(c.spec.loc[1]))
            for c in self.placements.get(service_id, ()) if c.alive))
        self.engine.set_data_locality(service_id, locs,
                                      weight=self.locality_weight)

    def cargo_join(self, cargo: Cargo):
        self.cargos[cargo.node_id] = cargo
        self.sim.log("cargo_join", node=cargo.node_id)

    def on_cargo_fail(self, cargo: Cargo):
        """A Cargo died: its replicas stop contributing data locality
        (``cargo_discover`` already skips dead nodes per call)."""
        for service_id, reps in self.placements.items():
            if any(c is cargo for c in reps):
                self._push_locality(service_id)

    def _rank_by_location(self, loc, need_mb: float,
                          exclude=()) -> List[Cargo]:
        ok = [c for c in self.cargos.values()
              if c.alive and c.node_id not in exclude
              and (c.spec.storage_gb * 1024 - c.used_mb) >= need_mb]
        ok.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], loc[0], loc[1]))
        return ok

    def store_register(self, spec,
                       initial: Optional[Dict[str, bytes]] = None):
        """Allocate three replicas near the service's expected location."""
        loc = spec.locations[0] if spec.locations else (0.0, 0.0)
        ranked = self._rank_by_location(loc, spec.storage_capacity_mb)
        chosen = ranked[:self.replicas]
        for c in chosen:
            c.provision(spec.service_id, chosen, initial)
        self.placements[spec.service_id] = chosen
        self.specs[spec.service_id] = spec
        self.sim.log("store_register", service=spec.service_id,
                     cargos=[c.node_id for c in chosen])
        self._push_locality(spec.service_id)
        return chosen

    # ------------------------------------------------------------ discovery

    def cargo_discover(self, service_id: str, captain_loc) -> List[Cargo]:
        """Step 1: candidate list of data access points for a Captain."""
        reps = [c for c in self.placements.get(service_id, ())
                if c.alive]
        reps.sort(key=lambda c: geohash.distance_km(
            c.spec.loc[0], c.spec.loc[1], captain_loc[0], captain_loc[1]))
        return reps[:self.top_n]

    # --------------------------------------------------------- auto-scaling

    def _ensure_replica_near(self, spec, loc, reason: str) -> bool:
        """Place one more data replica near ``loc`` unless an alive
        replica is already within ``DATA_LOCAL_RADIUS_KM``.  The copy is
        asynchronous (bulk-transfer model); locality re-publishes when it
        lands.  Returns True when a copy was started."""
        service_id = spec.service_id
        reps = self.placements.get(service_id, [])
        if not reps:
            return False
        nearest = min(
            (geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                 loc[0], loc[1])
             for c in reps if c.alive), default=float("inf"))
        if nearest <= DATA_LOCAL_RADIUS_KM:      # close enough
            return False
        ranked = self._rank_by_location(
            loc, spec.storage_capacity_mb,
            exclude=[c.node_id for c in reps])
        if not ranked:
            return False
        new = ranked[0]
        src = next((c for c in reps if c.alive), reps[0])
        data = dict(src.stores.get(service_id, {}))
        hop = self.topo.rtt(src.node_id, new.node_id)
        xfer = len(data) * 1.0e-3 + hop          # bulk copy model

        def _done():
            group = self.placements.get(service_id, []) + [new]
            new.provision(service_id, group, data)
            for c in group:
                c.peers[service_id] = [p for p in group if p is not c]
            self.placements[service_id] = group
            self.sim.log("storage_scale", service=service_id,
                         node=new.node_id, reason=reason)
            self._push_locality(service_id)

        self.sim.after(xfer, _done)
        return True

    def on_new_task(self, spec, task):
        """Compute layer grew: ensure low-latency data access nearby."""
        self._ensure_replica_near(spec, task.captain.spec.loc, "autoscale")

    def on_domain_handoff(self, loc) -> int:
        """A Beacon handoff (partition or failure) re-homed a domain's
        users near ``loc`` (the adopting region's centroid): re-place a
        data replica for every registered store that has none nearby, so
        post-handoff requests can land data-local.  Returns the number of
        copies started."""
        return sum(self._ensure_replica_near(self.specs[sid], loc,
                                             "handoff")
                   for sid in sorted(self.placements))
