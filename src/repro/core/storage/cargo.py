"""Cargo: an Armada edge storage node (paper §3.4.2).

Holds replicated key-value stores per service (face descriptors:
<ID 8 bytes, 128×8-byte vector>), serves reads/writes with network+lookup
latency, and propagates updates to its replica peers in a cascade.
Consistency:

* strong   — a write acks only after ALL replicas applied it (the
             synchronous fan-out makes loosely-coupled volunteers slow,
             Fig. 12b)
* eventual — a write acks after the local apply; propagation cascades
             asynchronously (Fig. 13)

Capacity accounting: ``used_mb`` tracks the *live* byte size of every
record in every store — provisioning, client writes, and replica
propagation all route through the same accounting, so the Cargo
Manager's placement filter ranks on what a volume actually holds, not
on its provision-time size.  When a write pushes ``used_mb`` past the
volume (``spec.storage_gb``), the manager-installed ``capacity_cb``
fires and eviction/migration takes over (``CargoManager``).

Load instrumentation for the in-situ data plane: every served read
folds its lookup service time into ``read_ema`` (the "measured read
EMA" the vectorized pool's per-user ``data_ms`` term consumes), and
fluid-transport pools charge their aggregated per-window read counts
through ``note_reads`` — ``read_rate`` (reads/s) is what lets hot
stores trigger storage auto-scaling the way hot Captains trigger
compute auto-scaling.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.cluster import NodeSpec, Topology
from repro.core.sim import Simulator

LOOKUP_MS = 2.0          # descriptor match against 1000-entry store
WRITE_MS = 1.5
RECORD_BYTES = 8 + 128 * 8
TIMEOUT_MS = 250.0       # client-side give-up on an unresponsive Cargo
READ_EMA_ALPHA = 0.3     # measured read-service-time fold
READ_RATE_ALPHA = 0.5    # per-window read-throughput fold
# utilization clamp for the effective read time: a drowned store reports
# at most 10x its base lookup, never a divide-by-zero blow-up
_UTIL_CAP = 0.9


def record_mb(key: str, value: bytes) -> float:
    """Live size of one store record: 8-byte ID + the value bytes."""
    return (8 + len(value)) / 1e6


class CargoUnavailableError(RuntimeError):
    """The addressed Cargo node is down: the request timed out.  Delivered
    to the caller's ``on_error`` callback (or signalled through ``on_done``
    when none was given) so Captains can retry against another replica
    from their ``cargo_discover`` candidate list instead of hanging."""


class Cargo:
    def __init__(self, sim: Simulator, topo: Topology, spec: NodeSpec):
        self.sim = sim
        self.topo = topo
        self.spec = spec
        self.node_id = spec.node_id
        self.alive = True
        self.stores: Dict[str, Dict[str, bytes]] = {}
        self.peers: Dict[str, List["Cargo"]] = {}     # per-service replicas
        self.used_mb: float = 0.0
        # measured read service time (EMA over served lookups) and read
        # throughput (reads/s, folded per fluid window) — the data-plane
        # inputs to ``CargoManager.data_ms_for_nodes`` / hot-store scaling
        self.read_ema: float = LOOKUP_MS
        self.read_rate: float = 0.0
        self.reads_total: int = 0
        # installed by ``CargoManager.cargo_join``: fired when a write or
        # propagation pushes ``used_mb`` past the volume capacity
        self.capacity_cb: Optional[Callable[["Cargo"], None]] = None

    # ------------------------------------------------------------- control

    @property
    def capacity_mb(self) -> float:
        return self.spec.storage_gb * 1024.0

    def provision(self, service_id: str, peers: List["Cargo"],
                  initial: Optional[Dict[str, bytes]] = None):
        old = self.stores.get(service_id)
        if old is not None:          # re-provision replaces, not stacks
            self.used_mb -= sum(record_mb(k, v) for k, v in old.items())
        store = dict(initial or {})
        self.stores[service_id] = store
        self.peers[service_id] = [p for p in peers if p is not self]
        self.used_mb += sum(record_mb(k, v) for k, v in store.items())

    def drop_store(self, service_id: str):
        """Evict a whole store (capacity migration): accounting shrinks
        with the dropped records."""
        store = self.stores.pop(service_id, None)
        if store is not None:
            self.used_mb -= sum(record_mb(k, v) for k, v in store.items())
        self.peers.pop(service_id, None)

    def fail(self):
        self.alive = False
        self.sim.log("cargo_fail", node=self.node_id)

    # ------------------------------------------------------- accounting

    def stored_mb(self) -> float:
        """Recomputed live size of every record — the accounting
        invariant ``used_mb`` must track incrementally."""
        return sum(record_mb(k, v)
                   for s in self.stores.values() for k, v in s.items())

    def check_capacity_invariant(self):
        got = self.stored_mb()
        if abs(got - self.used_mb) > 1e-9:
            raise AssertionError(
                f"cargo {self.node_id}: used_mb={self.used_mb!r} has "
                f"drifted from the live store size {got!r}")

    def _put(self, service_id: str, key: str, value: bytes):
        """Apply one record (client write or replica propagation) WITH
        capacity accounting — the only mutation path for store content
        after provisioning."""
        store = self.stores.setdefault(service_id, {})
        old = store.get(key)
        store[key] = value
        self.used_mb += record_mb(key, value) \
            - (record_mb(key, old) if old is not None else 0.0)
        if self.capacity_cb is not None and self.used_mb > self.capacity_mb:
            self.capacity_cb(self)

    # ------------------------------------------------------ load signals

    def note_reads(self, n: float, window_ms: float):
        """Charge ``n`` fluid-transport reads over one ``window_ms``
        probe window (vectorized pools aggregate per tick instead of
        issuing per-request ``read`` events)."""
        if window_ms <= 0:
            return
        rate = n * 1e3 / window_ms
        self.read_rate = READ_RATE_ALPHA * rate \
            + (1 - READ_RATE_ALPHA) * self.read_rate
        self.reads_total += int(n)

    def effective_read_ms(self) -> float:
        """Measured read service time inflated by load: utilization
        ``rate * service_time`` stretches the lookup the way a busy
        single-server queue would, clamped at 10x."""
        util = min(self.read_rate * self.read_ema / 1e3, _UTIL_CAP)
        return self.read_ema / (1.0 - util)

    # ---------------------------------------------------------------- I/O

    def _timeout(self, t0: float, op: str, key: str, on_error, fallback):
        """Deliver an explicit dead-node failure after the client-side
        timeout: ``on_error(CargoUnavailableError)`` when the caller gave
        one, else ``fallback`` (a sentinel through ``on_done`` — the
        caller must never hang on a dead Cargo)."""
        def _fire():
            self.sim.log("cargo_timeout", node=self.node_id, op=op, key=key)
            if on_error is not None:
                on_error(CargoUnavailableError(
                    f"cargo {self.node_id} is down ({op} {key!r} timed "
                    f"out after {self.sim.now - t0:.1f} ms)"))
            else:
                fallback()
        self.sim.after(max(0.0, t0 + TIMEOUT_MS - self.sim.now), _fire)

    def read(self, service_id: str, key: str, requester_id: str,
             on_done: Callable, on_error: Optional[Callable] = None):
        """Latency = RTT + lookup.  on_done(value, ms).

        A dead Cargo (at request time or mid-flight) times out after
        ``TIMEOUT_MS``: ``on_error(CargoUnavailableError)`` when given,
        else ``on_done(None, ms)`` — never a silent hang."""
        rtt = self.sim.jitter(self.topo.rtt(requester_id, self.node_id), 0.08)
        t0 = self.sim.now

        def _fail():
            self._timeout(t0, "read", key, on_error,
                          lambda: on_done(None, self.sim.now - t0))

        if not self.alive:
            _fail()
            return

        lookup = self.sim.jitter(LOOKUP_MS, 0.2)

        def _lookup():
            if not self.alive:
                _fail()
                return
            val = self.stores.get(service_id, {}).get(key)
            # served: fold the measured service time + count the read
            self.read_ema = READ_EMA_ALPHA * lookup \
                + (1 - READ_EMA_ALPHA) * self.read_ema
            self.reads_total += 1
            self.sim.after(rtt / 2, lambda: on_done(val, self.sim.now - t0))

        self.sim.after(rtt / 2 + lookup, _lookup)

    def write(self, service_id: str, key: str, value: bytes,
              requester_id: str, consistency: str, on_done: Callable,
              on_error: Optional[Callable] = None):
        """Write + replicate.  on_done(ms).

        A dead Cargo times out after ``TIMEOUT_MS``:
        ``on_error(CargoUnavailableError)`` when given, else
        ``on_done(nan)`` (a nan latency marks the failed write) — never a
        silent hang."""
        rtt = self.sim.jitter(self.topo.rtt(requester_id, self.node_id), 0.08)
        t0 = self.sim.now

        def _fail():
            self._timeout(t0, "write", key, on_error,
                          lambda: on_done(float("nan")))

        if not self.alive:
            _fail()
            return

        def _apply():
            if not self.alive:
                _fail()
                return
            self._put(service_id, key, value)
            peers = [p for p in self.peers.get(service_id, ()) if p.alive]
            if consistency == "strong":
                if not peers:
                    self.sim.after(rtt / 2,
                                   lambda: on_done(self.sim.now - t0))
                    return
                pending = {"n": len(peers)}

                def _acked():
                    pending["n"] -= 1
                    if pending["n"] == 0:
                        self.sim.after(rtt / 2,
                                       lambda: on_done(self.sim.now - t0))

                for p in peers:
                    self._propagate(service_id, key, value, p, _acked)
            else:
                # eventual: ack now, cascade in the background
                self.sim.after(rtt / 2, lambda: on_done(self.sim.now - t0))
                if peers:
                    self._propagate(service_id, key, value, peers[0],
                                    lambda: None,
                                    cascade=peers[1:])

        self.sim.after(rtt / 2 + self.sim.jitter(WRITE_MS, 0.2), _apply)

    def _propagate(self, service_id: str, key: str, value: bytes,
                   peer: "Cargo", on_acked: Callable,
                   cascade: Optional[List["Cargo"]] = None):
        hop = self.sim.jitter(self.topo.rtt(self.node_id, peer.node_id), 0.1)

        def _arrive():
            if not peer.alive:
                # skip the dead replica but keep cascading from here —
                # returning without forwarding used to orphan every
                # replica downstream of one dead peer
                if cascade:
                    self._propagate(service_id, key, value, cascade[0],
                                    lambda: None, cascade=cascade[1:])
                on_acked()
                return
            peer._put(service_id, key, value)
            if cascade:
                peer._propagate(service_id, key, value, cascade[0],
                                lambda: None, cascade=cascade[1:])
            on_acked()

        self.sim.after(hop + self.sim.jitter(WRITE_MS, 0.2), _arrive)
