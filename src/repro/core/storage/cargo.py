"""Cargo: an Armada edge storage node (paper §3.4.2).

Holds replicated key-value stores per service (face descriptors:
<ID 8 bytes, 128×8-byte vector>), serves reads/writes with network+lookup
latency, and propagates updates to its replica peers in a cascade.
Consistency:

* strong   — a write acks only after ALL replicas applied it (the
             synchronous fan-out makes loosely-coupled volunteers slow,
             Fig. 12b)
* eventual — a write acks after the local apply; propagation cascades
             asynchronously (Fig. 13)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.cluster import NodeSpec, Topology
from repro.core.sim import Simulator

LOOKUP_MS = 2.0          # descriptor match against 1000-entry store
WRITE_MS = 1.5
RECORD_BYTES = 8 + 128 * 8
TIMEOUT_MS = 250.0       # client-side give-up on an unresponsive Cargo


class CargoUnavailableError(RuntimeError):
    """The addressed Cargo node is down: the request timed out.  Delivered
    to the caller's ``on_error`` callback (or signalled through ``on_done``
    when none was given) so Captains can retry against another replica
    from their ``cargo_discover`` candidate list instead of hanging."""


class Cargo:
    def __init__(self, sim: Simulator, topo: Topology, spec: NodeSpec):
        self.sim = sim
        self.topo = topo
        self.spec = spec
        self.node_id = spec.node_id
        self.alive = True
        self.stores: Dict[str, Dict[str, bytes]] = {}
        self.peers: Dict[str, List["Cargo"]] = {}     # per-service replicas
        self.used_mb: float = 0.0

    # ------------------------------------------------------------- control

    def provision(self, service_id: str, peers: List["Cargo"],
                  initial: Optional[Dict[str, bytes]] = None):
        self.stores[service_id] = dict(initial or {})
        self.peers[service_id] = [p for p in peers if p is not self]
        self.used_mb += len(self.stores[service_id]) * RECORD_BYTES / 1e6

    def fail(self):
        self.alive = False
        self.sim.log("cargo_fail", node=self.node_id)

    # ---------------------------------------------------------------- I/O

    def _timeout(self, t0: float, op: str, key: str, on_error, fallback):
        """Deliver an explicit dead-node failure after the client-side
        timeout: ``on_error(CargoUnavailableError)`` when the caller gave
        one, else ``fallback`` (a sentinel through ``on_done`` — the
        caller must never hang on a dead Cargo)."""
        def _fire():
            self.sim.log("cargo_timeout", node=self.node_id, op=op, key=key)
            if on_error is not None:
                on_error(CargoUnavailableError(
                    f"cargo {self.node_id} is down ({op} {key!r} timed "
                    f"out after {self.sim.now - t0:.1f} ms)"))
            else:
                fallback()
        self.sim.after(max(0.0, t0 + TIMEOUT_MS - self.sim.now), _fire)

    def read(self, service_id: str, key: str, requester_id: str,
             on_done: Callable, on_error: Optional[Callable] = None):
        """Latency = RTT + lookup.  on_done(value, ms).

        A dead Cargo (at request time or mid-flight) times out after
        ``TIMEOUT_MS``: ``on_error(CargoUnavailableError)`` when given,
        else ``on_done(None, ms)`` — never a silent hang."""
        rtt = self.sim.jitter(self.topo.rtt(requester_id, self.node_id), 0.08)
        t0 = self.sim.now

        def _fail():
            self._timeout(t0, "read", key, on_error,
                          lambda: on_done(None, self.sim.now - t0))

        if not self.alive:
            _fail()
            return

        def _lookup():
            if not self.alive:
                _fail()
                return
            val = self.stores.get(service_id, {}).get(key)
            self.sim.after(rtt / 2, lambda: on_done(val, self.sim.now - t0))

        self.sim.after(rtt / 2 + self.sim.jitter(LOOKUP_MS, 0.2), _lookup)

    def write(self, service_id: str, key: str, value: bytes,
              requester_id: str, consistency: str, on_done: Callable,
              on_error: Optional[Callable] = None):
        """Write + replicate.  on_done(ms).

        A dead Cargo times out after ``TIMEOUT_MS``:
        ``on_error(CargoUnavailableError)`` when given, else
        ``on_done(nan)`` (a nan latency marks the failed write) — never a
        silent hang."""
        rtt = self.sim.jitter(self.topo.rtt(requester_id, self.node_id), 0.08)
        t0 = self.sim.now

        def _fail():
            self._timeout(t0, "write", key, on_error,
                          lambda: on_done(float("nan")))

        if not self.alive:
            _fail()
            return

        def _apply():
            if not self.alive:
                _fail()
                return
            self.stores.setdefault(service_id, {})[key] = value
            peers = [p for p in self.peers.get(service_id, ()) if p.alive]
            if consistency == "strong":
                if not peers:
                    self.sim.after(rtt / 2,
                                   lambda: on_done(self.sim.now - t0))
                    return
                pending = {"n": len(peers)}

                def _acked():
                    pending["n"] -= 1
                    if pending["n"] == 0:
                        self.sim.after(rtt / 2,
                                       lambda: on_done(self.sim.now - t0))

                for p in peers:
                    self._propagate(service_id, key, value, p, _acked)
            else:
                # eventual: ack now, cascade in the background
                self.sim.after(rtt / 2, lambda: on_done(self.sim.now - t0))
                if peers:
                    self._propagate(service_id, key, value, peers[0],
                                    lambda: None,
                                    cascade=peers[1:])

        self.sim.after(rtt / 2 + self.sim.jitter(WRITE_MS, 0.2), _apply)

    def _propagate(self, service_id: str, key: str, value: bytes,
                   peer: "Cargo", on_acked: Callable,
                   cascade: Optional[List["Cargo"]] = None):
        hop = self.sim.jitter(self.topo.rtt(self.node_id, peer.node_id), 0.1)

        def _arrive():
            if not peer.alive:
                # skip the dead replica but keep cascading from here —
                # returning without forwarding used to orphan every
                # replica downstream of one dead peer
                if cascade:
                    self._propagate(service_id, key, value, cascade[0],
                                    lambda: None, cascade=cascade[1:])
                on_acked()
                return
            peer.stores.setdefault(service_id, {})[key] = value
            if cascade:
                peer._propagate(service_id, key, value, cascade[0],
                                lambda: None, cascade=cascade[1:])
            on_acked()

        self.sim.after(hop + self.sim.jitter(WRITE_MS, 0.2), _arrive)
