"""Armada storage layer: Cargo nodes + Cargo manager (paper §3.4)."""
from repro.core.storage.cargo import Cargo  # noqa: F401
from repro.core.storage.cargo_manager import CargoManager  # noqa: F401
