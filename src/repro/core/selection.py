"""Batched performance-aware edge selection (paper §3.2, Algorithm 1).

The paper's 2-step selection scores each running replica per user:

    score = w1 * free_resources + w2 * net_affinity + w3 * proximity

after an adaptive-precision geohash proximity filter.  The seed repo ran
this as scalar Python per (user, replica) pair — fine for 5-15 users,
hostile to millions.  ``SelectionEngine`` keeps the exact semantics but
runs it on arrays:

* per-service node arrays (lat/lon, Morton geohash codes, net-type index,
  slot counts) are cached and rebuilt only when the replica set changes
  (captain join / task spawn / cancel — detected by fingerprint and by
  explicit ``invalidate`` calls from the ApplicationManager);
* per-query dynamic state (alive/running mask, free-slot fractions) is
  one O(N) sweep, amortized over the whole user batch;
* ``candidate_list`` serves the existing single-user API;
  ``candidate_lists`` scores a U×N matrix and returns per-user top-k in
  one shot (used by ``Beacon.query_service_batch`` and the autoscaler);
* the U×N scoring can optionally run through the fused
  ``repro.kernels.geo_topk`` op (jnp oracle on CPU, Pallas on TPU):
  ``candidate_indices_device`` returns device arrays with no numpy
  materialization (the fused probe tick's path), and the padded node
  half of the query is cached per node-epoch on the service view
  (``packed_static``) so only (U,)-sized user arrays and two (N,)
  dynamic vectors move per tick.

``candidate_list_scalar`` preserves the pre-refactor scalar scorer
verbatim; parity tests and ``benchmarks/bench_selection_scale.py`` pin
the engine's ranking against it.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import geohash

# scoring weights (paper Algorithm 1): resources, network affinity, proximity
W_RESOURCE = 0.5
W_AFFINITY = 0.2
W_PROXIMITY = 0.3

PROXIMITY_PRECISION = 4       # max geohash chars the proximity filter uses
MIN_PROXIMITY_HITS = 4        # widen the cell until this many replicas hit
CODE_PRECISION = 9            # full-precision Morton codes (45 bits)

# net-type affinity (same table the scalar path used); unknown types score
# the scalar path's 0.5 default via the trailing "other" row/column.
NET_TYPES = ("ethernet", "wifi", "lte", "other")
NET_INDEX = {n: i for i, n in enumerate(NET_TYPES)}
_NET_AFFINITY = {
    ("ethernet", "ethernet"): 1.0, ("ethernet", "wifi"): 0.7,
    ("wifi", "ethernet"): 0.7, ("wifi", "wifi"): 0.6,
    ("lte", "lte"): 0.5, ("lte", "wifi"): 0.4, ("wifi", "lte"): 0.4,
    ("lte", "ethernet"): 0.5, ("ethernet", "lte"): 0.5,
}
AFFINITY_TABLE = np.full((len(NET_TYPES), len(NET_TYPES)), 0.5)
for (_a, _b), _v in _NET_AFFINITY.items():
    AFFINITY_TABLE[NET_INDEX[_a], NET_INDEX[_b]] = _v


def net_index(net_type: str) -> int:
    return NET_INDEX.get(net_type, NET_INDEX["other"])


def parse_nets(user_nets, n_users: int) -> np.ndarray:
    """Coerce a net-type spec to an (U,) int64 index array: a single
    string (applied to every user), a pre-mapped integer array, or a
    sequence of net-type strings."""
    if isinstance(user_nets, str):
        return np.full(n_users, net_index(user_nets), np.int64)
    if isinstance(user_nets, np.ndarray) and \
            np.issubdtype(user_nets.dtype, np.integer):
        nets = user_nets.astype(np.int64)
    else:
        nets = np.asarray([net_index(n) for n in user_nets], np.int64)
    if len(nets) != n_users:
        raise ValueError(
            f"user_nets has {len(nets)} entries for {n_users} users")
    return nets


# ---------------------------------------------------------------------------
# Pre-refactor scalar scorer (reference for parity tests and benchmarks)
# ---------------------------------------------------------------------------

def candidate_list_scalar(tasks: Sequence[object], user_loc, user_net: str,
                          top_n: int = 3) -> List[object]:
    """The seed repo's ``ApplicationManager.candidate_list``, verbatim."""
    running = [t for t in tasks
               if t.status == "running" and t.captain is not None
               and t.captain.alive]
    if not running:
        return []
    items = [(t.task_id, t.captain.spec.loc) for t in running]
    local_ids = set(geohash.proximity_search(
        user_loc, items, precision=PROXIMITY_PRECISION))
    local = [t for t in running if t.task_id in local_ids] or running

    def score(t) -> float:
        c = t.captain
        resources = c.free_fraction()
        aff = _NET_AFFINITY.get((c.spec.net_type, user_net), 0.5)
        d = geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                user_loc[0], user_loc[1])
        prox = 1.0 / (1.0 + d / 10.0)
        return W_RESOURCE * resources + W_AFFINITY * aff + W_PROXIMITY * prox

    local.sort(key=score, reverse=True)
    return local[:top_n]


# ---------------------------------------------------------------------------
# Cached per-service arrays
# ---------------------------------------------------------------------------

class PackedStatic(NamedTuple):
    """Device-resident node half of a geo_topk query, zero-padded to a
    ``node_pad`` multiple so churn never changes jit shapes.  Static
    between replica-set changes — cached per node-epoch on the owning
    ``_ServiceArrays`` (free fractions and validity are per-tick dynamic
    and travel separately)."""
    n: int               # real task count (rows beyond are padding)
    n_pad: int
    lat: object          # (n_pad,) f32 jnp
    lon: object          # (n_pad,) f32 jnp
    aff: object          # (M, n_pad) f32 jnp affinity columns
    code20: object       # (n_pad,) i32 jnp
    cloud: object        # (n_pad,) f32 jnp — 1.0 = cloud replica


_EPOCH = itertools.count(1)


class _ServiceArrays:
    """Static (between replica-set changes) arrays over one task list."""

    def __init__(self, tasks: Sequence[object]):
        self.tasks = list(tasks)
        self.fingerprint = _fingerprint(tasks)
        self.epoch = next(_EPOCH)       # bumps on every rebuild
        self._packed: Dict[int, PackedStatic] = {}
        n = len(self.tasks)
        self.lat = np.empty(n)
        self.lon = np.empty(n)
        self.net_idx = np.empty(n, np.int64)
        self.cloud = np.zeros(n, bool)
        self.dedicated = np.zeros(n, bool)
        self.node_ids: List[Optional[str]] = [None] * n
        for i, t in enumerate(self.tasks):
            if t.captain is None:
                self.lat[i] = self.lon[i] = 0.0
                self.net_idx[i] = NET_INDEX["other"]
            else:
                self.lat[i], self.lon[i] = t.captain.spec.loc
                self.net_idx[i] = net_index(t.captain.spec.net_type)
                self.cloud[i] = t.captain.spec.is_cloud
                self.dedicated[i] = t.captain.spec.dedicated
                self.node_ids[i] = t.captain.node_id
        self.codes = geohash.encode_batch(self.lat, self.lon, CODE_PRECISION)

    def alive_mask(self) -> np.ndarray:
        """(T,) bool: task has a live captain (status ignored — matches the
        scalar client's connection-break liveness check)."""
        return np.fromiter(
            (t.captain is not None and t.captain.alive for t in self.tasks),
            bool, count=len(self.tasks))

    def dynamic_state(self) -> Tuple[np.ndarray, np.ndarray]:
        """(mask, free): alive+running mask and free-slot fractions."""
        n = len(self.tasks)
        mask = np.zeros(n, bool)
        free = np.zeros(n)
        for i, t in enumerate(self.tasks):
            c = t.captain
            if t.status == "running" and c is not None and c.alive:
                mask[i] = True
                free[i] = c.free_fraction()
        return mask, free

    def packed_static(self, node_pad: int = 256) -> PackedStatic:
        """Kernel-ready padded node arrays, built once per node-epoch
        (i.e. once per replica-set change) and cached on this view —
        repacking from numpy used to happen on every tick."""
        cached = self._packed.get(node_pad)
        if cached is not None:
            return cached
        import jax.numpy as jnp

        from repro.kernels.geo_topk.ops import code20
        n = len(self.tasks)
        n_pad = max(node_pad, -(-n // node_pad) * node_pad)

        def pad(x, dtype):
            out = np.zeros(n_pad, dtype)
            out[:n] = x
            return jnp.asarray(out)

        aff = np.zeros((AFFINITY_TABLE.shape[0], n_pad), np.float32)
        aff[:, :n] = AFFINITY_TABLE[self.net_idx, :].T
        packed = PackedStatic(
            n=n, n_pad=n_pad,
            lat=pad(self.lat, np.float32),
            lon=pad(self.lon, np.float32),
            aff=jnp.asarray(aff),
            code20=pad(code20(self.codes), np.int32),
            cloud=pad(self.cloud, np.float32))
        self._packed[node_pad] = packed
        return packed

    def padded_dynamic(self, node_pad: int = 256
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-tick (free, valid_sched, valid_alive) padded to match
        ``packed_static``: fp32 free fractions, schedulable mask (running
        + alive — what selection scores) and alive mask (what the client
        data plane may still talk to)."""
        mask, free = self.dynamic_state()
        st = self.packed_static(node_pad)
        free_p = np.zeros(st.n_pad, np.float32)
        free_p[:st.n] = free
        sched = np.zeros(st.n_pad, np.float32)
        sched[:st.n] = mask
        alive = np.zeros(st.n_pad, bool)
        alive[:st.n] = self.alive_mask()
        return free_p, sched, alive


def _fingerprint(tasks: Sequence[object]) -> Tuple:
    return tuple((t.task_id, None if t.captain is None
                  else t.captain.node_id) for t in tasks)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class SelectionEngine:
    def __init__(self, *, top_n: int = 3, user_chunk: int = 8192):
        self.top_n = top_n
        self.user_chunk = user_chunk        # bounds the U×N score matrices
        self._cache: Dict[str, _ServiceArrays] = {}

    # ------------------------------------------------------------- caching

    def invalidate(self, service_id: Optional[str] = None):
        """Drop cached node arrays (replica set changed)."""
        if service_id is None:
            self._cache.clear()
        else:
            self._cache.pop(service_id, None)

    def _arrays(self, service_id: str,
                tasks: Sequence[object]) -> _ServiceArrays:
        arr = self._cache.get(service_id)
        if arr is None or arr.fingerprint != _fingerprint(tasks):
            arr = _ServiceArrays(tasks)
            self._cache[service_id] = arr
        return arr

    # ------------------------------------------------------------- queries

    def candidate_list(self, service_id: str, tasks: Sequence[object],
                       user_loc, user_net: str,
                       top_n: Optional[int] = None) -> List[object]:
        """Single-user Algorithm 1 — same ranking as the scalar scorer."""
        return self.candidate_lists(service_id, tasks, [user_loc],
                                    [user_net], top_n=top_n)[0]

    def candidate_lists(self, service_id: str, tasks: Sequence[object],
                        user_locs, user_nets, top_n: Optional[int] = None,
                        ) -> List[List[object]]:
        """Batched Algorithm 1: per-user top-k over a U×N score matrix.

        ``user_locs``: sequence of (lat, lon); ``user_nets``: sequence of
        net-type strings (or a single string applied to every user).
        Returns one ranked Task list per user.  (Materializing wrapper over
        ``candidate_indices`` — the ClientPool stays in index space.)
        """
        idx = self.candidate_indices(service_id, tasks, user_locs,
                                     user_nets, top_n=top_n)
        task_seq = list(tasks)
        return [[task_seq[j] for j in row if j >= 0] for row in idx]

    def candidate_indices(self, service_id: str, tasks: Sequence[object],
                          user_locs, user_nets,
                          top_n: Optional[int] = None) -> np.ndarray:
        """Batched Algorithm 1 in index space: ``(U, k)`` int32 matrix of
        ranked positions into ``tasks``, right-padded with -1.  Same
        ranking as ``candidate_lists`` without materializing Python lists
        (the ``ClientPool`` hot path)."""
        k = top_n or self.top_n
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        u_total = len(users)
        nets = parse_nets(user_nets, u_total)
        arr = self._arrays(service_id, tasks)
        mask, free = arr.dynamic_state()
        run_ix = np.nonzero(mask)[0]
        out = np.full((u_total, k), -1, np.int32)   # always (U, k)
        if run_ix.size == 0:
            return out
        kk = min(k, run_ix.size)
        for lo in range(0, u_total, self.user_chunk):
            hi = min(lo + self.user_chunk, u_total)
            out[lo:hi, :kk] = self._score_chunk(arr, run_ix, free[run_ix],
                                                users[lo:hi], nets[lo:hi],
                                                kk)
        return out

    def _score_chunk(self, arr: _ServiceArrays, run_ix: np.ndarray,
                     free: np.ndarray, users: np.ndarray,
                     nets: np.ndarray, k: int) -> np.ndarray:
        n = run_ix.size
        u = len(users)
        n_lat = arr.lat[run_ix]
        n_lon = arr.lon[run_ix]
        n_codes = arr.codes[run_ix]
        n_net = arr.net_idx[run_ix]
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)

        # adaptive-precision proximity filter: for p = 4..1, keep replicas
        # sharing the first p geohash chars; accept the first p with enough
        # hits, else no filter (exact ``proximity_search`` semantics).
        # One (U, N) compare at a time keeps peak memory at a single tile.
        need = min(MIN_PROXIMITY_HITS, n)
        local = np.ones((u, n), bool)                 # fallback: no filter
        done = np.zeros(u, bool)
        for p in range(PROXIMITY_PRECISION, 0, -1):
            shift = 5 * (CODE_PRECISION - p)
            eq = (u_codes[:, None] >> shift) == (n_codes[None, :] >> shift)
            use = (eq.sum(axis=1) >= need) & ~done
            local = np.where(use[:, None], eq, local)
            done |= use

        d = geohash.distance_km_batch(users[:, 0:1], users[:, 1:2],
                                      n_lat[None, :], n_lon[None, :])
        prox = 1.0 / (1.0 + d / 10.0)
        aff = AFFINITY_TABLE[n_net[None, :], nets[:, None]]
        scores = (W_RESOURCE * free[None, :] + W_AFFINITY * aff
                  + W_PROXIMITY * prox)
        scores = np.where(local, scores, -np.inf)
        # stable argsort matches Python's stable sort on score ties
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        n_local = local.sum(axis=1)
        idx = run_ix[order].astype(np.int32)
        idx[np.arange(k)[None, :] >= np.minimum(k, n_local)[:, None]] = -1
        return idx

    def service_view(self, service_id: str,
                     tasks: Sequence[object]) -> _ServiceArrays:
        """Cached per-task attribute arrays (lat/lon, net, cloud/dedicated
        flags, node ids) for the current replica set — the ClientPool's
        window into task attributes without touching Task objects."""
        return self._arrays(service_id, tasks)

    # --------------------------------------------------- kernel-backed path

    def prepare_kernel_inputs(self, service_id: str,
                              tasks: Sequence[object], user_locs,
                              user_nets):
        """Pack the current replica set + a user batch into the flat arrays
        ``repro.kernels.geo_topk`` consumes (see its docstring for the
        meaning of the 20-bit codes and per-user shifts)."""
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        nets = parse_nets(user_nets, len(users))
        arr = self._arrays(service_id, tasks)
        mask, free = arr.dynamic_state()
        run_ix = np.nonzero(mask)[0]
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)
        from repro.kernels.geo_topk.ops import pack_inputs
        return run_ix, pack_inputs(
            users[:, 0], users[:, 1], nets, u_codes,
            arr.lat[run_ix], arr.lon[run_ix], free[run_ix],
            arr.net_idx[run_ix], arr.codes[run_ix])

    def candidate_lists_kernel(self, service_id: str,
                               tasks: Sequence[object], user_locs,
                               user_nets, top_n: Optional[int] = None,
                               interpret: bool = False) -> List[List[object]]:
        """Batched selection through the fused geo_topk op (jnp oracle on
        CPU, Pallas kernel on TPU).  Same top-k semantics as
        ``candidate_lists``."""
        from repro.kernels.geo_topk.ops import geo_topk
        k = top_n or self.top_n
        run_ix, packed = self.prepare_kernel_inputs(service_id, tasks,
                                                    user_locs, user_nets)
        if run_ix.size == 0:
            return [[] for _ in range(len(packed.user_lat))]
        scores, idx = geo_topk(packed, k=min(k, run_ix.size),
                               interpret=interpret)
        scores = np.asarray(scores)
        idx = np.asarray(idx)
        arr = self._cache[service_id]
        return [[arr.tasks[run_ix[j]] for j, s in zip(row_i, row_s)
                 if np.isfinite(s) and s > -1e29]
                for row_i, row_s in zip(idx, scores)]

    def candidate_indices_device(self, service_id: str,
                                 tasks: Sequence[object], user_locs,
                                 user_nets, top_n: Optional[int] = None,
                                 node_pad: int = 256,
                                 interpret: bool = False):
        """Batched Algorithm 1 on device, no numpy materialization:
        returns ``(scores, idx)`` jnp arrays of shape ``(U, k_eff)``,
        ``k_eff = min(top_n, running replicas)`` — ``idx`` in task-
        position space with padding/filtered entries scoring ``NEG``
        (callers keep entries with ``score > -1e29``).  Returns ``None``
        when no replica is running.

        The node half of the query comes from the per-epoch
        ``packed_static`` cache (device-resident, zero-padded to a
        ``node_pad`` multiple so churn never changes jit shapes); only
        the (U,) user arrays and two (n_pad,) dynamic vectors cross the
        host→device boundary per call.  fp32 scoring — ranking may
        differ from the float64 numpy path at exact-tie resolution.
        """
        from repro.kernels.geo_topk.ops import (GeoTopKInputs, geo_topk,
                                                pack_user_inputs)
        k = top_n or self.top_n
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        nets = parse_nets(user_nets, len(users))
        arr = self._arrays(service_id, tasks)
        st = arr.packed_static(node_pad)
        free_p, sched, _alive = arr.padded_dynamic(node_pad)
        n_run = int(sched.sum())
        if n_run == 0:
            return None
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)
        packed = GeoTopKInputs(
            *pack_user_inputs(users[:, 0], users[:, 1], nets, u_codes),
            st.lat, st.lon, free_p, st.aff, st.code20, sched)
        k_eff = min(k, n_run)
        return geo_topk(packed, k=k_eff,
                        need=min(MIN_PROXIMITY_HITS, n_run),
                        interpret=interpret)

    def candidate_indices_kernel(self, service_id: str,
                                 tasks: Sequence[object], user_locs,
                                 user_nets, top_n: Optional[int] = None,
                                 node_pad: int = 256,
                                 interpret: bool = False) -> np.ndarray:
        """``candidate_indices`` through the fused geo_topk op — the
        ClientPool's high-throughput refresh path (fluid transport).
        Materializing wrapper over ``candidate_indices_device``."""
        k = top_n or self.top_n
        u_total = np.asarray(user_locs, np.float64).reshape(-1, 2).shape[0]
        res = self.candidate_indices_device(
            service_id, tasks, user_locs, user_nets, top_n=top_n,
            node_pad=node_pad, interpret=interpret)
        if res is None:
            return np.full((u_total, k), -1, np.int32)
        scores = np.asarray(res[0])
        idx = np.asarray(res[1])
        out = np.where(scores > -1e29, idx, -1).astype(np.int32)
        k_eff = out.shape[1]
        if k_eff < k:
            out = np.concatenate(
                [out, np.full((u_total, k - k_eff), -1, np.int32)],
                axis=1)
        return out
