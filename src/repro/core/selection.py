"""Batched, region-sharded performance-aware edge selection (paper
§3.1-3.2, Algorithm 1).

The paper's 2-step selection scores each running replica per user:

    score = w1 * free_resources + w2 * net_affinity + w3 * proximity

after an adaptive-precision geohash proximity filter, and scales the
control plane by replicating Beacon per coarse geographic region so each
replica tracks only nearby nodes.  ``SelectionEngine`` implements both
halves on arrays:

* **Global view** — per-service node arrays (lat/lon, Morton geohash
  codes, net-type index, cloud/dedicated flags) are cached per replica-set
  fingerprint and rebuilt only on change (captain join / task spawn /
  cancel — detected lazily and by explicit ``invalidate`` calls);
  per-query dynamic state (running mask, free-slot fractions) is one O(N)
  sweep amortized over the whole user batch.
* **Region shards** (``shard_precision=1..4``) — the replica set is
  partitioned by Morton-code prefix into per-shard ``_ServiceArrays``
  (``_ShardSet``), each with its *own* ``packed_static`` device cache, so
  a replica-set change in one region leaves every other shard's device
  arrays untouched (``_Shard.adopt`` carries them across rebuilds).  A
  query routes each user chunk to its home-region shard and scores only
  that shard's nodes with the proximity filter restricted to precisions
  ``p >= shard_precision``.  Because geohash cells nest, a user's p-cell
  for ``p >= shard_precision`` lies entirely inside their home shard, so
  in-shard hit counts equal global hit counts and a satisfied user's
  filter level, mask and scores are *exactly* the unsharded engine's.
  Users the in-shard widening cannot satisfy (the **border band**: near a
  shard boundary, in a sparse region, or needing the global no-filter
  fallback) escalate to a cross-shard pass over the adjacent shards'
  union (the full node set), which reproduces the unsharded computation
  verbatim.  Per-shard (U, k) index matrices are merged back in global
  task-position space — within a shard, tasks keep ascending global
  order, so score ties resolve exactly like the unsharded stable argsort.
  Per-shard scoring cost is O(U·N/S + border overlap) instead of O(U·N).
* ``candidate_list`` serves the existing single-user API;
  ``candidate_lists`` scores a U×N matrix and returns per-user top-k in
  one shot (used by ``Beacon.query_service_batch`` and the autoscaler).
* The scoring can optionally run through the fused
  ``repro.kernels.geo_topk`` op (jnp oracle on CPU, Pallas on TPU):
  ``candidate_indices_device`` returns device arrays (the fused probe
  tick's path; its sharded variant syncs only a small per-shard
  "satisfied" mask to the host), and the padded node half of the query is
  cached per node-epoch per shard (``packed_static``) so only (U,)-sized
  user arrays and per-shard (N_s,) dynamic vectors move per tick.
  ``repro.core.fused_tick`` fuses the same per-shard layout into the
  device-resident probe tick with jit-stable shapes under churn.

* **Beacon fault domains** — a ``BeaconSet`` (``repro.core.beacon``)
  pushes control-plane state into the engine via ``set_beacon_routing``:
  an *ownership map* (dead region -> nearest live region; ``_ShardSet``
  groups and routes through it, merging the dead domain's tasks into the
  adopting shard and handing its users off — the multi-Beacon handoff)
  and a *hidden set* (nodes whose registration died with their Beacon;
  a dynamic schedulable-mask input with zero cache/jit impact).  While
  nothing is hidden the owner-mapped engine remains decision-identical
  to the unsharded one — nesting still holds for merged shards
  (tests/test_beacon_failover.py).

``candidate_list_scalar`` preserves the pre-refactor scalar scorer
verbatim; parity tests (``tests/test_selection.py``,
``tests/test_sharded_selection.py``) pin the engine's ranking against it
and the sharded engine against the unsharded one, including cross-shard
border ties; ``benchmarks/bench_sharded_selection.py`` measures the 1/S
scaling.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import geohash

# scoring weights (paper Algorithm 1): resources, network affinity, proximity
W_RESOURCE = 0.5
W_AFFINITY = 0.2
W_PROXIMITY = 0.3

# data-locality preference (paper §3.4 in-situ data access): bonus for nodes
# within DATA_LOCAL_RADIUS_KM of an alive Cargo replica of the service's
# store.  Folded into the free-fraction vector (scaled by 1/W_RESOURCE) in
# ``_ServiceArrays.dynamic_state`` — the single dynamic-input injection point
# shared by the numpy, geo_topk-kernel and fused-device tick paths — so all
# three stay decision-identical without touching kernel code.  Off (exact
# pre-existing scores) unless a CargoManager pushed placements for the
# service via ``SelectionEngine.set_data_locality``.
W_DATA = 0.15
DATA_LOCAL_RADIUS_KM = 50.0

# queueing-aware load term (serving-aware data plane): penalty for nodes
# whose serving profile reports expected queueing delay.  free_fraction
# clamps at 0 once the backlog exceeds the slot count, so under
# saturation proximity decides and users keep piling onto drowning
# nodes; this term keeps growing with the backlog
# (min(queue_ms / QUEUE_NORM_MS, 1)), letting scoring tell a
# slightly-busy node from a saturated one.  Folded into the
# free-fraction vector in ``_ServiceArrays.dynamic_state`` exactly like
# the data-locality bonus — one injection point, all four tick paths
# (numpy, geo_topk kernel, fused device, mesh) stay decision-identical.
# Off (exact pre-existing scores) unless enabled per service via
# ``SelectionEngine.set_queueing_awareness``.
W_QUEUE = 0.2
QUEUE_NORM_MS = 250.0

PROXIMITY_PRECISION = 4       # max geohash chars the proximity filter uses
MIN_PROXIMITY_HITS = 4        # widen the cell until this many replicas hit
CODE_PRECISION = 9            # full-precision Morton codes (45 bits)

# net-type affinity (same table the scalar path used); unknown types score
# the scalar path's 0.5 default via the trailing "other" row/column.
NET_TYPES = ("ethernet", "wifi", "lte", "other")
NET_INDEX = {n: i for i, n in enumerate(NET_TYPES)}
_NET_AFFINITY = {
    ("ethernet", "ethernet"): 1.0, ("ethernet", "wifi"): 0.7,
    ("wifi", "ethernet"): 0.7, ("wifi", "wifi"): 0.6,
    ("lte", "lte"): 0.5, ("lte", "wifi"): 0.4, ("wifi", "lte"): 0.4,
    ("lte", "ethernet"): 0.5, ("ethernet", "lte"): 0.5,
}
AFFINITY_TABLE = np.full((len(NET_TYPES), len(NET_TYPES)), 0.5)
for (_a, _b), _v in _NET_AFFINITY.items():
    AFFINITY_TABLE[NET_INDEX[_a], NET_INDEX[_b]] = _v


def net_index(net_type: str) -> int:
    return NET_INDEX.get(net_type, NET_INDEX["other"])


def parse_nets(user_nets, n_users: int) -> np.ndarray:
    """Coerce a net-type spec to an (U,) int64 index array: a single
    string (applied to every user), a pre-mapped integer sequence (list,
    tuple or ndarray), or a sequence of net-type strings.

    Pre-mapped indices are validated against ``NET_TYPES`` — a plain
    Python list of ints used to fall through the string branch and map
    every entry to "other" silently."""
    if isinstance(user_nets, str):
        return np.full(n_users, net_index(user_nets), np.int64)
    arr = np.asarray(user_nets)
    if np.issubdtype(arr.dtype, np.integer):
        nets = arr.astype(np.int64)
        if nets.size and (nets.min() < 0 or nets.max() >= len(NET_TYPES)):
            raise ValueError(
                f"net index out of range [0, {len(NET_TYPES)}): "
                f"{nets[(nets < 0) | (nets >= len(NET_TYPES))][:5]}")
    else:
        nets = np.asarray([net_index(n) for n in user_nets], np.int64)
    if len(nets) != n_users:
        raise ValueError(
            f"user_nets has {len(nets)} entries for {n_users} users")
    return nets


def _score_rows(lat, lon, net_idx, free, users, nets) -> np.ndarray:
    """Unfiltered (U, N) float64 Algorithm-1 scores for a user chunk
    against node attribute rows.  Single source for the numpy scoring
    arithmetic — the global and per-shard scorers must stay bit-identical
    for the sharded engine's decision parity to hold."""
    d = geohash.distance_km_batch(users[:, 0:1], users[:, 1:2],
                                  lat[None, :], lon[None, :])
    prox = 1.0 / (1.0 + d / 10.0)
    aff = AFFINITY_TABLE[net_idx[None, :], nets[:, None]]
    return (W_RESOURCE * free[None, :] + W_AFFINITY * aff
            + W_PROXIMITY * prox)


def _rank_local(scores: np.ndarray, local: np.ndarray, k: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Stable descending rank of filtered scores: ``(order, n_local)``.
    The stable argsort matches Python's stable sort on score ties —
    shared by the global and per-shard scorers so cross-shard merges
    tie-break identically."""
    masked = np.where(local, scores, -np.inf)
    order = np.argsort(-masked, axis=1, kind="stable")[:, :k]
    return order, local.sum(axis=1)


# ---------------------------------------------------------------------------
# Pre-refactor scalar scorer (reference for parity tests and benchmarks)
# ---------------------------------------------------------------------------

def candidate_list_scalar(tasks: Sequence[object], user_loc, user_net: str,
                          top_n: int = 3) -> List[object]:
    """The seed repo's ``ApplicationManager.candidate_list``, verbatim."""
    running = [t for t in tasks
               if t.status == "running" and t.captain is not None
               and t.captain.alive]
    if not running:
        return []
    items = [(t.task_id, t.captain.spec.loc) for t in running]
    local_ids = set(geohash.proximity_search(
        user_loc, items, precision=PROXIMITY_PRECISION))
    local = [t for t in running if t.task_id in local_ids] or running

    def score(t) -> float:
        c = t.captain
        resources = c.free_fraction()
        aff = _NET_AFFINITY.get((c.spec.net_type, user_net), 0.5)
        d = geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                user_loc[0], user_loc[1])
        prox = 1.0 / (1.0 + d / 10.0)
        return W_RESOURCE * resources + W_AFFINITY * aff + W_PROXIMITY * prox

    local.sort(key=score, reverse=True)
    return local[:top_n]


# ---------------------------------------------------------------------------
# Cached per-service arrays
# ---------------------------------------------------------------------------

class PackedStatic(NamedTuple):
    """Device-resident node half of a geo_topk query, zero-padded to a
    ``node_pad`` multiple so churn never changes jit shapes.  Static
    between replica-set changes — cached per node-epoch on the owning
    ``_ServiceArrays`` (free fractions and validity are per-tick dynamic
    and travel separately)."""
    n: int               # real task count (rows beyond are padding)
    n_pad: int
    lat: object          # (n_pad,) f32 jnp
    lon: object          # (n_pad,) f32 jnp
    aff: object          # (M, n_pad) f32 jnp affinity columns
    code20: object       # (n_pad,) i32 jnp
    cloud: object        # (n_pad,) f32 jnp — 1.0 = cloud replica


_EPOCH = itertools.count(1)


class _ServiceArrays:
    """Static (between replica-set changes) arrays over one task list."""

    def __init__(self, tasks: Sequence[object]):
        self.tasks = list(tasks)
        self.fingerprint = _fingerprint(tasks)
        self.epoch = next(_EPOCH)       # bumps on every rebuild
        self._packed: Dict[int, PackedStatic] = {}
        self._local_bits: Dict[tuple, np.ndarray] = {}
        n = len(self.tasks)
        self.lat = np.empty(n)
        self.lon = np.empty(n)
        self.net_idx = np.empty(n, np.int64)
        self.cloud = np.zeros(n, bool)
        self.dedicated = np.zeros(n, bool)
        self.node_ids: List[Optional[str]] = [None] * n
        for i, t in enumerate(self.tasks):
            if t.captain is None:
                self.lat[i] = self.lon[i] = 0.0
                self.net_idx[i] = NET_INDEX["other"]
            else:
                self.lat[i], self.lon[i] = t.captain.spec.loc
                self.net_idx[i] = net_index(t.captain.spec.net_type)
                self.cloud[i] = t.captain.spec.is_cloud
                self.dedicated[i] = t.captain.spec.dedicated
                self.node_ids[i] = t.captain.node_id
        self.codes = geohash.encode_batch(self.lat, self.lon, CODE_PRECISION)

    def alive_mask(self) -> np.ndarray:
        """(T,) bool: task has a live captain (status ignored — matches the
        scalar client's connection-break liveness check)."""
        return np.fromiter(
            (t.captain is not None and t.captain.alive for t in self.tasks),
            bool, count=len(self.tasks))

    def locality_bits(self, locs: tuple) -> np.ndarray:
        """(T,) float64 data-locality bits: 1.0 where the task's node sits
        within ``DATA_LOCAL_RADIUS_KM`` of any of the given Cargo replica
        locations.  Depends only on static node positions, so it is cached
        per replica-location tuple on this view."""
        bits = self._local_bits.get(locs)
        if bits is None:
            if not locs:
                bits = np.zeros(len(self.tasks))
            else:
                pts = np.asarray(locs, np.float64).reshape(-1, 2)
                d = geohash.distance_km_batch(
                    self.lat[:, None], self.lon[:, None],
                    pts[None, :, 0], pts[None, :, 1])
                bits = (d.min(axis=1) <= DATA_LOCAL_RADIUS_KM
                        ).astype(np.float64)
            self._local_bits[locs] = bits
        return bits

    def dynamic_state(self, hidden=None, locality=None, queueing=None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(mask, free): alive+running mask and free-slot fractions.

        ``hidden`` names nodes no live Beacon currently knows (their fault
        domain's Beacon died and the heartbeat replay has not reached a
        surviving replica yet): they stay alive on the data plane — warm
        connections and in-flight frames are untouched — but drop out of
        the schedulable mask, so selection cannot hand them to new users
        until they re-register.

        ``locality`` is an optional ``(replica_locs, weight)`` pair from
        ``SelectionEngine.set_data_locality``: the data-locality bonus is
        folded into ``free`` here, scaled by ``1/W_RESOURCE`` so the final
        Algorithm-1 score gains exactly ``weight`` per data-local node.

        ``queueing`` is an optional ``(weight, norm_ms)`` pair from
        ``SelectionEngine.set_queueing_awareness``: each captain's
        expected queueing delay (heartbeat ``queue_ms``, from its serving
        profile's backlog) is normalized to ``min(queue_ms / norm_ms, 1)``
        and subtracted the same way, so a saturated node loses up to
        ``weight`` score even after ``free_fraction`` has clamped at 0.

        This is the single injection point every tick path (numpy scorer,
        geo_topk kernel, fused device tick, mesh) draws its dynamic node
        state from — folding the terms here keeps them decision-identical
        by construction."""
        n = len(self.tasks)
        mask = np.zeros(n, bool)
        free = np.zeros(n)
        queue_ms = np.zeros(n) if queueing is not None else None
        for i, t in enumerate(self.tasks):
            c = t.captain
            if t.status == "running" and c is not None and c.alive \
                    and not (hidden and c.node_id in hidden):
                mask[i] = True
                free[i] = c.free_fraction()
                if queue_ms is not None:
                    queue_ms[i] = c.queueing_delay_ms()
        if locality is not None:
            locs, weight = locality
            free = free + (weight / W_RESOURCE) * self.locality_bits(locs) \
                * mask
        if queueing is not None:
            weight, norm_ms = queueing
            free = free - (weight / W_RESOURCE) \
                * np.minimum(queue_ms / max(norm_ms, 1e-9), 1.0) * mask
        return mask, free

    def packed_static(self, node_pad: int = 256) -> PackedStatic:
        """Kernel-ready padded node arrays, built once per node-epoch
        (i.e. once per replica-set change) and cached on this view —
        repacking from numpy used to happen on every tick."""
        cached = self._packed.get(node_pad)
        if cached is not None:
            return cached
        import jax.numpy as jnp

        from repro.kernels.geo_topk.ops import code20
        n = len(self.tasks)
        n_pad = max(node_pad, -(-n // node_pad) * node_pad)

        def pad(x, dtype):
            out = np.zeros(n_pad, dtype)
            out[:n] = x
            return jnp.asarray(out)

        aff = np.zeros((AFFINITY_TABLE.shape[0], n_pad), np.float32)
        aff[:, :n] = AFFINITY_TABLE[self.net_idx, :].T
        packed = PackedStatic(
            n=n, n_pad=n_pad,
            lat=pad(self.lat, np.float32),
            lon=pad(self.lon, np.float32),
            aff=jnp.asarray(aff),
            code20=pad(code20(self.codes), np.int32),
            cloud=pad(self.cloud, np.float32))
        self._packed[node_pad] = packed
        return packed

    def padded_sched(self, mask: np.ndarray, free: np.ndarray,
                     node_pad: int = 256
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(free_p, sched) in the kernel's padded layout, from an
        already-computed ``dynamic_state`` sweep (the single source for
        this padding — callers that did the O(N) sweep themselves must
        not restate it)."""
        st = self.packed_static(node_pad)
        free_p = np.zeros(st.n_pad, np.float32)
        free_p[:st.n] = free
        sched = np.zeros(st.n_pad, np.float32)
        sched[:st.n] = mask
        return free_p, sched

    def padded_dynamic(self, node_pad: int = 256, hidden=None,
                       locality=None, queueing=None
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-tick (free, valid_sched, valid_alive) padded to match
        ``packed_static``: fp32 free fractions (data-locality bonus and
        queueing-delay penalty folded in when ``locality`` / ``queueing``
        are set — see ``dynamic_state``), schedulable mask (running +
        alive + Beacon-visible — what selection scores) and alive mask
        (what the client data plane may still talk to; control-plane
        ``hidden`` does NOT touch it)."""
        mask, free = self.dynamic_state(hidden, locality, queueing)
        free_p, sched = self.padded_sched(mask, free, node_pad)
        alive = np.zeros(free_p.shape[0], bool)
        alive[:len(self.tasks)] = self.alive_mask()
        return free_p, sched, alive


def _fingerprint(tasks: Sequence[object]) -> Tuple:
    return tuple((t.task_id, None if t.captain is None
                  else t.captain.node_id) for t in tasks)


# ---------------------------------------------------------------------------
# Region shards (paper §3.1: per-region Beacon replicas)
# ---------------------------------------------------------------------------

class _Shard:
    """One Morton-prefix region of a service's replica set: a child
    ``_ServiceArrays`` over the shard's tasks plus the mapping back to
    global task-list positions (``ix``, ascending — so per-shard stable
    sorts tie-break exactly like the global one)."""

    def __init__(self, code: int, ix: np.ndarray, tasks: Sequence[object]):
        self.code = int(code)
        self.ix = ix
        self.arrays = _ServiceArrays(tasks)
        self._task_ix_pad: Dict[int, np.ndarray] = {}

    def adopt(self, prev: "_Shard"):
        """Carry the device-resident caches over from a predecessor whose
        membership fingerprint is identical — a replica-set change in
        another region must not repack this shard's node arrays."""
        self.arrays._packed = prev.arrays._packed
        self.arrays.epoch = prev.arrays.epoch
        self._task_ix_pad = prev._task_ix_pad

    def task_ix_padded(self, node_pad: int = 256) -> np.ndarray:
        """(n_pad,) int32 global task positions, -1 beyond the shard —
        the local→global index map for kernel-path top-k results, padded
        exactly like ``packed_static`` so churn never changes jit shapes."""
        out = self._task_ix_pad.get(node_pad)
        if out is None:
            n = len(self.ix)
            n_pad = max(node_pad, -(-n // node_pad) * node_pad)
            out = np.full(n_pad, -1, np.int32)
            out[:n] = self.ix
            self._task_ix_pad[node_pad] = out
        return out

    def padded_dynamic(self, mask: np.ndarray, free: np.ndarray,
                       node_pad: int = 256
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-tick (free, sched) for this shard, sliced from the parent
        O(N) sweep and padded to the shard's kernel layout."""
        return self.arrays.padded_sched(mask[self.ix], free[self.ix],
                                        node_pad)


class _ShardSet:
    """Partition of one service's task list by Morton-code prefix at
    ``precision`` chars.  Rebuilt when the parent view changes, but
    shards whose own membership is unchanged adopt their predecessor's
    device caches — invalidation is effectively routed to the one shard
    whose region actually changed.

    ``owner`` maps home region codes to the region whose Beacon replica
    currently *serves* them (Beacon fault domains — a dead domain's
    regions are re-pointed at the nearest live Beacon).  Grouping and
    routing both apply the map, so a failed domain's tasks merge into
    the adopting Beacon's shard and its users hand off to the same shard
    — decision-identical to the unsharded engine by the same nesting
    argument: an owner-mapped user's ``p >= precision`` cells still lie
    entirely inside their (merged) shard."""

    def __init__(self, parent: _ServiceArrays, precision: int,
                 prev: Optional["_ShardSet"] = None,
                 owner: Optional[Dict[int, int]] = None,
                 owner_version: int = 0):
        self.parent_epoch = parent.epoch
        self.precision = precision
        self.owner = dict(owner) if owner else None
        self.owner_version = owner_version
        shift = 5 * (CODE_PRECISION - precision)
        shard_code = self._apply_owner(parent.codes >> shift)
        prev_by_code = {}
        diffable = prev is not None and prev.precision == precision
        if diffable:
            prev_by_code = {s.code: s for s in prev.shards}
        self.shards: List[_Shard] = []
        # refresh-epoch attribution: serving codes whose membership
        # actually changed across this rebuild (failed adopt, new shard,
        # vanished shard); None when there is no predecessor to diff
        # against (initial build / teardown) — the engine marks globally
        changed: List[int] = []
        for code in np.unique(shard_code):
            ix = np.nonzero(shard_code == code)[0]
            sh = _Shard(code, ix, [parent.tasks[i] for i in ix])
            old = prev_by_code.pop(int(code), None)
            if old is not None and len(old.ix) == len(ix) \
                    and old.arrays.fingerprint == sh.arrays.fingerprint \
                    and np.array_equal(old.ix, ix):
                sh.adopt(old)
            else:
                changed.append(int(code))
            self.shards.append(sh)
        changed.extend(prev_by_code)          # vanished shards
        self.changed_codes: Optional[List[int]] = changed if diffable \
            else None

    def _apply_owner(self, codes: np.ndarray) -> np.ndarray:
        """Map prefix codes through the Beacon ownership table (identity
        for regions whose own Beacon is alive).  Vectorized over the
        unique codes — the table is tiny, the arrays are not."""
        if not self.owner:
            return codes
        uq, inv = np.unique(codes, return_inverse=True)
        mapped = np.asarray([self.owner.get(int(c), int(c)) for c in uq],
                            np.int64)
        return mapped[inv]

    def route(self, u_codes: np.ndarray) -> np.ndarray:
        """(U,) serving-shard prefix code per user (full-precision codes):
        the home-region prefix mapped through Beacon ownership — a user
        whose home Beacon is down routes to the adopting live Beacon's
        merged shard (the multi-Beacon handoff path)."""
        return self._apply_owner(
            u_codes >> np.int64(5 * (CODE_PRECISION - self.precision)))


def assign_shards_to_devices(counts: Sequence[int], n_devices: int
                             ) -> Tuple[List[int], List[int]]:
    """Greedy LPT bin-pack of region shards onto mesh devices by user
    count: heaviest shard first onto the least-loaded device.  Returns
    ``(assignment, load)`` — a device index per shard and the resulting
    per-device user counts.  Deterministic (ties break on ascending
    shard / device index), so every host computes the same placement;
    the mesh tick driver consumes it to build its block permutation."""
    order = sorted(range(len(counts)), key=lambda i: (-counts[i], i))
    load = [0] * n_devices
    assign = [0] * len(counts)
    for i in order:
        d = min(range(n_devices), key=lambda j: (load[j], j))
        assign[i] = d
        load[d] += counts[i]
    return assign, load


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class SelectionEngine:
    def __init__(self, *, top_n: int = 3, user_chunk: int = 8192,
                 shard_precision: Optional[int] = None):
        if shard_precision is not None and not \
                1 <= shard_precision <= PROXIMITY_PRECISION:
            raise ValueError(
                f"shard_precision must be in [1, {PROXIMITY_PRECISION}] "
                f"(got {shard_precision}) — shards are aligned to the "
                "proximity filter's geohash cells")
        self.top_n = top_n
        self.user_chunk = user_chunk        # bounds the U×N score matrices
        self.shard_precision = shard_precision
        self._cache: Dict[str, _ServiceArrays] = {}
        self._shard_cache: Dict[str, _ShardSet] = {}
        # Beacon fault domains (set by a BeaconSet): region -> serving
        # region for domains whose Beacon is down, plus the nodes no live
        # Beacon currently knows.  ``owner_version`` bumps on every
        # ownership change so shard sets (and the fused tick's static
        # routing) rebuild exactly once per handoff/re-home.
        self.hidden_nodes: frozenset = frozenset()
        self._owner: Optional[Dict[int, int]] = None
        self.owner_version = 0
        # client-side Beacon discovery latency (set by an ArmadaSystem):
        # the probe loop charges this window on bootstrap and whenever a
        # user's serving region changes (Beacon handoff/re-home) before
        # refreshing candidates from the new Beacon
        self.discovery_ms = 0.0
        # data-locality preference (set by a CargoManager): per-service
        # (replica_locs, weight) — a purely dynamic input like ``hidden``,
        # folded into the free-fraction vector so every tick path scores
        # it identically (no jit-shape or cache impact)
        self.data_locality: Dict[str, Tuple[tuple, float]] = {}
        # queueing-aware load term (serving-aware data plane): per-service
        # (weight, norm_ms) — like data_locality a purely dynamic input,
        # folded into the free-fraction vector at the single injection
        # point so every tick path scores it identically
        self.queueing: Dict[str, Tuple[float, float]] = {}
        # incremental-refresh epoch channel: a monotonic counter per
        # serving-region prefix code, bumped whenever that region's
        # schedulable node set (membership, ownership, visibility) may
        # have changed, plus a global counter for events that cannot be
        # attributed to a region (locality change, unsharded rebuilds,
        # full invalidation).  ``ClientPool._RefreshTracker`` diffs these
        # against its last-seen snapshot to decide which users to rescore.
        self.region_epoch: Dict[int, int] = {}
        self.epoch_all = 0

    # ------------------------------------------------- region dirty epochs

    def mark_all_dirty(self) -> None:
        """Bump the global refresh epoch: every user's candidates may be
        stale (events with no region attribution)."""
        self.epoch_all += 1

    def mark_regions_dirty(self, codes) -> None:
        """Bump the refresh epoch of the given *home*-region prefix codes
        (mapped through Beacon ownership, so a dead region's mark lands on
        the merged serving shard its users actually route to).  Serving
        codes are fixed points of the map, so callers may pass either."""
        owner = self._owner
        for c in codes:
            c = int(c)
            if owner:
                c = owner.get(c, c)
            self.region_epoch[c] = self.region_epoch.get(c, 0) + 1

    # ------------------------------------------------------------- caching

    def set_data_locality(self, service_id: str, replica_locs,
                          weight: float = W_DATA) -> None:
        """Data-placement update from a ``CargoManager``: the (lat, lon)
        locations of the service's alive Cargo replicas.  Nodes within
        ``DATA_LOCAL_RADIUS_KM`` of any replica gain ``weight`` on their
        Algorithm-1 score, so failover and handoff prefer nodes that can
        reach the service's store in situ (paper §3.4).  Pass an empty /
        None ``replica_locs`` to clear the preference."""
        prev = self.data_locality.get(service_id)
        if not replica_locs:
            self.data_locality.pop(service_id, None)
        else:
            self.data_locality[service_id] = (
                tuple(tuple(map(float, p)) for p in replica_locs),
                float(weight))
        if self.data_locality.get(service_id) != prev:
            # the preference shifts scores everywhere within radius of any
            # replica — no region attribution, mark globally
            self.mark_all_dirty()

    def set_queueing_awareness(self, service_id: str,
                               weight: float = W_QUEUE,
                               norm_ms: float = QUEUE_NORM_MS) -> None:
        """Enable the queueing-aware load term for a service: every
        captain's expected queueing delay (its serving profile's backlog,
        ``Captain.queueing_delay_ms``) is normalized against ``norm_ms``
        and subtracts up to ``weight`` from the Algorithm-1 score — so
        selection keeps differentiating nodes after their free fraction
        has clamped at 0 (batch slots saturated).  Pass a falsy
        ``weight`` to disable (exact pre-existing scores)."""
        prev = self.queueing.get(service_id)
        if not weight:
            self.queueing.pop(service_id, None)
        else:
            self.queueing[service_id] = (float(weight), float(norm_ms))
        if self.queueing.get(service_id) != prev:
            # backlog is per-node state with no region attribution —
            # enabling/disabling shifts scores fleet-wide
            self.mark_all_dirty()

    def set_beacon_routing(self, owner, hidden,
                           dirty_regions=None) -> None:
        """Control-plane routing update from a ``BeaconSet``.

        ``owner`` maps home region codes (Morton prefixes at
        ``shard_precision``) to the region whose live Beacon serves them;
        identity entries are dropped.  An ownership change bumps
        ``owner_version`` — shard sets rebuild lazily on the next query,
        with unchanged regions adopting their device caches, so a Beacon
        handoff never triggers a global rebuild.  ``hidden`` names nodes
        whose registration is lost (failed domain, heartbeat replay
        pending): a purely *dynamic* input — it flows through the
        schedulable mask without touching cached arrays or jit shapes."""
        owner = {int(k): int(v) for k, v in (owner or {}).items()
                 if int(k) != int(v)} or None
        if owner != self._owner:
            self._owner = owner
            self.owner_version += 1
        hidden = frozenset(hidden)
        hidden_changed = hidden != self.hidden_nodes
        self.hidden_nodes = hidden
        # refresh epochs: ``dirty_regions`` is the caller's attribution of
        # which regions' node visibility changed (a BeaconSet diffs its
        # serving map).  A visibility change without attribution must
        # still dirty *someone* — fall back to the global epoch.
        if dirty_regions:
            self.mark_regions_dirty(dirty_regions)
        elif hidden_changed and dirty_regions is None:
            self.mark_all_dirty()

    def invalidate(self, service_id: Optional[str] = None):
        """Drop cached node arrays (replica set changed).  A per-service
        invalidate keeps that service's shard set: the next query diffs
        per-shard fingerprints and rebuilds only the shards whose
        membership actually changed (the others adopt their device
        caches), so invalidation is region-routed.  A full
        ``invalidate()`` releases everything, shard sets included —
        the teardown path."""
        if service_id is None:
            self._cache.clear()
            self._shard_cache.clear()
            self.mark_all_dirty()
        else:
            self._cache.pop(service_id, None)

    def _arrays(self, service_id: str,
                tasks: Sequence[object]) -> _ServiceArrays:
        arr = self._cache.get(service_id)
        if arr is None or arr.fingerprint != _fingerprint(tasks):
            arr = _ServiceArrays(tasks)
            self._cache[service_id] = arr
            if self.shard_precision is None:
                # unsharded engines have no region diff — any replica-set
                # change dirties the whole population (the sharded path
                # attributes the change per shard in ``_shards`` below)
                self.mark_all_dirty()
        return arr

    def _shards(self, service_id: str, arr: _ServiceArrays) -> _ShardSet:
        cur = self._shard_cache.get(service_id)
        if cur is None or cur.parent_epoch != arr.epoch \
                or cur.precision != self.shard_precision \
                or cur.owner_version != self.owner_version:
            cur = _ShardSet(arr, self.shard_precision, prev=cur,
                            owner=self._owner,
                            owner_version=self.owner_version)
            self._shard_cache[service_id] = cur
            if cur.changed_codes is None:
                self.mark_all_dirty()
            elif cur.changed_codes:
                self.mark_regions_dirty(cur.changed_codes)
        return cur

    def shard_view(self, service_id: str,
                   tasks: Sequence[object]) -> Optional[_ShardSet]:
        """Current region partition of the replica set (None when the
        engine is unsharded) — the fused tick's window into the shard
        layout."""
        if self.shard_precision is None:
            return None
        return self._shards(service_id, self._arrays(service_id, tasks))

    # ------------------------------------------------------------- queries

    def candidate_list(self, service_id: str, tasks: Sequence[object],
                       user_loc, user_net: str,
                       top_n: Optional[int] = None) -> List[object]:
        """Single-user Algorithm 1 — same ranking as the scalar scorer."""
        return self.candidate_lists(service_id, tasks, [user_loc],
                                    [user_net], top_n=top_n)[0]

    def candidate_lists(self, service_id: str, tasks: Sequence[object],
                        user_locs, user_nets, top_n: Optional[int] = None,
                        ) -> List[List[object]]:
        """Batched Algorithm 1: per-user top-k over a U×N score matrix.

        ``user_locs``: sequence of (lat, lon); ``user_nets``: sequence of
        net-type strings (or a single string applied to every user).
        Returns one ranked Task list per user.  (Materializing wrapper over
        ``candidate_indices`` — the ClientPool stays in index space.)
        """
        idx = self.candidate_indices(service_id, tasks, user_locs,
                                     user_nets, top_n=top_n)
        task_seq = list(tasks)
        return [[task_seq[j] for j in row if j >= 0] for row in idx]

    def candidate_indices(self, service_id: str, tasks: Sequence[object],
                          user_locs, user_nets,
                          top_n: Optional[int] = None) -> np.ndarray:
        """Batched Algorithm 1 in index space: ``(U, k)`` int32 matrix of
        ranked positions into ``tasks``, right-padded with -1.  Same
        ranking as ``candidate_lists`` without materializing Python lists
        (the ``ClientPool`` hot path)."""
        k = top_n or self.top_n
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        u_total = len(users)
        nets = parse_nets(user_nets, u_total)
        arr = self._arrays(service_id, tasks)
        mask, free = arr.dynamic_state(self.hidden_nodes,
                                       self.data_locality.get(service_id),
                                       self.queueing.get(service_id))
        run_ix = np.nonzero(mask)[0]
        out = np.full((u_total, k), -1, np.int32)   # always (U, k)
        if run_ix.size == 0:
            return out
        kk = min(k, run_ix.size)
        if self.shard_precision is not None:
            self._indices_sharded(service_id, arr, mask, free, run_ix,
                                  users, nets, kk, out)
            return out
        for lo in range(0, u_total, self.user_chunk):
            hi = min(lo + self.user_chunk, u_total)
            out[lo:hi, :kk] = self._score_chunk(arr, run_ix, free[run_ix],
                                                users[lo:hi], nets[lo:hi],
                                                kk)
        return out

    def _indices_sharded(self, service_id: str, arr: _ServiceArrays,
                         mask: np.ndarray, free: np.ndarray,
                         run_ix: np.ndarray, users: np.ndarray,
                         nets: np.ndarray, kk: int, out: np.ndarray):
        """Region-sharded Algorithm 1: each user chunk scores only its
        home-region shard; users the in-shard proximity widening cannot
        satisfy (the border band) escalate to one cross-shard pass over
        the full node set.  Fills ``out`` in place — decision-identical
        to the unsharded chunk loop (see the module docstring for the
        nesting argument)."""
        need = min(MIN_PROXIMITY_HITS, run_ix.size)
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)
        shards = self._shards(service_id, arr)
        u_shard = shards.route(u_codes)
        sat_all = np.zeros(len(users), bool)
        for sh in shards.shards:
            sel = np.nonzero(u_shard == sh.code)[0]
            if sel.size == 0:
                continue
            run_local = np.nonzero(mask[sh.ix])[0]
            if run_local.size == 0:
                continue            # nothing running here: all border
            free_sub = free[sh.ix][run_local]
            for lo in range(0, sel.size, self.user_chunk):
                s = sel[lo:lo + self.user_chunk]
                idx, sat = self._score_shard_chunk(
                    sh, run_local, free_sub, users[s], nets[s],
                    u_codes[s], kk, need)
                rows = s[sat]
                out[rows, :kk] = idx[sat]
                sat_all[rows] = True
        border = np.nonzero(~sat_all)[0]
        for lo in range(0, border.size, self.user_chunk):
            b = border[lo:lo + self.user_chunk]
            out[b, :kk] = self._score_chunk(arr, run_ix, free[run_ix],
                                            users[b], nets[b], kk)

    def _score_shard_chunk(self, sh: _Shard, run_local: np.ndarray,
                           free: np.ndarray, users: np.ndarray,
                           nets: np.ndarray, u_codes: np.ndarray,
                           k: int, need: int
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """One user chunk against one shard, proximity filter restricted
        to ``p >= shard_precision``.  Returns ``(idx, sat)``: (U, k)
        global task positions (-1 padded) and the per-user satisfied
        mask.  Unsatisfied rows carry no result — the caller escalates
        them to the cross-shard border pass.  ``need`` is the *global*
        running-replica hit target, so a satisfied user's filter level is
        exactly the unsharded engine's."""
        child = sh.arrays
        n = run_local.size
        u = len(users)
        n_codes = child.codes[run_local]
        local = np.zeros((u, n), bool)          # no fallback in-shard
        done = np.zeros(u, bool)
        for p in range(PROXIMITY_PRECISION, self.shard_precision - 1, -1):
            shift = 5 * (CODE_PRECISION - p)
            eq = (u_codes[:, None] >> shift) == (n_codes[None, :] >> shift)
            use = (eq.sum(axis=1) >= need) & ~done
            local = np.where(use[:, None], eq, local)
            done |= use

        scores = _score_rows(child.lat[run_local], child.lon[run_local],
                             child.net_idx[run_local], free, users, nets)
        kk = min(k, n)
        order, n_local = _rank_local(scores, local, kk)
        idx = np.full((u, k), -1, np.int32)
        idx[:, :kk] = sh.ix[run_local[order]].astype(np.int32)
        idx[np.arange(k)[None, :] >= np.minimum(k, n_local)[:, None]] = -1
        return idx, done

    def _score_chunk(self, arr: _ServiceArrays, run_ix: np.ndarray,
                     free: np.ndarray, users: np.ndarray,
                     nets: np.ndarray, k: int) -> np.ndarray:
        n = run_ix.size
        u = len(users)
        n_lat = arr.lat[run_ix]
        n_lon = arr.lon[run_ix]
        n_codes = arr.codes[run_ix]
        n_net = arr.net_idx[run_ix]
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)

        # adaptive-precision proximity filter: for p = 4..1, keep replicas
        # sharing the first p geohash chars; accept the first p with enough
        # hits, else no filter (exact ``proximity_search`` semantics).
        # One (U, N) compare at a time keeps peak memory at a single tile.
        need = min(MIN_PROXIMITY_HITS, n)
        local = np.ones((u, n), bool)                 # fallback: no filter
        done = np.zeros(u, bool)
        for p in range(PROXIMITY_PRECISION, 0, -1):
            shift = 5 * (CODE_PRECISION - p)
            eq = (u_codes[:, None] >> shift) == (n_codes[None, :] >> shift)
            use = (eq.sum(axis=1) >= need) & ~done
            local = np.where(use[:, None], eq, local)
            done |= use

        scores = _score_rows(n_lat, n_lon, n_net, free, users, nets)
        order, n_local = _rank_local(scores, local, k)
        idx = run_ix[order].astype(np.int32)
        idx[np.arange(k)[None, :] >= np.minimum(k, n_local)[:, None]] = -1
        return idx

    def service_view(self, service_id: str,
                     tasks: Sequence[object]) -> _ServiceArrays:
        """Cached per-task attribute arrays (lat/lon, net, cloud/dedicated
        flags, node ids) for the current replica set — the ClientPool's
        window into task attributes without touching Task objects."""
        return self._arrays(service_id, tasks)

    # --------------------------------------------------- kernel-backed path

    def prepare_kernel_inputs(self, service_id: str,
                              tasks: Sequence[object], user_locs,
                              user_nets):
        """Pack the current replica set + a user batch into the flat arrays
        ``repro.kernels.geo_topk`` consumes (see its docstring for the
        meaning of the 20-bit codes and per-user shifts)."""
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        nets = parse_nets(user_nets, len(users))
        arr = self._arrays(service_id, tasks)
        mask, free = arr.dynamic_state(self.hidden_nodes,
                                       self.data_locality.get(service_id),
                                       self.queueing.get(service_id))
        run_ix = np.nonzero(mask)[0]
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)
        from repro.kernels.geo_topk.ops import pack_inputs
        return run_ix, pack_inputs(
            users[:, 0], users[:, 1], nets, u_codes,
            arr.lat[run_ix], arr.lon[run_ix], free[run_ix],
            arr.net_idx[run_ix], arr.codes[run_ix])

    def candidate_lists_kernel(self, service_id: str,
                               tasks: Sequence[object], user_locs,
                               user_nets, top_n: Optional[int] = None,
                               interpret: bool = False) -> List[List[object]]:
        """Batched selection through the fused geo_topk op (jnp oracle on
        CPU, Pallas kernel on TPU).  Same top-k semantics as
        ``candidate_lists``."""
        from repro.kernels.geo_topk.ops import geo_topk
        k = top_n or self.top_n
        run_ix, packed = self.prepare_kernel_inputs(service_id, tasks,
                                                    user_locs, user_nets)
        if run_ix.size == 0:
            return [[] for _ in range(len(packed.user_lat))]
        scores, idx = geo_topk(packed, k=min(k, run_ix.size),
                               interpret=interpret)
        scores = np.asarray(scores)
        idx = np.asarray(idx)
        arr = self._cache[service_id]
        return [[arr.tasks[run_ix[j]] for j, s in zip(row_i, row_s)
                 if np.isfinite(s) and s > -1e29]
                for row_i, row_s in zip(idx, scores)]

    def candidate_indices_device(self, service_id: str,
                                 tasks: Sequence[object], user_locs,
                                 user_nets, top_n: Optional[int] = None,
                                 node_pad: int = 256,
                                 interpret: bool = False):
        """Batched Algorithm 1 on device, no numpy materialization:
        returns ``(scores, idx)`` jnp arrays of shape ``(U, k_eff)``,
        ``k_eff = min(top_n, running replicas)`` — ``idx`` in task-
        position space with padding/filtered entries scoring ``NEG``
        (callers keep entries with ``score > -1e29``).  Returns ``None``
        when no replica is running.

        The node half of the query comes from the per-epoch
        ``packed_static`` cache (device-resident, zero-padded to a
        ``node_pad`` multiple so churn never changes jit shapes); only
        the (U,) user arrays and two (n_pad,) dynamic vectors cross the
        host→device boundary per call.  fp32 scoring — ranking may
        differ from the float64 numpy path at exact-tie resolution.

        With ``shard_precision`` set, each user chunk is scored against
        its home-region shard's ``packed_static`` only (one geo_topk
        invocation per shard) and the per-shard (U_s, k) results are
        merged in global task-position space; border users take one
        cross-shard pass over the full packed layout.  The sharded path
        syncs a small per-shard "satisfied" mask to the host to size the
        border pass — the fully-fused variant lives in
        ``repro.core.fused_tick``.
        """
        from repro.kernels.geo_topk.ops import (GeoTopKInputs, geo_topk,
                                                pack_user_inputs)
        k = top_n or self.top_n
        users = np.asarray(user_locs, np.float64).reshape(-1, 2)
        nets = parse_nets(user_nets, len(users))
        arr = self._arrays(service_id, tasks)
        mask, free = arr.dynamic_state(self.hidden_nodes,
                                       self.data_locality.get(service_id),
                                       self.queueing.get(service_id))
        n_run = int(mask.sum())
        if n_run == 0:
            return None
        u_codes = geohash.encode_batch(users[:, 0], users[:, 1],
                                       CODE_PRECISION)
        k_eff = min(k, n_run)
        need = min(MIN_PROXIMITY_HITS, n_run)
        if self.shard_precision is not None:
            return self._indices_device_sharded(
                service_id, arr, mask, free, users, nets, u_codes,
                k_eff, need, node_pad, interpret)
        st = arr.packed_static(node_pad)
        free_p, sched = arr.padded_sched(mask, free, node_pad)
        packed = GeoTopKInputs(
            *pack_user_inputs(users[:, 0], users[:, 1], nets, u_codes),
            st.lat, st.lon, free_p, st.aff, st.code20, sched)
        return geo_topk(packed, k=k_eff, need=need, interpret=interpret)

    def _indices_device_sharded(self, service_id: str, arr: _ServiceArrays,
                                mask: np.ndarray, free: np.ndarray,
                                users: np.ndarray, nets: np.ndarray,
                                u_codes: np.ndarray, k_eff: int, need: int,
                                node_pad: int, interpret: bool):
        """Sharded kernel-path scoring: per-shard ``geo_topk_shard`` over
        each shard's cached padded layout, border users through one full
        ``geo_topk`` pass, merged into (U, k_eff) device arrays in global
        task-position space."""
        import jax.numpy as jnp

        from repro.kernels.geo_topk.ops import (GeoTopKInputs, geo_topk,
                                                geo_topk_shard,
                                                pack_user_inputs)
        from repro.kernels.geo_topk.ref import NEG
        u_total = len(users)
        scores = jnp.full((u_total, k_eff), NEG, jnp.float32)
        idx = jnp.full((u_total, k_eff), -1, jnp.int32)
        shards = self._shards(service_id, arr)
        u_shard = shards.route(u_codes)
        sat_all = np.zeros(u_total, bool)
        # dispatch every shard's kernel before the first host sync, then
        # merge with ONE concatenated scatter — per-shard .at[].set would
        # copy the full (U, k) buffers S times and the sat sync would
        # serialize the shard launches
        parts = []
        for sh in shards.shards:
            sel = np.nonzero(u_shard == sh.code)[0]
            if sel.size == 0 or not mask[sh.ix].any():
                continue            # empty / dead shard: users go border
            st = sh.arrays.packed_static(node_pad)
            if st.n_pad < k_eff:
                continue            # shard smaller than k: border scores it
            free_p, sched = sh.padded_dynamic(mask, free, node_pad)
            packed = GeoTopKInputs(
                *pack_user_inputs(users[sel, 0], users[sel, 1], nets[sel],
                                  u_codes[sel]),
                st.lat, st.lon, free_p, st.aff, st.code20, sched)
            s, li, sat = geo_topk_shard(packed, k=k_eff, need=need,
                                        p_min=self.shard_precision,
                                        interpret=interpret)
            g = jnp.asarray(sh.task_ix_padded(node_pad))[li]
            parts.append((sel, s, g, sat))
        rows_p, s_p, g_p = [], [], []
        for sel, s, g, sat in parts:
            sat_np = np.asarray(sat)
            keep = sel[sat_np]
            if keep.size:
                rows_p.append(keep)
                s_p.append(s[sat_np])
                g_p.append(g[sat_np])
                sat_all[keep] = True
        if rows_p:
            rows = np.concatenate(rows_p)
            scores = scores.at[rows].set(jnp.concatenate(s_p))
            idx = idx.at[rows].set(jnp.concatenate(g_p).astype(jnp.int32))
        border = np.nonzero(~sat_all)[0]
        if border.size:
            st = arr.packed_static(node_pad)
            free_p, sched = arr.padded_sched(mask, free, node_pad)
            packed = GeoTopKInputs(
                *pack_user_inputs(users[border, 0], users[border, 1],
                                  nets[border], u_codes[border]),
                st.lat, st.lon, free_p, st.aff, st.code20, sched)
            s, i = geo_topk(packed, k=k_eff, need=need, interpret=interpret)
            scores = scores.at[border].set(s)
            idx = idx.at[border].set(i.astype(jnp.int32))
        return scores, idx

    def candidate_indices_kernel(self, service_id: str,
                                 tasks: Sequence[object], user_locs,
                                 user_nets, top_n: Optional[int] = None,
                                 node_pad: int = 256,
                                 interpret: bool = False) -> np.ndarray:
        """``candidate_indices`` through the fused geo_topk op — the
        ClientPool's high-throughput refresh path (fluid transport).
        Materializing wrapper over ``candidate_indices_device``."""
        k = top_n or self.top_n
        u_total = np.asarray(user_locs, np.float64).reshape(-1, 2).shape[0]
        res = self.candidate_indices_device(
            service_id, tasks, user_locs, user_nets, top_n=top_n,
            node_pad=node_pad, interpret=interpret)
        if res is None:
            return np.full((u_total, k), -1, np.int32)
        scores = np.asarray(res[0])
        idx = np.asarray(res[1])
        out = np.where(scores > -1e29, idx, -1).astype(np.int32)
        k_eff = out.shape[1]
        if k_eff < k:
            out = np.concatenate(
                [out, np.full((u_total, k - k_eff), -1, np.int32)],
                axis=1)
        return out
