"""Armada control plane: the paper's contribution (§2-§4).

Beacon (entry point) -> Application Manager (registry + auto-scaling) ->
Spinner (scheduler) -> Captains (compute nodes), plus the Cargo storage
layer and the client SDK (2-step performance-aware selection,
multi-connection fault tolerance).  A discrete-event simulator (sim.py)
provides the WAN latency / churn environment; the served models are real
JAX programs (repro.serving).
"""
from repro.core.sim import Simulator  # noqa: F401
