"""Cluster topology: node specs, network model, and the paper's testbeds.

``real_world()`` reproduces Table 5(a)/6(a): five volunteer nodes V1-V5
around campus, one dedicated 4-slot server D6, and AWS us-east as Cloud.
``emulation()`` reproduces Table 5(b)/6(b): nodes A/B/C in three cities
100-150 miles apart.  Pairwise base RTTs are set so the paper's end-to-end
tables fall out (e2e = RTT + queue + processing); jitter is added by the
simulator at request time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------


@dataclass
class NodeSpec:
    node_id: str
    loc: Tuple[float, float]                    # (lat, lon)
    proc_ms: float                              # per-frame on the ref model
    slots: int = 1                              # parallel service replicas
    dedicated: bool = False
    net_type: str = "wifi"                      # wifi | ethernet | lte
    storage_gb: float = 2.0
    layers: set = field(default_factory=set)    # artifact chunks present
    is_cloud: bool = False
    # served-model latency profile (repro.serving.profile.ServingProfile);
    # None = synthetic node whose per-request time is proc_ms exactly
    profile: Optional[object] = None


@dataclass
class Topology:
    nodes: Dict[str, NodeSpec]
    rtt_base: Dict[Tuple[str, str], float]      # one-way pairs (sym applied)
    default_rtt: float = 30.0

    def rtt(self, a: str, b: str) -> float:
        if a == b:
            return 0.5
        return self.rtt_base.get((a, b),
                                 self.rtt_base.get((b, a), self.default_rtt))

    def add_endpoint(self, node_id: str, loc, rtts: Dict[str, float],
                     net_type: str = "wifi"):
        """Register a user endpoint (no compute) with explicit RTTs."""
        self.nodes[node_id] = NodeSpec(node_id, loc, proc_ms=0.0,
                                       net_type=net_type)
        for other, ms in rtts.items():
            self.rtt_base[(node_id, other)] = ms


# ---------------------------------------------------------------------------
# Paper testbeds
# ---------------------------------------------------------------------------

_CAMPUS = (44.9740, -93.2277)                   # UMN
_US_EAST = (39.0438, -77.4874)


def _near(base, dlat, dlon):
    return (base[0] + dlat, base[1] + dlon)


def real_world() -> Topology:
    """Table 5(a): V1-V5 volunteers (<5 mi), D6 dedicated (4 slots), Cloud."""
    nodes = {
        "V1": NodeSpec("V1", _near(_CAMPUS, 0.020, 0.010), 24.0),
        "V2": NodeSpec("V2", _near(_CAMPUS, -0.030, 0.020), 32.0),
        "V3": NodeSpec("V3", _near(_CAMPUS, 0.010, -0.040), 31.0),
        "V4": NodeSpec("V4", _near(_CAMPUS, -0.050, -0.030), 45.0),
        "V5": NodeSpec("V5", _near(_CAMPUS, 0.060, 0.040), 49.0),
        "D6": NodeSpec("D6", _CAMPUS, 30.0, slots=4, dedicated=True,
                       net_type="ethernet"),
        "Cloud": NodeSpec("Cloud", _US_EAST, 34.0, slots=64, dedicated=True,
                          net_type="ethernet", is_cloud=True,
                          storage_gb=1000.0),
    }
    # Base one-way RTTs for the paper's three probe users (Table 6a minus
    # Table 5a processing times).
    rtt = {}
    table6a = {
        "C1": {"V1": 14, "V2": 15, "V3": 18, "V4": 20, "V5": 23, "D6": 12,
               "Cloud": 73},
        "C2": {"V1": 19, "V2": 3, "V3": 25, "V4": 13, "V5": 12, "D6": 14,
               "Cloud": 68},
        "C3": {"V1": 25, "V2": 18, "V3": 14, "V4": 14, "V5": 22, "D6": 12,
               "Cloud": 78},
    }
    topo = Topology(nodes, rtt)
    locs = {"C1": _near(_CAMPUS, 0.018, 0.012),
            "C2": _near(_CAMPUS, -0.028, 0.018),
            "C3": _near(_CAMPUS, 0.008, -0.036)}
    for cid, r in table6a.items():
        topo.add_endpoint(cid, locs[cid], r)
    # node-to-node RTTs (cargo reads/propagation, image prefetch).
    # Volunteer<->volunteer links ride residential uplinks (25-45 ms);
    # task-node->cargo rows are reverse-engineered from Table 7.
    rtt.update({
        ("V3", "V1"): 19.0, ("V3", "V2"): 23.0, ("V3", "D6"): 29.0,
        ("V4", "V1"): 21.0, ("V4", "V2"): 21.0, ("V4", "D6"): 31.0,
        ("V5", "V1"): 38.0, ("V5", "V2"): 36.0, ("V5", "D6"): 16.0,
        ("V1", "V2"): 32.0, ("V1", "D6"): 18.0, ("V2", "D6"): 20.0,
        ("V4", "V5"): 34.0, ("V3", "V4"): 30.0, ("V3", "V5"): 36.0,
        ("V1", "V5"): 38.0, ("V2", "V5"): 36.0, ("V2", "V4"): 28.0,
        ("V1", "V4"): 30.0, ("V2", "V3"): 23.0,
    })
    for v, ms in (("V1", 62.0), ("V2", 64.0), ("V3", 59.0), ("V4", 60.0),
                  ("V5", 58.0), ("D6", 56.0)):
        rtt[(v, "Cloud")] = ms
    return topo


def campus_users(topo: Topology, n: int, seed: int = 0) -> List[str]:
    """Recruit ``n`` heterogeneous users around campus (§6.3.1, 15 users)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    users = []
    for i in range(n):
        uid = f"U{i}"
        loc = _near(_CAMPUS, float(rng.uniform(-0.06, 0.06)),
                    float(rng.uniform(-0.06, 0.06)))
        rtts = {}
        for v in ("V1", "V2", "V3", "V4", "V5"):
            rtts[v] = float(rng.uniform(8, 28))
        rtts["D6"] = float(rng.uniform(8, 18))
        rtts["Cloud"] = float(rng.uniform(65, 95))
        topo.add_endpoint(uid, loc, rtts)
        users.append(uid)
    return users


_CITY_A = (44.9740, -93.2277)
_CITY_B = (44.0121, -92.4802)                   # ~100 mi
_CITY_C = (43.5391, -96.7311)                   # ~150 mi


def emulation() -> Topology:
    """Table 5(b)/6(b): cities A/B/C, users co-located with the nodes."""
    nodes = {
        "A": NodeSpec("A", _CITY_A, 23.0, slots=2, dedicated=True,
                      net_type="ethernet"),
        "B": NodeSpec("B", _CITY_B, 34.0, slots=1, dedicated=True,
                      net_type="ethernet"),
        "C": NodeSpec("C", _CITY_C, 58.0, slots=1, dedicated=True,
                      net_type="ethernet"),
        "Cloud": NodeSpec("Cloud", _US_EAST, 34.0, slots=64, dedicated=True,
                          net_type="ethernet", is_cloud=True,
                          storage_gb=1000.0),
    }
    rtt = {("A", "B"): 35.0, ("A", "C"): 38.0, ("B", "C"): 30.0,
           ("A", "Cloud"): 72.0, ("B", "Cloud"): 66.0, ("C", "Cloud"): 70.0}
    topo = Topology(nodes, rtt)
    table6b = {
        "User_A": {"A": 8, "B": 29, "C": 31, "Cloud": 74},
        "User_B": {"A": 40, "B": 13, "C": 25, "Cloud": 68},
        "User_C": {"A": 28, "B": 34, "C": 1, "Cloud": 77},
    }
    locs = {"User_A": _CITY_A, "User_B": _CITY_B, "User_C": _CITY_C}
    for uid, r in table6b.items():
        topo.add_endpoint(uid, locs[uid], r)
    return topo


def city_user(topo: Topology, city: str, ix: int) -> str:
    """Add another user at a given emulation city."""
    uid = f"User_{city}{ix}"
    base = {"A": {"A": 8, "B": 29, "C": 31, "Cloud": 74},
            "B": {"A": 40, "B": 13, "C": 25, "Cloud": 68},
            "C": {"A": 28, "B": 34, "C": 1, "Cloud": 77}}[city]
    locs = {"A": _CITY_A, "B": _CITY_B, "C": _CITY_C}
    topo.add_endpoint(uid, locs[city], dict(base))
    return uid
