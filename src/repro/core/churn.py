"""Online churn analysis + stability-aware scheduling (beyond-paper).

The paper's stated next step (§8): "carry out an online churn analysis to
quantify the volunteer node stability, which will play an essential part
in the placement process."  This module implements it:

* ``ChurnModel`` drives volunteer node failures/recoveries in the
  simulator from per-node exponential lifetime distributions (dedicated
  nodes get ~20× the volunteer MTTF).
* ``StabilityTracker`` observes join/leave events ONLINE and maintains a
  per-node stability score — the posterior-mean availability of an
  exponential up/down process with a Beta(2,1) prior (new nodes start
  optimistic-but-uncertain, exactly the paper's "quantify volunteer
  stability" need).
* ``stability_policy`` plugs the score into Spinner as a weighted sorting
  policy, so replicas of latency-critical services prefer stable nodes —
  measurably fewer failovers per client at equal latency
  (tests/test_churn.py).
* ``BeaconChurnModel`` extends churn to the control plane itself: it
  drives ``BeaconSet`` fault-domain failures/recoveries (multi-Beacon
  handoff + heartbeat replay) the same way ``ChurnModel`` drives node
  churn.
* ``PartitionChurnModel`` drives split-brain cuts and heals
  (``BeaconSet.partition``/``heal``) — divergence and reconciliation
  under stochastic network partitions instead of crashes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.captain import Captain
from repro.core.sim import Simulator
from repro.core.spinner import SchedulePolicy, Spinner


@dataclass
class NodeChurnStats:
    joins: int = 0
    leaves: int = 0
    up_ms: float = 0.0
    down_ms: float = 0.0
    last_change: float = 0.0
    up_now: bool = True


class StabilityTracker:
    """Online availability estimation from observed churn events."""

    def __init__(self, sim: Simulator, prior_up: float = 2.0,
                 prior_down: float = 1.0):
        self.sim = sim
        self.stats: Dict[str, NodeChurnStats] = {}
        self.prior_up = prior_up
        self.prior_down = prior_down

    def _get(self, node: str) -> NodeChurnStats:
        if node not in self.stats:
            self.stats[node] = NodeChurnStats(last_change=self.sim.now)
        return self.stats[node]

    def on_join(self, node: str):
        s = self._get(node)
        if not s.up_now:
            s.down_ms += self.sim.now - s.last_change
        s.joins += 1
        s.up_now = True
        s.last_change = self.sim.now

    def on_leave(self, node: str):
        s = self._get(node)
        if s.up_now:
            s.up_ms += self.sim.now - s.last_change
        s.leaves += 1
        s.up_now = False
        s.last_change = self.sim.now

    def availability(self, node: str) -> float:
        """Posterior-mean availability in [0, 1]; optimistic prior."""
        s = self.stats.get(node)
        if s is None:
            return self.prior_up / (self.prior_up + self.prior_down)
        up = s.up_ms + (self.sim.now - s.last_change if s.up_now else 0.0)
        down = s.down_ms + (0.0 if s.up_now else
                            self.sim.now - s.last_change)
        # scale observations to pseudo-counts (1 count per 10 s observed)
        k_up = up / 10_000.0 + self.prior_up
        k_down = down / 10_000.0 + self.prior_down
        # each leave event is strong evidence of instability
        k_down += (s.leaves if s else 0)
        return k_up / (k_up + k_down)

    def mttf_ms(self, node: str) -> Optional[float]:
        """Observed mean-time-to-failure, if any failures were seen."""
        s = self.stats.get(node)
        if not s or s.leaves == 0:
            return None
        up = s.up_ms + (self.sim.now - s.last_change if s.up_now else 0.0)
        return up / s.leaves


def stability_policy(tracker: StabilityTracker,
                     weight: float = 0.35) -> SchedulePolicy:
    """Spinner sorting policy: prefer nodes with high posterior
    availability (paper §3.3.1 'customized' policy slot)."""
    return SchedulePolicy(
        "stability",
        lambda captain, ctx: tracker.availability(captain.node_id),
        weight)


class ChurnModel:
    """Exponential up/down process per node, driven in virtual time."""

    def __init__(self, sim: Simulator, captains: Dict[str, Captain],
                 tracker: Optional[StabilityTracker] = None, *,
                 volunteer_mttf_ms: float = 60_000.0,
                 dedicated_mttf_ms: float = 1_200_000.0,
                 mttr_ms: float = 20_000.0,
                 unstable: tuple = ()):
        self.sim = sim
        self.captains = captains
        self.tracker = tracker
        self.volunteer_mttf = volunteer_mttf_ms
        self.dedicated_mttf = dedicated_mttf_ms
        self.mttr = mttr_ms
        self.unstable = set(unstable)
        self.events: List[dict] = []

    def _mttf(self, cap: Captain) -> float:
        base = self.dedicated_mttf if cap.spec.dedicated else \
            self.volunteer_mttf
        if cap.node_id in self.unstable:
            base *= 0.25
        return base

    def start(self):
        for cap in self.captains.values():
            if cap.spec.is_cloud:
                continue
            self._schedule_failure(cap)

    def _schedule_failure(self, cap: Captain):
        dt = float(self.sim.rng.exponential(self._mttf(cap)))
        self.sim.after(dt, self._fail, cap)

    def _fail(self, cap: Captain):
        if not cap.alive:
            return
        cap.fail()
        self.events.append({"t": self.sim.now, "node": cap.node_id,
                            "kind": "leave"})
        if self.tracker:
            self.tracker.on_leave(cap.node_id)
        self.sim.after(float(self.sim.rng.exponential(self.mttr)),
                       self._recover, cap)

    def _recover(self, cap: Captain):
        cap.recover()
        self.events.append({"t": self.sim.now, "node": cap.node_id,
                            "kind": "join"})
        if self.tracker:
            self.tracker.on_join(cap.node_id)
        self._schedule_failure(cap)


class BeaconChurnModel:
    """Control-plane churn: exponential fail/recover cycles per Beacon
    fault domain (paper "Armada is robust" — users must survive
    control-plane loss, not just node churn).

    Drives ``BeaconSet.fail``/``recover`` in virtual time from per-region
    exponential lifetimes, on the ``sim.substream("beacon_churn")`` RNG
    stream so enabling it never shifts data-plane jitter draws.  With
    ``spare_last`` (default) a failure that would kill the final live
    Beacon is skipped and rescheduled — total control-plane loss is an
    explicit scenario (``BeaconSet.fail`` by hand), not a default one.
    """

    def __init__(self, sim: Simulator, beacon_set, *,
                 mttf_ms: float = 600_000.0, mttr_ms: float = 30_000.0,
                 spare_last: bool = True, regions: tuple = ()):
        self.sim = sim
        self.beacons = beacon_set
        self.mttf = mttf_ms
        self.mttr = mttr_ms
        self.spare_last = spare_last
        self.regions = tuple(regions)       # default: every known domain
        self.events: List[dict] = []

    def start(self):
        rng = self.sim.substream("beacon_churn")
        codes = [self.beacons.region_code(r) for r in self.regions] \
            or list(self.beacons.replicas)
        for code in sorted(codes):
            self._schedule_failure(code, rng)

    def _schedule_failure(self, code: int, rng):
        self.sim.after(float(rng.exponential(self.mttf)),
                       self._fail, code, rng)

    def _fail(self, code: int, rng):
        rep = self.beacons.replicas.get(code)
        if rep is None:
            return
        if not rep.alive:
            # failed manually in the meantime: skip this cycle but keep
            # the region's churn process alive (a silent early return
            # would end its churn for the rest of the run)
            self._schedule_failure(code, rng)
            return
        if self.spare_last and len(self.beacons.live_regions()) <= 1:
            self._schedule_failure(code, rng)   # skip: last Beacon standing
            return
        self.beacons.fail(code)
        self.events.append({"t": self.sim.now, "kind": "beacon_fail",
                            "region": self.beacons.region_str(code)})
        self.sim.after(float(rng.exponential(self.mttr)),
                       self._recover, code, rng)

    def _recover(self, code: int, rng):
        rep = self.beacons.replicas.get(code)
        if rep is None:
            return
        if not rep.alive:                   # still down: our recovery
            self.beacons.recover(code)
            self.events.append({"t": self.sim.now, "kind": "beacon_recover",
                                "region": self.beacons.region_str(code)})
        # recovered manually or by us — either way the cycle continues
        self._schedule_failure(code, rng)


class PartitionChurnModel:
    """Stochastic split-brain: exponential partition/heal cycles per
    Beacon fault domain, the network-cut analogue of
    ``BeaconChurnModel``'s replica crashes.

    Runs on the ``sim.substream("partition_churn")`` RNG stream so
    enabling it never shifts data-plane jitter draws.  With
    ``spare_majority`` (default) a cut that would leave no live
    majority-side Beacon is skipped and rescheduled — ``BeaconSet``
    rejects such cuts anyway, and churn should never abort a run.  Each
    partition heals after an exponential ``heal_ms`` unless the replica
    failed or was healed manually meanwhile (the group-id check makes
    the heal idempotent against manual interference)."""

    def __init__(self, sim: Simulator, beacon_set, *,
                 mtbp_ms: float = 600_000.0, heal_ms: float = 30_000.0,
                 spare_majority: bool = True, regions: tuple = ()):
        self.sim = sim
        self.beacons = beacon_set
        self.mtbp = mtbp_ms                 # mean time between partitions
        self.heal = heal_ms
        self.spare_majority = spare_majority
        self.regions = tuple(regions)       # default: every known domain
        self.events: List[dict] = []

    def start(self):
        rng = self.sim.substream("partition_churn")
        codes = [self.beacons.region_code(r) for r in self.regions] \
            or list(self.beacons.replicas)
        for code in sorted(codes):
            self._schedule_cut(code, rng)

    def _schedule_cut(self, code: int, rng):
        self.sim.after(float(rng.exponential(self.mtbp)),
                       self._cut, code, rng)

    def _live_majority_without(self, code: int) -> int:
        return sum(1 for c in self.beacons.live_regions()
                   if c != code and c not in self.beacons.partition_of)

    def _cut(self, code: int, rng):
        b = self.beacons
        rep = b.replicas.get(code)
        if rep is None:
            return
        if (not rep.alive or code in b.partition_of
                or (self.spare_majority
                    and self._live_majority_without(code) < 1)):
            # dead, already cut, or would empty the majority: skip this
            # cycle but keep the region's churn process alive
            self._schedule_cut(code, rng)
            return
        gid = b.partition(code)
        self.events.append({"t": self.sim.now, "kind": "partition",
                            "region": b.region_str(code), "group": gid})
        self.sim.after(float(rng.exponential(self.heal)),
                       self._heal, code, gid, rng)

    def _heal(self, code: int, gid: int, rng):
        b = self.beacons
        if b.partition_of.get(code) == gid and code not in b._heal_pending:
            b.heal(code)
            self.events.append({"t": self.sim.now, "kind": "heal",
                                "region": b.region_str(code)})
        # else: replica died (partition collapsed) or healed manually
        self._schedule_cut(code, rng)


def data_locality_policy(cargo_manager, service_id: str,
                         topo, weight: float = 0.3) -> SchedulePolicy:
    """Paper §3.3.1 'customized' policy: data-dependent workloads prefer
    Captains near the service's Cargo replicas (pairs with
    CargoManager.cargo_discover on the read path)."""
    def score(captain, ctx) -> float:
        reps = [c for c in cargo_manager.placements.get(service_id, ())
                if c.alive]
        if not reps:
            return 0.5
        best = min(topo.rtt(captain.node_id, c.node_id) for c in reps)
        return 1.0 / (1.0 + best / 20.0)
    return SchedulePolicy("data_locality", score, weight)
