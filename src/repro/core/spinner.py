"""Spinner: the Armada scheduler / compute resource manager (paper §3.3.1).

Filter policies run sequentially (geo-proximity with adaptive radius,
resource availability); sorting policies combine via weighted scores
(resource-aware, Docker/weight-layer-aware, locality, custom).  After each
placement the un-selected candidates are told to PREFETCH the image layers
— the paper's trick for fast future auto-scaling (Fig. 9a).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import geohash
from repro.core.captain import Captain
from repro.core.cluster import Topology
from repro.core.sim import Simulator

PULL_BANDWIDTH_MBPS = 50.0         # layer pull throughput
CONTAINER_START_MS = 300.0
REGISTRATION_MS = 150.0            # lightweight Captain handshake (Fig. 9b)
K3S_REGISTRATION_MS = 350.0        # measured-in-paper comparisons
K8S_REGISTRATION_MS = 1070.0


@dataclass
class Image:
    image_id: str
    layers: List[Tuple[str, float]]          # (layer_id, size MB)

    @property
    def total_mb(self) -> float:
        return sum(mb for _, mb in self.layers)


@dataclass
class SchedulePolicy:
    name: str
    score: Callable[[Captain, dict], float]  # captain, context -> [0, 1]
    weight: float


class Spinner:
    def __init__(self, sim: Simulator, topo: Topology):
        self.sim = sim
        self.topo = topo
        self.captains: Dict[str, Captain] = {}
        self.policies: List[SchedulePolicy] = [
            SchedulePolicy("resource", self._score_resource, 0.4),
            SchedulePolicy("docker", self._score_docker, 0.3),
            SchedulePolicy("locality", self._score_locality, 0.3),
        ]
        self.prefetch_on_deploy = True
        self.deploy_log: List[dict] = []

    # --------------------------------------------------------- registration

    def captain_join(self, captain: Captain,
                     runtime: str = "armada") -> float:
        """Register a node; returns registration latency (Fig. 9b)."""
        base = {"armada": REGISTRATION_MS, "k3s": K3S_REGISTRATION_MS,
                "k8s": K8S_REGISTRATION_MS}[runtime]
        dt = self.sim.jitter(base, 0.1) + self.topo.rtt(
            captain.node_id, "Cloud") / 2
        captain.registered_at = self.sim.now + dt
        self.captains[captain.node_id] = captain
        self.sim.log("captain_join", node=captain.node_id, ms=dt)
        return dt

    def captain_update(self, node_id: str):
        pass                                   # heartbeats read on demand

    # ------------------------------------------------------------- policies

    @staticmethod
    def _score_resource(c: Captain, ctx: dict) -> float:
        # free *task slots* (placement) blended with live load (runtime)
        slot_free = max(0.0, 1.0 - len(c.tasks) / max(c.spec.slots, 1))
        return 0.6 * slot_free + 0.4 * c.free_fraction()

    @staticmethod
    def _score_docker(c: Captain, ctx: dict) -> float:
        image: Image = ctx["image"]
        if not image.layers:
            return 1.0
        have = sum(mb for lid, mb in image.layers if lid in c.spec.layers)
        return have / image.total_mb

    def _score_locality(self, c: Captain, ctx: dict) -> float:
        loc = ctx["location"]
        d = geohash.distance_km(c.spec.loc[0], c.spec.loc[1], loc[0], loc[1])
        return 1.0 / (1.0 + d / 10.0)

    def new_policy(self, policy: SchedulePolicy):
        self.policies.append(policy)

    # ------------------------------------------------------------ scheduling

    def _geo_filter(self, cands: List[Captain], loc,
                    radius_km: float = 30.0) -> List[Captain]:
        while True:
            hits = [c for c in cands if geohash.distance_km(
                c.spec.loc[0], c.spec.loc[1], loc[0], loc[1]) <= radius_km]
            if hits or radius_km > 50_000:
                return hits
            radius_km *= 2

    def select_captain(self, image: Image, location,
                       *, allow_busy: bool = True,
                       exclude: Tuple[str, ...] = (),
                       policy_filter: Optional[Callable] = None,
                       selection: str = "armada") -> Optional[Captain]:
        cands = [c for c in self.captains.values()
                 if c.alive and c.node_id not in exclude
                 and not c.spec.is_cloud]
        if policy_filter is not None:
            cands = [c for c in cands if policy_filter(c)]
        cands = self._geo_filter(cands, location)
        # resource filter: prefer captains with a free task slot
        with_slot = [c for c in cands if len(c.tasks) < c.spec.slots]
        cands = with_slot or (cands if allow_busy else [])
        if not cands:
            return None
        ctx = {"image": image, "location": location}
        if selection == "random":
            return cands[int(self.sim.rng.integers(len(cands)))]
        if selection == "anti-affinity":
            # avoid nodes already running this image's tasks
            empty = [c for c in cands if not c.tasks]
            pool = empty or cands
            return max(pool, key=lambda c: c.free_fraction())
        scored = [(sum(p.weight * p.score(c, ctx) for p in self.policies), c)
                  for c in cands]
        scored.sort(key=lambda x: -x[0])
        return scored[0][1]

    def deploy_task(self, task, image: Image, location,
                    selection: str = "armada",
                    on_ready: Optional[Callable] = None,
                    policy_filter: Optional[Callable] = None
                    ) -> Optional[float]:
        """Task_Deploy: place + pull + start. Returns deployment latency."""
        captain = self.select_captain(image, location, selection=selection,
                                      policy_filter=policy_filter)
        if captain is None:
            return None
        missing = sum(mb for lid, mb in image.layers
                      if lid not in captain.spec.layers)
        pull_ms = missing / PULL_BANDWIDTH_MBPS * 1000.0
        dt = self.sim.jitter(pull_ms + CONTAINER_START_MS, 0.05)
        task.captain = captain
        task.status = "deploying"
        captain.tasks[task.task_id] = task        # claim the slot now

        def _ready():
            if not captain.alive:
                task.status = "failed"
                captain.tasks.pop(task.task_id, None)
                return
            captain.spec.layers.update(l for l, _ in image.layers)
            task.status = "running"
            task.ready_at = self.sim.now
            if on_ready is not None:
                on_ready(task)

        self.sim.after(dt, _ready)
        self.deploy_log.append({
            "t": self.sim.now, "task": task.task_id,
            "node": captain.node_id, "ms": dt, "selection": selection,
            "pulled_mb": missing})
        if self.prefetch_on_deploy and selection == "armada":
            self._prefetch_losers(image, location, captain)
        return dt

    def _prefetch_losers(self, image: Image, location, winner: Captain):
        for c in self.captains.values():
            if c is winner or not c.alive or c.spec.is_cloud:
                continue
            missing = [l for l, _ in image.layers if l not in c.spec.layers]
            if not missing:
                continue
            mb = sum(m for l, m in image.layers if l in missing)
            self.sim.after(mb / PULL_BANDWIDTH_MBPS * 1000.0,
                           c.spec.layers.update, set(missing))

    def cancel_task(self, task):
        if task.captain is not None:
            task.captain.tasks.pop(task.task_id, None)
        task.status = "cancelled"
