"""Beacon (paper §3.1): the entry point(s), plus system assembly.

``ArmadaSystem`` wires Simulator + Topology + Spinner + ApplicationManager
+ CargoManager and exposes the three interaction surfaces the paper gives
Beacon: application deployment, user service discovery, and resource
registration.

Beacon fault domains (paper "Armada is robust", beyond the single
immortal control plane): with ``shard_precision`` set, a ``BeaconSet``
runs one ``Beacon`` replica per coarse geohash region — the same regions
the ``SelectionEngine`` shards by — and each replica owns its region's
node registrations and (through the engine's per-region ``_ShardSet``)
its shard's node arrays.  Killing a replica (``fail_beacon``) loses its
registration state:

* its nodes become control-plane *hidden* — alive on the data plane
  (warm connections and in-flight frames continue) but unschedulable —
  until each Captain's heartbeat replay re-registers it with the
  nearest live Beacon;
* its *users* hand off: the engine's ownership map re-points the dead
  region at the adopting Beacon, so every batched tick path (numpy,
  kernel, fused device) routes those user chunks to the adopting
  Beacon's merged shard, with the existing border-band escalation
  covering cross-domain queries;
* on ``recover_beacon`` the ownership map reverts (users re-home
  immediately — the adopted nodes stay visible through the surviving
  replica until they re-home at their next heartbeat).

Network partitions (split-brain) are a separate fault from replica
death: ``BeaconSet.partition`` cuts one or more regions' replicas off
from the majority WITHOUT killing them.  A partitioned replica keeps
accepting registrations and (staged) deployments from the Captains on
its side, so registration state *diverges*; the majority re-homes the
cut domain's users through the same ownership map a failure uses.
``heal`` merges the divergent logs — last-writer-wins on heartbeat
sequence for node registrations, staged task spawns applied or dropped
as conflicts — and reverts ownership with a single engine push (at most
one fused-tick retrace).  See ``docs/partition_tolerance.md``.

See ``docs/beacon_fault_domains.md`` for the ownership/handoff map and
``benchmarks/bench_beacon_failover.py`` for the measured unavailability
window.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import geohash
from repro.core.app_manager import ApplicationManager, ServiceSpec
from repro.core.captain import Captain
from repro.core.client import Client
from repro.core.client_pool import ClientPool
from repro.core.cluster import Topology
from repro.core.sim import Simulator
from repro.core.spinner import Image, Spinner
from repro.core.storage.cargo import Cargo
from repro.core.storage.cargo_manager import CargoManager

HEARTBEAT_MS = 1000.0      # Captain -> Beacon heartbeat period (replay lag)
RECONCILE_BASE_MS = 50.0       # heal: fixed log-exchange round trip
RECONCILE_PER_ENTRY_MS = 0.5   # heal: per divergence-log-entry merge cost


class BeaconUnavailableError(RuntimeError):
    """The addressed Beacon replica is down (its fault domain failed).

    Batched pool queries never see this — the ``BeaconSet`` ownership map
    hands their region off to the nearest live replica inside the
    selection engine — but direct calls against a dead replica fail
    loudly instead of serving stale registration state."""


class Beacon:
    """Request router: forwards to the right handler component.

    One instance is either the global entry point (``region=None``, the
    unsharded system) or a per-region replica inside a ``BeaconSet``
    (``region`` = Morton prefix code of its fault domain).  A replica
    owns the node registrations of its domain (``registered_nodes``);
    killing it loses that state until heartbeat replay rebuilds it on a
    surviving replica."""

    def __init__(self, am: ApplicationManager, spinner: Spinner,
                 cargo_manager: CargoManager, *,
                 region: Optional[int] = None,
                 region_str: Optional[str] = None):
        self.am = am
        self.spinner = spinner
        self.cargo_manager = cargo_manager
        self.region = region
        self.region_str = region_str
        self.alive = True
        self.registered_nodes: Dict[str, Captain] = {}
        # ---- split-brain state (only a BeaconSet replica uses these) ----
        self.partitioned = False
        # divergence log: registrations accepted while cut off
        self.reg_log: List[dict] = []
        # node -> last registration sequence this replica saw (LWW merge)
        self.hb_last: Dict[str, int] = {}
        # deploys accepted while cut off, applied (or dropped) at heal
        self.pending_tasks: List[object] = []

    def _check_alive(self):
        if not self.alive:
            raise BeaconUnavailableError(
                f"Beacon replica {self.region_str or self.region!r} is "
                "down — route through BeaconSet.beacon_for (pools hand "
                "off automatically via the engine's ownership map)")

    # the three public surfaces (paper §3.1)
    def deploy_application(self, spec: ServiceSpec, **kw):
        self._check_alive()
        return self.am.deploy_service(spec, **kw)

    def query_service(self, service_id: str, user_loc, user_net: str):
        self._check_alive()
        return self.am.candidate_list(service_id, user_loc, user_net)

    def query_service_batch(self, service_id: str, user_locs, user_nets):
        """Batched service discovery: one vectorized selection pass over a
        whole user population; returns one ranked Task list per user."""
        self._check_alive()
        return self.am.candidate_lists(service_id, user_locs, user_nets)

    def query_service_indices(self, service_id: str, user_locs, user_nets):
        """Index-space batched discovery for pools: (U, k) int32 positions
        into the service's task list, padded with -1."""
        self._check_alive()
        return self.am.candidate_indices(service_id, user_locs, user_nets)

    def register_node(self, captain: Captain, runtime: str = "armada"):
        self._check_alive()
        self.registered_nodes[captain.node_id] = captain
        return self.spinner.captain_join(captain, runtime)

    def register_task(self, task):
        """Out-of-band replica spawn through this entry point.  A
        partitioned replica cannot reach the global engine state, so it
        *stages* the spawn in its divergence log; the heal-time
        reconciliation applies it (or drops it as a conflict if the
        majority side placed the same service on that Captain
        meanwhile)."""
        self._check_alive()
        if self.partitioned:
            self.pending_tasks.append(task)
            self.am.sim.log("partition_stage", region=self.region_str,
                            task=task.task_id)
            return task
        self.am.register_task(task)
        return task

    def register_cargo(self, cargo: Cargo):
        self._check_alive()
        return self.cargo_manager.cargo_join(cargo)


class BeaconSet:
    """Per-region Beacon replicas as injectable fault domains.

    Each replica serves one Morton-prefix region at the engine's
    ``shard_precision``.  The set maintains two pieces of control-plane
    state and pushes both into the ``SelectionEngine`` on every change
    (``set_beacon_routing``):

    * the **ownership map** — home region -> serving region.  Identity
      while a region's own Beacon is alive; on ``fail`` the dead domain
      is re-pointed at the nearest live replica (haversine between
      region cell centers, lowest code on ties), which merges its shard
      arrays and serves its users' queries (the handoff path).  Reverts
      on ``recover``.
    * the **hidden set** — nodes whose registration was lost with their
      Beacon and has not been replayed yet.  Each Captain re-registers
      with the serving replica at its next heartbeat (staggered over
      ``heartbeat_ms`` on the ``sim.substream("beacon")`` stream, so
      injection never shifts data-plane RNG); visibility converges
      node-by-node with no global rebuild.

    ``events`` records the full fail/replay/recover timeline —
    ``benchmarks/bench_beacon_failover.py`` derives the
    selection-unavailability window from it.
    """

    def __init__(self, sim: Simulator, am: ApplicationManager,
                 spinner: Spinner, cargo_manager: CargoManager, *,
                 shard_precision: int,
                 heartbeat_ms: float = HEARTBEAT_MS):
        self.sim = sim
        self.am = am
        self.spinner = spinner
        self.cargo_manager = cargo_manager
        self.precision = int(shard_precision)
        self.heartbeat_ms = heartbeat_ms
        self.replicas: Dict[int, Beacon] = {}
        self.home: Dict[str, int] = {}      # node -> home region code
        # node -> region whose live Beacon knows it (None = lost/hidden)
        self.serving: Dict[str, Optional[int]] = {}
        self.events: List[dict] = []
        self._centroids: Dict[int, tuple] = {}
        # ---------------- split-brain (partition) state -----------------
        # region code -> reachability group id (>0); absent = majority (0)
        self.partition_of: Dict[int, int] = {}
        self._next_gid = 1
        # node -> global monotonic registration sequence (LWW clock)
        self.hb_seq: Dict[str, int] = {}
        self._heal_pending: set = set()
        # last-pushed visibility map (node -> (serving, group)) — diffed
        # in ``_push`` to attribute refresh-epoch marks to regions
        self._last_serving: Optional[Dict[str, tuple]] = None

    # ---------------------------------------------------------- regions

    def region_code(self, region) -> int:
        """Coerce a region spec to a Morton prefix code: a base32 geohash
        prefix (exactly ``shard_precision`` chars), a prefix code int, or
        a (lat, lon) location."""
        if isinstance(region, str):
            if len(region) != self.precision:
                raise ValueError(
                    f"region prefix {region!r} must be exactly "
                    f"{self.precision} geohash chars")
            return geohash.str_to_code(region)
        if isinstance(region, (int, np.integer)):
            return int(region)
        lat, lon = region
        return int(geohash.encode_batch(
            np.asarray([lat]), np.asarray([lon]), self.precision)[0])

    def region_str(self, code: int) -> str:
        return geohash.code_to_str(int(code), self.precision)

    def _centroid(self, code: int) -> tuple:
        c = self._centroids.get(code)
        if c is None:
            lat, lon, _, _ = geohash.decode(self.region_str(code))
            c = (lat, lon)
            self._centroids[code] = c
        return c

    def replica(self, code: int) -> Beacon:
        rep = self.replicas.get(int(code))
        if rep is None:
            rep = Beacon(self.am, self.spinner, self.cargo_manager,
                         region=int(code),
                         region_str=self.region_str(code))
            self.replicas[int(code)] = rep
        return rep

    def live_regions(self) -> List[int]:
        return [c for c, r in self.replicas.items() if r.alive]

    def busiest_region(self) -> str:
        """Geohash prefix of the region homing the most Captains —
        killing it maximizes the blast radius (the canonical
        fault-injection target; ties break on the lowest code so the
        benchmark and the test harness always kill the same domain)."""
        counts: Dict[int, int] = {}
        for code in self.home.values():
            counts[code] = counts.get(code, 0) + 1
        if not counts:
            raise ValueError("busiest_region: no Captains registered")
        return self.region_str(max(sorted(counts), key=lambda c: counts[c]))

    def _coerce_regions(self, regions) -> List[int]:
        """A region spec, a (lat, lon) pair, or an iterable of either —
        normalized to a list of prefix codes."""
        if isinstance(regions, (str, int, np.integer)):
            return [self.region_code(regions)]
        regions = list(regions)
        if (len(regions) == 2
                and all(isinstance(x, (float, np.floating))
                        for x in regions)):
            return [self.region_code(tuple(regions))]   # one (lat, lon)
        return [self.region_code(r) for r in regions]

    def group_of(self, code: int) -> int:
        """Reachability group of a region: 0 = majority, >0 = the
        partition group it was cut into."""
        return self.partition_of.get(int(code), 0)

    def owner_of(self, code: int, group: int = 0) -> Optional[int]:
        """The region whose live Beacon serves ``code``'s domain *within
        a reachability group*: itself while up and in-group, else the
        nearest live in-group region (ties -> lowest code); None when the
        group has no live Beacon.  ``group=0`` (the majority side) is
        what user routing and the engine ownership map use; a partitioned
        Captain resolves against its own side's group."""
        code = int(code)
        rep = self.replicas.get(code)
        if rep is not None and rep.alive and self.group_of(code) == group:
            return code
        live = [c for c in self.live_regions() if self.group_of(c) == group]
        if not live:
            return None
        lat, lon = self._centroid(code)
        return min(live, key=lambda c: (geohash.distance_km(
            lat, lon, *self._centroid(c)), c))

    def beacon_for(self, loc) -> Beacon:
        """The replica serving a location — home if alive, else the
        nearest live one on the same side of any partition (a bootstrap
        lookup from inside a cut-off region reaches that side's replica,
        not the unreachable majority)."""
        code = self.region_code(tuple(loc))
        owner = self.owner_of(code, group=self.group_of(code))
        if owner is None:
            raise BeaconUnavailableError(
                "no live Beacon replica in any region")
        return self.replica(owner)

    # ----------------------------------------------------- registration

    def _record(self, rep: Beacon, node_id: str):
        """Stamp a registration on ``rep`` with the next global sequence
        number (the LWW clock for heal-time merges); while the replica is
        partitioned the entry also lands in its divergence log."""
        seq = self.hb_seq.get(node_id, 0) + 1
        self.hb_seq[node_id] = seq
        rep.hb_last[node_id] = seq
        if rep.partitioned:
            rep.reg_log.append({"t": self.sim.now, "node": node_id,
                                "seq": seq})

    def register_node(self, captain: Captain, runtime: str = "armada"):
        """Home a Captain in its region's fault domain and register it
        with the replica currently serving that domain.  A Captain
        joining inside a partitioned region registers with its side's
        replica — it stays hidden from the majority until heal."""
        code = self.region_code(tuple(captain.spec.loc))
        self.replica(code)                  # domain exists even if empty
        self.home[captain.node_id] = code
        group = self.group_of(code)
        owner = self.owner_of(code, group=group)
        if owner is None:
            self.serving[captain.node_id] = None
            self._push()
            return None
        rep = self.replica(owner)
        self.serving[captain.node_id] = owner
        dt = rep.register_node(captain, runtime)
        self._record(rep, captain.node_id)
        if group != 0:
            rng = self.sim.substream("beacon")
            self.sim.after(float(rng.uniform(0.0, self.heartbeat_ms)),
                           self._partition_heartbeat,
                           captain.node_id, group)
        self._push()
        return dt

    # -------------------------------------------------- fail / recover

    def fail(self, region):
        """Kill a region's Beacon replica: its registration state is
        lost (nodes it served go hidden until heartbeat replay lands
        them on the serving replica) and its users hand off to the
        nearest live Beacon through the engine ownership map."""
        code = self.region_code(region)
        rep = self.replicas.get(code)
        if rep is None or not rep.alive:
            known = sorted(self.region_str(c) for c in self.live_regions())
            raise ValueError(
                f"fail_beacon: no live Beacon for region "
                f"{self.region_str(code)!r} (live: {known})")
        rep.alive = False
        rep.registered_nodes.clear()
        if code in self.partition_of:
            # a partitioned replica dying collapses the split-brain into
            # a plain failure: its divergence log dies with it
            self.partition_of.pop(code, None)
            self._heal_pending.discard(code)
            rep.partitioned = False
            rep.reg_log.clear()
            rep.pending_tasks.clear()
        self.sim.log("beacon_fail", region=rep.region_str)
        self.events.append({"t": self.sim.now, "kind": "beacon_fail",
                            "region": rep.region_str})
        lost = sorted(n for n, s in self.serving.items() if s == code)
        rng = self.sim.substream("beacon")
        for node in lost:
            self.serving[node] = None
            # replay at the Captain's next heartbeat (uniform phase)
            self.sim.after(float(rng.uniform(0.0, self.heartbeat_ms)),
                           self._reregister, node)
        owner = self.owner_of(code, group=0)
        if owner is not None:
            # the adopting region inherits this domain's users — give
            # them a nearby data replica too (no-op without stores)
            self.cargo_manager.on_domain_handoff(self._centroid(owner))
        self._push()

    def recover(self, region):
        """Bring a region's Beacon back.  Ownership (and user routing)
        reverts immediately; its nodes re-home from the adopting replica
        at their next heartbeat — they stay visible through the adopter
        meanwhile, so recovery has no second unavailability dip."""
        code = self.region_code(region)
        rep = self.replicas.get(code)
        if rep is None or rep.alive:
            raise ValueError(
                f"recover_beacon: Beacon for region "
                f"{self.region_str(code)!r} is not down")
        rep.alive = True
        self.sim.log("beacon_recover", region=rep.region_str)
        self.events.append({"t": self.sim.now, "kind": "beacon_recover",
                            "region": rep.region_str})
        rng = self.sim.substream("beacon")
        for node in sorted(n for n, h in self.home.items()
                           if h == code and self.serving.get(n) != code):
            self.sim.after(float(rng.uniform(0.0, self.heartbeat_ms)),
                           self._rehome, node)
        self._push()

    # ------------------------------------------------ partition / heal

    def partition(self, regions) -> int:
        """Cut one or more regions' replicas off from the majority
        (split-brain) WITHOUT killing them.  Returns the reachability
        group id.

        Majority side: the cut domains' users hand off through the
        ownership map exactly like a failure, their nodes go hidden, and
        the ``CargoManager`` re-places data replicas near each adopting
        region.  Minority side: each cut replica keeps serving its own
        Captains — registrations and staged deploys accumulate in its
        divergence log until ``heal``."""
        codes = self._coerce_regions(regions)
        for code in codes:
            rep = self.replicas.get(code)
            if rep is None or not rep.alive:
                known = sorted(self.region_str(c)
                               for c in self.live_regions())
                raise ValueError(
                    f"partition: no live Beacon for region "
                    f"{self.region_str(code)!r} (live: {known})")
            if code in self.partition_of:
                raise ValueError(
                    f"partition: region {self.region_str(code)!r} is "
                    "already partitioned — heal it first")
        majority = [c for c in self.live_regions()
                    if c not in self.partition_of and c not in codes]
        if not majority:
            raise ValueError(
                "partition: refusing to cut off every majority region — "
                "at least one live group-0 Beacon must remain")
        gid = self._next_gid
        self._next_gid += 1
        rng = self.sim.substream("beacon")
        for code in codes:
            self.partition_of[code] = gid
            rep = self.replicas[code]
            rep.partitioned = True
            self.sim.log("beacon_partition", region=rep.region_str,
                         group=gid)
            self.events.append({"t": self.sim.now,
                                "kind": "beacon_partition",
                                "region": rep.region_str, "group": gid})
        cut = set(codes)
        for node, home in sorted(self.home.items()):
            cur = self.serving.get(node)
            if home in cut:
                # the Captain is physically on the minority side: its
                # heartbeats reach only its home replica from now on.
                # If a majority adopter was serving it, that adopter
                # keeps a now-stale record (divergence, resolved by LWW
                # at heal).
                rep = self.replicas[home]
                cap = self.spinner.captains.get(node)
                if cap is not None:
                    rep.registered_nodes[node] = cap
                self.serving[node] = home
                self._record(rep, node)
                self.sim.after(
                    float(rng.uniform(0.0, self.heartbeat_ms)),
                    self._partition_heartbeat, node, gid)
            elif cur in cut:
                # majority-side Captain adopted by a now-cut replica:
                # unreachable — hidden until heartbeat replay lands it
                # on a majority Beacon (the minority keeps its stale
                # record for LWW).
                self.serving[node] = None
                self.sim.after(
                    float(rng.uniform(0.0, self.heartbeat_ms)),
                    self._reregister, node)
        for code in codes:
            owner = self.owner_of(code, group=0)
            if owner is not None:
                self.cargo_manager.on_domain_handoff(
                    self._centroid(owner))
        self._push()
        return gid

    def _partition_heartbeat(self, node_id: str, gid: int):
        """Minority-side heartbeat: while the partition holds, a cut-off
        Captain keeps refreshing its registration on its home replica,
        advancing its LWW sequence (so at heal the side that actually
        heard the node last wins the merge)."""
        home = self.home.get(node_id)
        if home is None or self.partition_of.get(home) != gid:
            return                          # healed / collapsed meanwhile
        rep = self.replicas.get(home)
        if rep is None or not rep.alive:
            return
        cap = self.spinner.captains.get(node_id)
        if cap is not None and cap.alive:
            rep.registered_nodes[node_id] = cap
            self.serving[node_id] = home
            self._record(rep, node_id)
        self.sim.after(self.heartbeat_ms, self._partition_heartbeat,
                       node_id, gid)

    def heal(self, regions=None) -> float:
        """Reconnect partitioned regions (all of them by default).  The
        replicas first exchange divergence logs — a latency of
        ``RECONCILE_BASE_MS + RECONCILE_PER_ENTRY_MS × divergence`` —
        then ``_reconcile`` merges state and reverts ownership in one
        engine push.  Until the merge lands, routing still treats the
        regions as cut (that window IS the reconciliation latency the
        benchmark measures).  Returns the scheduled exchange delay."""
        if regions is None:
            codes = sorted(self.partition_of)
        else:
            codes = self._coerce_regions(regions)
        if not codes:
            raise ValueError("heal: no region is partitioned")
        for code in codes:
            if code not in self.partition_of:
                raise ValueError(
                    f"heal: region {self.region_str(code)!r} is not "
                    "partitioned")
            if code in self._heal_pending:
                raise ValueError(
                    f"heal: region {self.region_str(code)!r} is already "
                    "reconciling")
        divergence = sum(
            len(self.replicas[c].reg_log)
            + len(self.replicas[c].pending_tasks) for c in codes)
        delay = RECONCILE_BASE_MS + RECONCILE_PER_ENTRY_MS * divergence
        self._heal_pending.update(codes)
        self.sim.log("beacon_heal",
                     regions=[self.region_str(c) for c in codes],
                     divergence=divergence)
        self.events.append({"t": self.sim.now, "kind": "beacon_heal",
                            "regions": [self.region_str(c)
                                        for c in codes],
                            "divergence": divergence})
        self.sim.after(delay, self._reconcile, codes, self.sim.now)
        return delay

    def _reconcile(self, codes: List[int], heal_t: float):
        """Merge a healed partition's divergent state back into the
        majority:

        * node registrations — last-writer-wins on the heartbeat
          sequence: whichever replica heard the node most recently keeps
          it, every other holder drops its stale record;
        * staged task spawns — applied through the ApplicationManager
          (one engine invalidation each, shapes stay within the node
          pad) unless the Captain died or the majority placed the same
          service there meanwhile (a conflict, dropped and logged).

        One ``_push`` at the end reverts ownership and un-hides the
        minority's nodes: at most one fused-tick retrace per heal."""
        lww = conflicts = applied = 0
        divergence = sum(
            len(self.replicas[c].reg_log)
            + len(self.replicas[c].pending_tasks) for c in codes)
        for code in codes:
            rep = self.replicas[code]
            rep.partitioned = False
            self.partition_of.pop(code, None)
            self._heal_pending.discard(code)
        for code in codes:
            rep = self.replicas[code]
            for node in sorted(rep.registered_nodes):
                holders = [(c, r) for c, r in self.replicas.items()
                           if r.alive and node in r.registered_nodes]
                if len(holders) <= 1:
                    continue
                winner_code, winner = max(
                    holders, key=lambda cr: (cr[1].hb_last.get(node, 0),
                                             -cr[0]))
                for c, r in holders:
                    if r is not winner:
                        r.registered_nodes.pop(node, None)
                self.serving[node] = winner_code
                lww += 1
            for task in rep.pending_tasks:
                cap = task.captain
                if cap is None or not cap.alive:
                    conflicts += 1
                    self.sim.log("reconcile_conflict", task=task.task_id,
                                 reason="captain_dead")
                    continue
                existing = self.am.tasks.get(task.service_id, ())
                if any(t.captain is cap and t.status == "running"
                       for t in existing):
                    conflicts += 1
                    self.sim.log("reconcile_conflict", task=task.task_id,
                                 reason="duplicate_placement")
                    continue
                task.status = "running"
                task.ready_at = self.sim.now
                cap.tasks[task.task_id] = task
                self.am.register_task(task)
                applied += 1
            rep.reg_log.clear()
            rep.pending_tasks.clear()
        latency = self.sim.now - heal_t
        self.sim.log("beacon_reconcile",
                     regions=[self.region_str(c) for c in codes],
                     divergence=divergence, lww=lww,
                     conflicts=conflicts, staged=applied,
                     latency_ms=latency)
        self.events.append({"t": self.sim.now, "kind": "beacon_reconcile",
                            "regions": [self.region_str(c)
                                        for c in codes],
                            "divergence": divergence, "lww": lww,
                            "conflicts": conflicts, "staged": applied,
                            "latency_ms": latency})
        self._push()

    def _reregister(self, node_id: str):
        """Heartbeat replay: a Captain that lost its Beacon registers
        with the replica currently serving its home domain."""
        if self.serving.get(node_id) is not None:
            return                          # already replayed elsewhere
        cap = self.spinner.captains.get(node_id)
        if cap is None:
            return                          # node left the cluster for good
        if not cap.alive:
            # the node itself is churned out right now; its heartbeats
            # resume when it recovers — keep polling at heartbeat cadence
            self.sim.after(self.heartbeat_ms, self._reregister, node_id)
            return
        home = self.home[node_id]
        target = self.owner_of(home, group=self.group_of(home))
        if target is None:                  # still no live Beacon: retry
            self.sim.after(self.heartbeat_ms, self._reregister, node_id)
            return
        rep = self.replica(target)
        rep.registered_nodes[node_id] = cap
        self.serving[node_id] = target
        self._record(rep, node_id)
        self.sim.log("beacon_reregister", node=node_id,
                     region=rep.region_str)
        self.events.append({"t": self.sim.now, "kind": "reregister",
                            "node": node_id, "region": rep.region_str})
        self._push()

    def _rehome(self, node_id: str):
        """Post-recovery heartbeat: move a Captain's registration from
        the adopting replica back to its (now live) home Beacon."""
        home = self.home[node_id]
        rep = self.replicas.get(home)
        if rep is None or not rep.alive:
            return                          # home died again meanwhile
        cur = self.serving.get(node_id)
        if cur == home:
            return
        cap = self.spinner.captains.get(node_id)
        if cap is None:
            return                          # left the cluster for good
        if not cap.alive:
            # node is churned out right now — don't touch its adopted
            # registration (it must stay non-hidden for when it returns);
            # re-home at a later heartbeat instead
            self.sim.after(self.heartbeat_ms, self._rehome, node_id)
            return
        if cur is not None:
            cross = self.group_of(cur) != self.group_of(home)
            if not cross:
                self.replica(cur).registered_nodes.pop(node_id, None)
            # across a partition the adopter is unreachable: its stale
            # record stays until heal-time LWW drops it
        rep.registered_nodes[node_id] = cap
        self.serving[node_id] = home
        self._record(rep, node_id)
        self.events.append({"t": self.sim.now, "kind": "rehome",
                            "node": node_id, "region": rep.region_str})
        self._push()

    # ------------------------------------------------------- engine push

    def hidden_nodes(self) -> frozenset:
        """Nodes invisible to majority-side selection: registration lost
        (``serving is None``) or only reachable through a partitioned
        replica (serving region's group != 0)."""
        return frozenset(n for n, s in self.serving.items()
                         if s is None or self.group_of(s) != 0)

    def ownership(self) -> Dict[int, int]:
        """Non-identity region -> serving-region entries: dead domains
        AND partitioned domains (whose users the majority re-homes the
        same way); regions with no live majority owner are omitted —
        their nodes are hidden anyway and their users fall to the border
        pass."""
        out = {}
        for code, rep in self.replicas.items():
            if rep.alive and self.group_of(code) == 0:
                continue
            owner = self.owner_of(code, group=0)
            if owner is not None:
                out[code] = owner
        return out

    def _push(self):
        # attribute node-visibility changes to regions for the engine's
        # incremental-refresh epochs: any node whose serving entry moved
        # (registered, lost, re-registered, re-homed) dirties its home
        # region and both serving regions — exactly the shards whose
        # schedulable set the change can touch
        vis = {n: (s, self.group_of(s) if s is not None else -1)
               for n, s in self.serving.items()}
        regions = set()
        if self._last_serving is not None:
            for n in vis.keys() | self._last_serving.keys():
                if vis.get(n) != self._last_serving.get(n):
                    for r in (self.home.get(n),
                              vis.get(n, (None,))[0],
                              self._last_serving.get(n, (None,))[0]):
                        if r is not None:
                            regions.add(r)
        self._last_serving = vis
        self.am.engine.set_beacon_routing(self.ownership(),
                                          self.hidden_nodes(),
                                          dirty_regions=sorted(regions))

    def convergence_ms(self, fail_t: float) -> float:
        """Selection-unavailability window of the failure at ``fail_t``:
        time until the last lost Captain re-registered (after which every
        pre-failure node is schedulable again).  Bounded at the NEXT
        ``beacon_fail`` event, so replays belonging to a later, unrelated
        failure never inflate this window."""
        replays = []
        for e in self.events:
            if e["t"] < fail_t:
                continue
            if e["kind"] == "beacon_fail" and e["t"] > fail_t:
                break                       # a later failure's replays
            if e["kind"] == "reregister":
                replays.append(e["t"])
        return (max(replays) - fail_t) if replays else float("nan")


class ArmadaSystem:
    """Fully wired Armada instance over a Topology."""

    def __init__(self, topo: Topology, *, seed: int = 0,
                 compute_nodes: Optional[List[str]] = None,
                 cargo_nodes: Optional[List[str]] = None,
                 include_cloud_compute: bool = True,
                 trace_enabled: bool = True,
                 shard_precision: Optional[int] = None,
                 beacon_heartbeat_ms: float = HEARTBEAT_MS,
                 discovery_ms: float = 0.0):
        self.sim = Simulator(seed=seed, trace_enabled=trace_enabled)
        self.topo = topo
        self.spinner = Spinner(self.sim, topo)
        self.cargo_manager = CargoManager(self.sim, topo)
        self.am = ApplicationManager(self.sim, topo, self.spinner,
                                     self.cargo_manager,
                                     shard_precision=shard_precision)
        # storage placements feed the selection score (data locality)
        self.cargo_manager.attach_engine(self.am.engine)
        # client-side Beacon discovery window: charged by every
        # ClientPool on bootstrap and on handoff-driven re-discovery
        self.discovery_ms = float(discovery_ms)
        self.am.engine.discovery_ms = self.discovery_ms
        self.beacon = Beacon(self.am, self.spinner, self.cargo_manager)
        # region-sharded systems get per-region Beacon fault domains; the
        # global facade above still serves deployment/bootstrap calls
        self.beacons: Optional[BeaconSet] = None
        if shard_precision is not None:
            self.beacons = BeaconSet(self.sim, self.am, self.spinner,
                                     self.cargo_manager,
                                     shard_precision=shard_precision,
                                     heartbeat_ms=beacon_heartbeat_ms)
        self.captains: Dict[str, Captain] = {}
        self.cargos: Dict[str, Cargo] = {}

        names = compute_nodes if compute_nodes is not None else [
            n for n, s in topo.nodes.items() if s.proc_ms > 0]
        for name in names:
            spec = topo.nodes[name]
            if spec.is_cloud and not include_cloud_compute:
                continue
            cap = Captain(self.sim, topo, spec)
            self.captains[name] = cap
            if self.beacons is not None:
                self.beacons.register_node(cap)
            else:
                self.beacon.register_node(cap)
        for name in (cargo_nodes or []):
            cg = Cargo(self.sim, topo, topo.nodes[name])
            self.cargos[name] = cg
            self.beacon.register_cargo(cg)

    # ------------------------------------------------------------- helpers

    def make_client(self, client_id: str, service_id: str, **kw) -> Client:
        return Client(self.sim, self.topo, self.am, client_id, service_id,
                      **kw)

    def make_client_pool(self, service_id: str, **kw) -> ClientPool:
        """Vectorized population: pass ``client_ids=[...]`` for Topology
        endpoints (scalar-parity events transport) or ``locs=(U, 2)`` for
        synthetic users (fluid transport at scale)."""
        return ClientPool(self.sim, self.topo, self.am, service_id, **kw)

    def ensure_cloud_replica(self, service_id: str):
        """The paper's cloud baseline assumes an always-available cloud
        deployment; Armada's own scheduler never places on the cloud.
        Registration routes through ``ApplicationManager.register_task``
        so the selection engine's device-resident node caches are
        invalidated like any other replica-set change (appending to
        ``am.tasks`` directly would leave a stale ``packed_static`` to
        whatever path skips the lazy fingerprint check)."""
        from repro.core.app_manager import Task
        cloud = next((c for c in self.captains.values()
                      if c.spec.is_cloud), None)
        if cloud is None:
            return None
        task = Task(f"{service_id}/cloud", service_id, captain=cloud,
                    status="running", ready_at=self.sim.now)
        cloud.tasks[task.task_id] = task
        self.am.register_task(task)
        return task

    def fail_node(self, name: str, at_ms: float):
        """Schedule a node failure.  Unknown names raise immediately;
        failing a node that is already down when the event fires raises
        instead of silently re-running ``Captain.fail``'s no-op branch —
        the scenario author almost certainly meant a different node or
        forgot a recovery (``ChurnModel`` drives overlapping churn with
        its own alive guard and is unaffected)."""
        if name not in self.captains:
            known = sorted(self.captains)
            raise ValueError(
                f"fail_node: unknown node {name!r} — known compute nodes: "
                f"{known[:8]}{'...' if len(known) > 8 else ''}")
        self.sim.at(at_ms, self._fail_captain, name)

    def _fail_captain(self, name: str):
        cap = self.captains[name]
        if not cap.alive:
            raise RuntimeError(
                f"fail_node({name!r}): node is already failed at "
                f"t={self.sim.now:.1f} ms — schedule a recovery first, "
                "or use ChurnModel for overlapping fail/recover cycles")
        cap.fail()

    def fail_beacon(self, region, at_ms: float):
        """Schedule a Beacon fault-domain failure (``region``: geohash
        prefix string at shard_precision, prefix code, or (lat, lon))."""
        if self.beacons is None:
            raise RuntimeError(
                "fail_beacon needs Beacon fault domains — construct "
                "ArmadaSystem with shard_precision to get a BeaconSet")
        self.sim.at(at_ms, self.beacons.fail, region)

    def recover_beacon(self, region, at_ms: float):
        if self.beacons is None:
            raise RuntimeError(
                "recover_beacon needs Beacon fault domains — construct "
                "ArmadaSystem with shard_precision to get a BeaconSet")
        self.sim.at(at_ms, self.beacons.recover, region)

    def partition_beacon(self, regions, at_ms: float) -> "PartitionHandle":
        """Schedule a split-brain: cut ``regions`` (one spec or a list)
        off from the majority at ``at_ms``.  Region specs are validated
        at schedule time (liveness is checked when the event fires).
        Returns a handle whose ``heal_at(ms)`` schedules the heal."""
        if self.beacons is None:
            raise RuntimeError(
                "partition_beacon needs Beacon fault domains — construct "
                "ArmadaSystem with shard_precision to get a BeaconSet")
        self.beacons._coerce_regions(regions)    # parse errors fail now
        self.sim.at(at_ms, self.beacons.partition, regions)
        return PartitionHandle(self, regions)

    def fail_cargo(self, name: str, at_ms: float):
        """Schedule a Cargo node failure — same contract as
        ``fail_node``: unknown names raise immediately, failing an
        already-dead Cargo raises when the event fires."""
        if name not in self.cargos:
            known = sorted(self.cargos)
            raise ValueError(
                f"fail_cargo: unknown cargo {name!r} — known cargo "
                f"nodes: {known[:8]}{'...' if len(known) > 8 else ''}")
        self.sim.at(at_ms, self._fail_cargo, name)

    def _fail_cargo(self, name: str):
        cg = self.cargos[name]
        if not cg.alive:
            raise RuntimeError(
                f"fail_cargo({name!r}): cargo is already failed at "
                f"t={self.sim.now:.1f} ms — the scenario author almost "
                "certainly meant a different node")
        cg.fail()
        self.cargo_manager.on_cargo_fail(cg)


class PartitionHandle:
    """Ticket returned by ``ArmadaSystem.partition_beacon``: remembers
    which regions were cut so the matching heal is one call."""

    def __init__(self, system: "ArmadaSystem", regions):
        self.system = system
        self.regions = regions

    def heal_at(self, at_ms: float):
        self.system.sim.at(at_ms, self.system.beacons.heal, self.regions)
        return self


def detection_image() -> Image:
    """The paper's object-detection service image (~480 MB, 6 layers)."""
    return Image("detector", [("base", 120.0), ("cuda-lite", 140.0),
                              ("py", 60.0), ("deps", 90.0),
                              ("weights", 60.0), ("app", 10.0)])


def facerec_image() -> Image:
    return Image("facerec", [("base", 120.0), ("py", 60.0),
                             ("dlib", 110.0), ("weights", 45.0),
                             ("app", 10.0)])
