"""Beacon (paper §3.1): the global entry point, plus system assembly.

``ArmadaSystem`` wires Simulator + Topology + Spinner + ApplicationManager
+ CargoManager and exposes the three interaction surfaces the paper gives
Beacon: application deployment, user service discovery, and resource
registration.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.app_manager import ApplicationManager, ServiceSpec
from repro.core.captain import Captain
from repro.core.client import Client
from repro.core.client_pool import ClientPool
from repro.core.cluster import Topology
from repro.core.sim import Simulator
from repro.core.spinner import Image, Spinner
from repro.core.storage.cargo import Cargo
from repro.core.storage.cargo_manager import CargoManager


class Beacon:
    """Request router: forwards to the right handler component."""

    def __init__(self, am: ApplicationManager, spinner: Spinner,
                 cargo_manager: CargoManager):
        self.am = am
        self.spinner = spinner
        self.cargo_manager = cargo_manager

    # the three public surfaces (paper §3.1)
    def deploy_application(self, spec: ServiceSpec, **kw):
        return self.am.deploy_service(spec, **kw)

    def query_service(self, service_id: str, user_loc, user_net: str):
        return self.am.candidate_list(service_id, user_loc, user_net)

    def query_service_batch(self, service_id: str, user_locs, user_nets):
        """Batched service discovery: one vectorized selection pass over a
        whole user population; returns one ranked Task list per user."""
        return self.am.candidate_lists(service_id, user_locs, user_nets)

    def query_service_indices(self, service_id: str, user_locs, user_nets):
        """Index-space batched discovery for pools: (U, k) int32 positions
        into the service's task list, padded with -1."""
        return self.am.candidate_indices(service_id, user_locs, user_nets)

    def register_node(self, captain: Captain, runtime: str = "armada"):
        return self.spinner.captain_join(captain, runtime)

    def register_cargo(self, cargo: Cargo):
        return self.cargo_manager.cargo_join(cargo)


class ArmadaSystem:
    """Fully wired Armada instance over a Topology."""

    def __init__(self, topo: Topology, *, seed: int = 0,
                 compute_nodes: Optional[List[str]] = None,
                 cargo_nodes: Optional[List[str]] = None,
                 include_cloud_compute: bool = True,
                 trace_enabled: bool = True,
                 shard_precision: Optional[int] = None):
        self.sim = Simulator(seed=seed, trace_enabled=trace_enabled)
        self.topo = topo
        self.spinner = Spinner(self.sim, topo)
        self.cargo_manager = CargoManager(self.sim, topo)
        self.am = ApplicationManager(self.sim, topo, self.spinner,
                                     self.cargo_manager,
                                     shard_precision=shard_precision)
        self.beacon = Beacon(self.am, self.spinner, self.cargo_manager)
        self.captains: Dict[str, Captain] = {}
        self.cargos: Dict[str, Cargo] = {}

        names = compute_nodes if compute_nodes is not None else [
            n for n, s in topo.nodes.items() if s.proc_ms > 0]
        for name in names:
            spec = topo.nodes[name]
            if spec.is_cloud and not include_cloud_compute:
                continue
            cap = Captain(self.sim, topo, spec)
            self.captains[name] = cap
            self.beacon.register_node(cap)
        for name in (cargo_nodes or []):
            cg = Cargo(self.sim, topo, topo.nodes[name])
            self.cargos[name] = cg
            self.beacon.register_cargo(cg)

    # ------------------------------------------------------------- helpers

    def make_client(self, client_id: str, service_id: str, **kw) -> Client:
        return Client(self.sim, self.topo, self.am, client_id, service_id,
                      **kw)

    def make_client_pool(self, service_id: str, **kw) -> ClientPool:
        """Vectorized population: pass ``client_ids=[...]`` for Topology
        endpoints (scalar-parity events transport) or ``locs=(U, 2)`` for
        synthetic users (fluid transport at scale)."""
        return ClientPool(self.sim, self.topo, self.am, service_id, **kw)

    def ensure_cloud_replica(self, service_id: str):
        """The paper's cloud baseline assumes an always-available cloud
        deployment; Armada's own scheduler never places on the cloud.
        Registration routes through ``ApplicationManager.register_task``
        so the selection engine's device-resident node caches are
        invalidated like any other replica-set change (appending to
        ``am.tasks`` directly would leave a stale ``packed_static`` to
        whatever path skips the lazy fingerprint check)."""
        from repro.core.app_manager import Task
        cloud = next((c for c in self.captains.values()
                      if c.spec.is_cloud), None)
        if cloud is None:
            return None
        task = Task(f"{service_id}/cloud", service_id, captain=cloud,
                    status="running", ready_at=self.sim.now)
        cloud.tasks[task.task_id] = task
        self.am.register_task(task)
        return task

    def fail_node(self, name: str, at_ms: float):
        self.sim.at(at_ms, self.captains[name].fail)

    def fail_cargo(self, name: str, at_ms: float):
        self.sim.at(at_ms, self.cargos[name].fail)


def detection_image() -> Image:
    """The paper's object-detection service image (~480 MB, 6 layers)."""
    return Image("detector", [("base", 120.0), ("cuda-lite", 140.0),
                              ("py", 60.0), ("deps", 90.0),
                              ("weights", 60.0), ("app", 10.0)])


def facerec_image() -> Image:
    return Image("facerec", [("base", 120.0), ("py", 60.0),
                             ("dlib", 110.0), ("weights", 45.0),
                             ("app", 10.0)])
