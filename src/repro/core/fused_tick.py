"""Device-resident fused probe tick for the fluid ``ClientPool``.

PR 2 vectorized the client control plane but each probe tick still
round-tripped device↔host: geo_topk scoring on device, then numpy for
the EMA fold, switch decision and failover pick.  This module runs the
whole tick as ONE jitted program over the pool's SoA state:

    connection breaks (sequential, host arrival order)
      → EMA fold of the previous traffic window
      → scoring + candidate top-k (same fp32 math as geo_topk)
      → two-round ``switch_decide``
      → next-window traffic masks

When the ``SelectionEngine`` is region-sharded (``shard_precision``),
the scoring step routes each user chunk to its home-region shard — one
(U_s, Ts_pad) pass per shard over gathered node columns with the
proximity filter restricted to the shard prefix — plus one
fixed-capacity border pass (``shard_border_cap`` rows) over the full
node set for users the in-shard widening cannot satisfy.  All shapes
stay jit-stable under churn (per-shard task paddings, static user
routing); only a shard appearing/vanishing retraces, and a border band
larger than its capacity raises rather than dropping users.  Decisions
remain identical to the sharded host tick (tests/test_sharded_selection
pins this on the Fig. 8/10 scenarios).

``FusedTickState`` keeps every pool array resident on device across
ticks (buffers are donated on accelerators, so the state updates in
place); per tick only small dynamic vectors cross host→device (free
fractions, validity masks, queued node deaths, jitter draws) and only
the per-user decisions the transport needs come back (candidates,
active/pending, switch confirmations, traffic masks).  Shapes are
jit-stable under churn: node/task arrays ride the engine's
``node_pad``-padded layout (``selection.PackedStatic``), the EMA table
is the host ``_EmaTable`` vectorized as fixed-width per-user slots
(see ``FusedTickState``), and breaks are processed through a
fixed-width queue with a dynamic trip count — ``COMPILE_COUNTS`` tracks
trace events so tests can pin "compiles exactly once per program".

Equivalence with the host tick (``ClientPool`` with ``tick="host"``,
``selection_backend="geo_topk"``) is exact in the decision stream —
same candidates, actives, pending nominations, switches and failovers —
because scoring consumes bit-identical fp32 inputs and the policy
functions are the same xp-generic code (``ema_fold``/``switch_decide``/
``failover_pick`` with ``xp=jnp``); EMA values and latencies agree to
fp32 rounding (the host folds in float64).  ``tests/test_fused_tick.py``
pins both on the paper's Fig. 8/10 scenarios.  Two deliberate
approximations, both outside the pinned scenarios: a user who loses
every candidate re-enters initial selection at the next tick boundary
(the host retries ~500 ms earlier), and baseline modes other than
``armada`` are not fused (they stay on the host tick).

The driver at the bottom owns the host glue that cannot leave the
simulator: ``Captain.arrive_batch`` fluid admission, RNG jitter draws in
the exact scalar order (``Simulator.jitter_batch`` parity), switch/break
bookkeeping, and metric mirrors.
"""
from __future__ import annotations

import collections
import time
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client_pool import (RTT_CLOUD_PENALTY_MS, RTT_LAST_MILE_MS,
                                    RTT_MS_PER_KM, ema_fold, failover_pick,
                                    switch_decide)
from repro.core.selection import MIN_PROXIMITY_HITS
from repro.kernels.geo_topk.ref import (haversine_km, score_matrix,
                                        score_matrix_restricted)

# trace-time counters: a body runs once per compile, so tests can assert
# shape stability under churn (no silent recompiles)
COMPILE_COUNTS: collections.Counter = collections.Counter()

DEATH_QUEUE_MAX = 128          # breaks processed per tick (fixed jit shape)

# buffer donation updates the state in place on accelerators; XLA:CPU
# does not implement it and would warn on every call
_DONATE = (0,) if jax.default_backend() != "cpu" else ()


class FusedTickState(NamedTuple):
    """Pool SoA state resident on device across ticks.

    The EMA table is the host ``_EmaTable`` vectorized, not densified: a
    fixed-width per-user slot map ``ema_nodes`` (node index, -1 free) /
    ``ema_vals`` (NaN = no sample; pops NaN the value but keep the slot,
    exactly like the host dict-pop semantics).  Memory stays
    O(U × slots), independent of fleet size — a dense (U, N) table would
    cap the very node counts the tiled kernel just unlocked.
    ``ema_overflow`` latches when a user outgrows the slot width (the
    host table would have grown; the driver raises with the remedy)."""
    ema_nodes: jnp.ndarray      # (U, S) i32 node index per slot, -1 free
    ema_vals: jnp.ndarray       # (U, S) f32 EMA per slot, NaN = no sample
    ema_overflow: jnp.ndarray   # () bool
    cand: jnp.ndarray           # (U, k) i32 candidate task positions, -1 pad
    active: jnp.ndarray         # (U,) i32 active task position, -1 none
    pending: jnp.ndarray        # (U,) i32 pending-switch task index, -1 none
    running: jnp.ndarray        # (U,) bool
    ticking: jnp.ndarray        # (U,) bool probe-tick membership
    reinit: jnp.ndarray         # (U,) bool lost every candidate; re-enter
    lat_probe: jnp.ndarray      # (U, k) f32 stashed window latencies, NaN=none
    lat_frame: jnp.ndarray      # (U, nf) f32
    cand_traffic: jnp.ndarray   # (U, k) i32 candidates the stash refers to
    active_traffic: jnp.ndarray  # (U,) i32
    frame_count: jnp.ndarray    # (U,) i32 aggregate frame stats
    frame_sum: jnp.ndarray      # (U,) f32
    failovers: jnp.ndarray      # () i32


class ShardIx(NamedTuple):
    """One region shard's index maps inside the fused tick: the user
    rows homed in this shard (a static partition — user locations never
    move) and the shard's padded global task positions (content changes
    under churn, shape does not).  The shard's user/node attribute
    arrays are gathered from the full ``FusedTickStatic`` on device, so
    these two index vectors are all a shard costs."""
    user_ix: jnp.ndarray        # (Us,) i32 user rows of this shard
    task_ix: jnp.ndarray        # (Ts_pad,) i32 global task positions, -1 pad


class FusedTickStatic(NamedTuple):
    """Per-pool device constants (rebuilt only on node-epoch change)."""
    user_lat: jnp.ndarray       # (U,) f32
    user_lon: jnp.ndarray       # (U,) f32
    user_net: jnp.ndarray       # (U,) i32
    user_code20: jnp.ndarray    # (U,) i32
    task_lat: jnp.ndarray       # (Tp,) f32
    task_lon: jnp.ndarray       # (Tp,) f32
    task_aff: jnp.ndarray       # (M, Tp) f32
    task_code20: jnp.ndarray    # (Tp,) i32
    task_cloud: jnp.ndarray     # (Tp,) f32
    task_node: jnp.ndarray      # (Tp,) i32 node index per task (-1 none)
    node_proc: jnp.ndarray      # (Np,) f32 proc_ms per node
    node_slots: jnp.ndarray     # (Np,) f32 slots per node
    shards: Optional[Tuple[ShardIx, ...]] = None   # region-sharded scoring


class TickOuts(NamedTuple):
    """Per-user decisions handed back to the transport each tick."""
    cand: jnp.ndarray           # (U, k) i32
    active: jnp.ndarray         # (U,) i32
    pending: jnp.ndarray        # (U,) i32
    confirm: jnp.ndarray        # (U,) bool switches confirmed this tick
    from_node: jnp.ndarray      # (U,) i32 pre-switch active node
    probe_ok: jnp.ndarray       # (U, k) bool probes to send this window
    frame_ok: jnp.ndarray       # (U,) bool frames to send this window
    failovers: jnp.ndarray      # () i32 running total
    border_overflow: jnp.ndarray  # () bool sharded border band > capacity
    refresh_fallback: jnp.ndarray  # () bool dirty set > refresh_cap (the
    #                               tick fell back to the dense scan)


# ---------------------------------------------------------------------------
# traced building blocks (shared by the tick and flush programs)
# ---------------------------------------------------------------------------

def _ema_get(nodes_tab, vals_tab, node):
    """Per-row EMA lookup for ``node`` (U,) — NaN when absent; matches
    ``_EmaTable.get`` (including the quirk that node == -1 matches a
    free slot, whose value is NaN anyway)."""
    eq = nodes_tab == node[:, None]                    # (U, S)
    rows = jnp.arange(nodes_tab.shape[0])
    v = vals_tab[rows, eq.argmax(axis=1)]
    return jnp.where(eq.any(axis=1), v, jnp.nan)


def _ema_get_matrix(nodes_tab, vals_tab, node_mat):
    """(U, k) lookup — ``_EmaTable.get_matrix``."""
    return jnp.stack([_ema_get(nodes_tab, vals_tab, node_mat[:, c])
                      for c in range(node_mat.shape[1])], axis=1)


def _ema_fold_into(nodes_tab, vals_tab, overflow, node, lat, m, alpha):
    """One EMA step per row at ``node`` where ``m``: reuse the matching
    slot, else claim the first free one (``_EmaTable.fold`` semantics).
    A row with no free slot latches ``overflow`` — the host table would
    have grown; the driver surfaces it."""
    rows = jnp.arange(nodes_tab.shape[0])
    eq = nodes_tab == node[:, None]
    has = eq.any(axis=1)
    free = nodes_tab == -1
    can_alloc = free.any(axis=1)
    slot = jnp.where(has, eq.argmax(axis=1), free.argmax(axis=1))
    do = m & (has | can_alloc)
    overflow = overflow | (m & ~has & ~can_alloc).any()
    claim = do & ~has
    nodes_tab = nodes_tab.at[rows, slot].set(
        jnp.where(claim, node, nodes_tab[rows, slot]))
    prev = vals_tab[rows, slot]
    prev = jnp.where(has, prev, jnp.nan)               # fresh slot: no prior
    new = jnp.where(do, ema_fold(prev, lat, alpha, xp=jnp),
                    vals_tab[rows, slot])
    return nodes_tab, vals_tab.at[rows, slot].set(new), overflow


def _process_deaths(state, tn, deaths, n_deaths):
    """Replay queued connection breaks in arrival order — each step is
    ``ClientPool.on_connection_break``'s fluid/armada branch: pop the
    dead node's EMAs for affected users, left-compact their candidate
    rows, instant-failover users whose active died (best known EMA, else
    first candidate, else mark for re-initialization).

    Pops are accumulated as a slot mask and applied once after the loop.
    That is exact: the slot map itself never changes during the loop,
    compaction removes every dead-node candidate before
    ``failover_pick`` gathers EMAs (so a popped cell is never read
    inside the loop), and the fold that could re-seed popped cells runs
    after the mask is applied."""
    rows = jnp.arange(state.cand.shape[0])
    running = state.running
    nodes_tab, vals_tab = state.ema_nodes, state.ema_vals

    def step(i, carry):
        cand, active, reinit, failovers, popmask = carry
        d = deaths[i]
        cand_node = jnp.where(cand >= 0, tn[jnp.clip(cand, 0)], -1)
        act_node = jnp.where(active >= 0, tn[jnp.clip(active, 0)], -1)
        hit = running & ((cand_node == d).any(axis=1) | (act_node == d))
        popmask = popmask | (hit[:, None] & (nodes_tab == d))
        keep = (cand >= 0) & (cand_node != d)
        # left-compact kept entries by rank (compact_rows semantics) —
        # closed-form per output column, no per-row sort
        rank = jnp.cumsum(keep, axis=1) - 1
        cols = []
        for j in range(cand.shape[1]):
            hitj = keep & (rank == j)
            src = jnp.argmax(hitj, axis=1)
            cols.append(jnp.where(hitj.any(axis=1), cand[rows, src], -1))
        compacted = jnp.stack(cols, axis=1)
        cand = jnp.where(hit[:, None], compacted, cand)
        act_dead = hit & ((active < 0) | (act_node == d))
        cand_node = jnp.where(cand >= 0, tn[jnp.clip(cand, 0)], -1)
        slot = failover_pick(
            cand, _ema_get_matrix(nodes_tab, vals_tab, cand_node), xp=jnp)
        has = slot >= 0
        picked = cand[rows, jnp.clip(slot, 0)]
        active = jnp.where(act_dead & has, picked, active)
        active = jnp.where(act_dead & ~has, -1, active)
        failovers = failovers + jnp.sum((act_dead & has).astype(jnp.int32))
        reinit = reinit | (act_dead & ~has)
        return cand, active, reinit, failovers, popmask

    cand, active, reinit, failovers, popmask = jax.lax.fori_loop(
        0, n_deaths, step,
        (state.cand, state.active, state.reinit, state.failovers,
         jnp.zeros(nodes_tab.shape, bool)))
    vals_tab = jnp.where(popmask, jnp.nan, vals_tab)
    return nodes_tab, vals_tab, cand, active, reinit, failovers


def _fold_window(state, nodes_tab, vals_tab, tn, alpha):
    """Fold the stashed window's latencies into the EMA table in the
    host flush order: candidate slots left-to-right (== per-(user, node)
    occurrence rank), then frame rounds in arrival order."""
    u, k = state.cand_traffic.shape
    nf = state.lat_frame.shape[1]
    overflow = state.ema_overflow

    ct = state.cand_traffic
    for c in range(k):
        tc = ct[:, c]
        lat = state.lat_probe[:, c]
        node = jnp.where(tc >= 0, tn[jnp.clip(tc, 0)], -1)
        nodes_tab, vals_tab, overflow = _ema_fold_into(
            nodes_tab, vals_tab, overflow, node, lat,
            (node >= 0) & ~jnp.isnan(lat), alpha)
    at_ = state.active_traffic
    fnode = jnp.where(at_ >= 0, tn[jnp.clip(at_, 0)], -1)
    fc, fs = state.frame_count, state.frame_sum
    for j in range(nf):
        lat = state.lat_frame[:, j]
        m = (fnode >= 0) & ~jnp.isnan(lat)
        nodes_tab, vals_tab, overflow = _ema_fold_into(
            nodes_tab, vals_tab, overflow, fnode, lat, m, alpha)
        fc = fc + m.astype(fc.dtype)
        fs = fs + jnp.where(m, lat, 0.0).astype(fs.dtype)
    return nodes_tab, vals_tab, overflow, fc, fs


def _base_rtt(static, tasks):
    """``default_rtt_model`` on device (same constants, fp32)."""
    safe = jnp.clip(tasks, 0)
    ul, uo = static.user_lat, static.user_lon
    if tasks.ndim == 2:
        ul, uo = ul[:, None], uo[:, None]
    d = haversine_km(ul, uo, static.task_lat[safe], static.task_lon[safe])
    return RTT_LAST_MILE_MS + RTT_MS_PER_KM * d \
        + jnp.where(static.task_cloud[safe] > 0, RTT_CLOUD_PENALTY_MS, 0.0)


# ---------------------------------------------------------------------------
# jitted programs
# ---------------------------------------------------------------------------

def _sharded_candidates(static, free, sched, need, k, p_min, border_cap,
                        tick_mask):
    """Region-sharded candidate refresh: each shard's users score only
    that shard's gathered node columns (filter restricted to
    ``p >= p_min``); the border band — users the in-shard widening could
    not satisfy — is gathered into a fixed-capacity buffer
    (``border_cap`` rows, jit-stable) and scored against the full node
    set with the unrestricted filter.  Per-shard (U_s, k) results merge
    by scatter in global task-position space; ``lax.top_k``'s min-index
    ties match the unsharded pass because shard task columns keep
    ascending global order.  Returns ``(new_cand, border_overflow)`` —
    an overflowing border band means dropped users, so the driver
    raises with the remedy instead of serving wrong candidates."""
    u = static.user_lat.shape[0]
    new_cand = jnp.full((u, k), -1, jnp.int32)
    sat_all = jnp.zeros(u, bool)
    for sh in static.shards:
        safe_t = jnp.clip(sh.task_ix, 0)
        t_ok = (sh.task_ix >= 0).astype(jnp.float32)
        s_scores, sat = score_matrix_restricted(
            static.user_lat[sh.user_ix], static.user_lon[sh.user_ix],
            static.user_net[sh.user_ix], static.user_code20[sh.user_ix],
            static.task_lat[safe_t], static.task_lon[safe_t],
            free[safe_t] * t_ok, static.task_aff[:, safe_t],
            static.task_code20[safe_t], sched[safe_t] * t_ok, need, p_min)
        kk = min(k, sh.task_ix.shape[0])
        top_s, top_i = jax.lax.top_k(s_scores, kk)
        g = sh.task_ix[top_i]
        cand_s = jnp.where(top_s > -1e29, g.astype(jnp.int32), -1)
        if kk < k:
            cand_s = jnp.pad(cand_s, ((0, 0), (0, k - kk)),
                             constant_values=-1)
        new_cand = new_cand.at[sh.user_ix].set(cand_s)
        sat_all = sat_all.at[sh.user_ix].set(sat)
    border = tick_mask & ~sat_all
    b_count = border.sum()
    # fill_value=u: out-of-range rows are dropped by the scatter below
    b_ix, = jnp.nonzero(border, size=border_cap, fill_value=u)
    safe_b = jnp.clip(b_ix, 0, u - 1)
    b_scores = score_matrix(
        static.user_lat[safe_b], static.user_lon[safe_b],
        static.user_net[safe_b], static.user_code20[safe_b],
        static.task_lat, static.task_lon, free, static.task_aff,
        static.task_code20, sched, need)
    top_s, top_i = jax.lax.top_k(b_scores, k)
    cand_b = jnp.where(top_s > -1e29, top_i.astype(jnp.int32), -1)
    new_cand = new_cand.at[b_ix].set(cand_b)
    return new_cand, b_count > border_cap


def _shard_refresh_caps(static, refresh_cap: int) -> tuple:
    """Static per-shard sparse-gather capacities: ``refresh_cap`` rows
    per shard, clamped to the shard's population."""
    return tuple(min(int(sh.user_ix.shape[0]), refresh_cap)
                 for sh in static.shards)


def _sharded_candidates_sparse(static, free, sched, need, k, p_min,
                               border_cap, refresh_cap, dirty, cand):
    """Sparse variant of ``_sharded_candidates``: gather only each
    shard's *dirty* rows (``jnp.nonzero(size=cap)`` — the border-band
    idiom, jit-stable shapes under any churn) and scatter their top-k
    straight back into the resident candidate matrix.  Callers must
    guarantee no shard's dirty count exceeds its capacity (the tick
    latches overflow OUTSIDE and takes the dense branch instead — a
    dropped dirty user would silently keep wrong candidates).  Returns
    ``(cand, border_overflow)`` with the refresh already applied; rows
    outside ``dirty`` are untouched bit-for-bit."""
    u = static.user_lat.shape[0]
    sat_all = jnp.zeros(u, bool)
    caps = _shard_refresh_caps(static, refresh_cap)
    for sh, cap_s in zip(static.shards, caps):
        us = sh.user_ix.shape[0]
        l_ix, = jnp.nonzero(dirty[sh.user_ix], size=cap_s, fill_value=us)
        g_ix = sh.user_ix[jnp.clip(l_ix, 0, us - 1)]
        # pad rows (l_ix == us) must drop at the scatter, not clobber the
        # shard's last user — send them out of range
        g_put = jnp.where(l_ix < us, g_ix, u)
        safe_t = jnp.clip(sh.task_ix, 0)
        t_ok = (sh.task_ix >= 0).astype(jnp.float32)
        s_scores, sat = score_matrix_restricted(
            static.user_lat[g_ix], static.user_lon[g_ix],
            static.user_net[g_ix], static.user_code20[g_ix],
            static.task_lat[safe_t], static.task_lon[safe_t],
            free[safe_t] * t_ok, static.task_aff[:, safe_t],
            static.task_code20[safe_t], sched[safe_t] * t_ok, need, p_min)
        kk = min(k, sh.task_ix.shape[0])
        top_s, top_i = jax.lax.top_k(s_scores, kk)
        g = sh.task_ix[top_i]
        cand_s = jnp.where(top_s > -1e29, g.astype(jnp.int32), -1)
        if kk < k:
            cand_s = jnp.pad(cand_s, ((0, 0), (0, k - kk)),
                             constant_values=-1)
        cand = cand.at[g_put].set(cand_s)
        sat_all = sat_all.at[g_put].set(sat)
    # dirty users the in-shard widening could not satisfy (plus dirty
    # users homed to no shard at all) ride the standard border pass
    border = dirty & ~sat_all
    b_count = border.sum()
    b_ix, = jnp.nonzero(border, size=border_cap, fill_value=u)
    safe_b = jnp.clip(b_ix, 0, u - 1)
    b_scores = score_matrix(
        static.user_lat[safe_b], static.user_lon[safe_b],
        static.user_net[safe_b], static.user_code20[safe_b],
        static.task_lat, static.task_lon, free, static.task_aff,
        static.task_code20, sched, need)
    top_s, top_i = jax.lax.top_k(b_scores, k)
    cand_b = jnp.where(top_s > -1e29, top_i.astype(jnp.int32), -1)
    cand = cand.at[b_ix].set(cand_b)
    return cand, b_count > border_cap


def _tick_impl(state, static, free, sched, alive, need, deaths, n_deaths,
               alpha, margin, refresh_ok, dirty, p_min, border_cap,
               refresh_cap):
    COMPILE_COUNTS["tick"] += 1
    u, k = state.cand.shape
    rows = jnp.arange(u)
    tn = static.task_node

    # 1. queued connection breaks (before the fold — host breaks happen
    #    mid-window, after traffic was scheduled but before it is folded)
    enodes, evals, cand, active, reinit, failovers = _process_deaths(
        state, tn, deaths, n_deaths)

    # 2. fold the previous window
    enodes, evals, overflow, fc, fs = _fold_window(
        state, enodes, evals, tn, alpha)

    # 3. candidate refresh: fused scoring + top-k (lax.top_k — the exact
    #    op the geo_topk kernel path dispatches to, same min-index ties) —
    #    one (U, Tp) pass unsharded, or per-shard (U_s, Ts_pad) passes
    #    plus the fixed-capacity border pass when the engine is sharded.
    #    ``refresh_ok`` gates the refresh only: users inside a Beacon
    #    re-discovery window keep (and keep probing) their stale
    #    candidates, exactly like the host tick's filtered ``_refresh``
    tick_mask = state.running & state.ticking
    if refresh_cap == 0:
        # every-tick refresh (the historical semantics, bit-for-bit)
        refresh_mask = tick_mask & refresh_ok
        if static.shards is None:
            scores = score_matrix(
                static.user_lat, static.user_lon, static.user_net,
                static.user_code20, static.task_lat, static.task_lon, free,
                static.task_aff, static.task_code20, sched, need)
            top_s, top_i = jax.lax.top_k(scores, k)
            new_cand = jnp.where(top_s > -1e29,
                                 top_i.astype(jnp.int32), -1)
            border_overflow = jnp.zeros((), bool)
        else:
            new_cand, border_overflow = _sharded_candidates(
                static, free, sched, need, k, p_min, border_cap,
                refresh_mask)
        cand = jnp.where(refresh_mask[:, None], new_cand, cand)
        refresh_fallback = jnp.zeros((), bool)
    else:
        # incremental refresh: rescore only the dirty rows (host-supplied
        # marks, plus users who just lost every candidate), gathered into
        # a fixed-capacity buffer.  If the dirty set outgrows the buffer
        # the whole tick falls back to the dense scan *applied to exactly
        # the same rows* — identical decisions, latched as
        # ``refresh_fallback`` so the driver can account for it
        dirty_full = (dirty | reinit) & tick_mask & refresh_ok
        if static.shards is None:
            over = dirty_full.sum() > refresh_cap

            def dense_fn(cand_in):
                scores = score_matrix(
                    static.user_lat, static.user_lon, static.user_net,
                    static.user_code20, static.task_lat, static.task_lon,
                    free, static.task_aff, static.task_code20, sched, need)
                top_s, top_i = jax.lax.top_k(scores, k)
                nc = jnp.where(top_s > -1e29, top_i.astype(jnp.int32), -1)
                return (jnp.where(dirty_full[:, None], nc, cand_in),
                        jnp.zeros((), bool))

            def sparse_fn(cand_in):
                d_ix, = jnp.nonzero(dirty_full, size=refresh_cap,
                                    fill_value=u)
                safe_d = jnp.clip(d_ix, 0, u - 1)
                scores = score_matrix(
                    static.user_lat[safe_d], static.user_lon[safe_d],
                    static.user_net[safe_d], static.user_code20[safe_d],
                    static.task_lat, static.task_lon, free,
                    static.task_aff, static.task_code20, sched, need)
                top_s, top_i = jax.lax.top_k(scores, k)
                nc = jnp.where(top_s > -1e29, top_i.astype(jnp.int32), -1)
                # fill rows (d_ix == u) drop at the scatter
                return cand_in.at[d_ix].set(nc), jnp.zeros((), bool)

        else:
            caps = _shard_refresh_caps(static, refresh_cap)
            counts = [dirty_full[sh.user_ix].sum()
                      for sh in static.shards]
            over = jnp.zeros((), bool)
            for c, cap_s in zip(counts, caps):
                over = over | (c > cap_s)

            def dense_fn(cand_in):
                nc, b_over = _sharded_candidates(
                    static, free, sched, need, k, p_min, border_cap,
                    dirty_full)
                return jnp.where(dirty_full[:, None], nc, cand_in), b_over

            def sparse_fn(cand_in):
                return _sharded_candidates_sparse(
                    static, free, sched, need, k, p_min, border_cap,
                    refresh_cap, dirty_full, cand_in)

        cand, border_overflow = jax.lax.cond(over, dense_fn, sparse_fn,
                                             cand)
        refresh_fallback = over

    # users who lost every candidate re-enter initial selection: active
    # is the best-base-RTT candidate (Client start semantics)
    base = jnp.where(cand >= 0, _base_rtt(static, cand), jnp.inf)
    init_slot = jnp.argmin(base, axis=1)
    has_cand = (cand >= 0).any(axis=1)
    init_active = jnp.where(has_cand, cand[rows, init_slot], -1)
    do_init = reinit & tick_mask
    active = jnp.where(do_init, init_active, active)
    reinit = jnp.where(do_init & has_cand, False, reinit)

    # 4. two-round confirmed switch on the freshly folded EMAs.  The
    #    pending target is judged from the EMA table + task-alive mask
    #    directly (not via candidate-list membership — the candidate set
    #    rotates under load feedback)
    cand_node = jnp.where(cand >= 0, tn[jnp.clip(cand, 0)], -1)
    act_node = jnp.where(active >= 0, tn[jnp.clip(active, 0)], -1)
    cand_ema = _ema_get_matrix(enodes, evals, cand_node)
    act_ema = _ema_get(enodes, evals, act_node)
    pend = state.pending
    pend_node = jnp.where(pend >= 0, tn[jnp.clip(pend, 0)], -1)
    pend_ema = _ema_get(enodes, evals, pend_node)
    pend_alive = (pend >= 0) & alive[jnp.clip(pend, 0)]
    confirm, target, new_pending = switch_decide(
        cand, cand_ema, active, act_ema, pend, pend_ema, pend_alive,
        margin, xp=jnp)
    confirm = confirm & tick_mask
    pending = jnp.where(tick_mask, new_pending, state.pending)
    active = jnp.where(confirm, target, active)

    # 5. next-window traffic: probes to every live candidate, frames to
    #    the live active
    probe_ok = (cand >= 0) & alive[jnp.clip(cand, 0)] & tick_mask[:, None]
    frame_ok = (active >= 0) & alive[jnp.clip(active, 0)] & tick_mask

    nf = state.lat_frame.shape[1]
    new_state = FusedTickState(
        ema_nodes=enodes, ema_vals=evals, ema_overflow=overflow,
        cand=cand, active=active, pending=pending,
        running=state.running, ticking=state.ticking, reinit=reinit,
        lat_probe=jnp.full((u, k), jnp.nan, jnp.float32),
        lat_frame=jnp.full((u, nf), jnp.nan, jnp.float32),
        cand_traffic=cand, active_traffic=active,
        frame_count=fc, frame_sum=fs, failovers=failovers)
    outs = TickOuts(cand=cand, active=active, pending=pending,
                    confirm=confirm, from_node=act_node,
                    probe_ok=probe_ok, frame_ok=frame_ok,
                    failovers=failovers, border_overflow=border_overflow,
                    refresh_fallback=refresh_fallback)
    return new_state, outs


def _traffic_impl(state, static, work0, net_rate, probe_ok, frame_ok,
                  e_rtt_p, e_proc_p, e_back_p, e_rtt_f, e_proc_f, e_back_f,
                  data_f, scale, frame_interval):
    """Fluid-window latencies for the traffic the tick scheduled, stashed
    into the state for the next tick's fold.  Mirrors the host
    ``_traffic_fluid`` arithmetic: ``wait(tau) = max(0, work0 +
    net_rate * tau) / slots``, multiplicative jitter on rtt/proc/back.
    ``data_f`` is the (U,) per-user in-situ data-access term (zeros when
    the pool has no data profile), computed host-side from each user's
    active node and added to FRAME latencies only — probes stay pure
    network/queue measurements, exactly like the host tick."""
    COMPILE_COUNTS["traffic"] += 1
    tn = static.task_node
    nf = state.lat_frame.shape[1]

    ct = state.cand_traffic
    node_p = jnp.clip(tn[jnp.clip(ct, 0)], 0)
    base_p = _base_rtt(static, ct)
    rtt = base_p * (1 + 0.08 * e_rtt_p)
    wait_p = jnp.maximum(0.0, work0[node_p]) / static.node_slots[node_p]
    proc_p = (static.node_proc[node_p] * scale) * (1 + 0.06 * e_proc_p)
    back = (rtt / 2) * (1 + 0.08 * e_back_p)
    lat_p = rtt / 2 + wait_p + jnp.maximum(proc_p, 0.1) + back
    lat_probe = jnp.where(probe_ok, lat_p, jnp.nan)

    at_ = state.active_traffic
    node_f = jnp.clip(tn[jnp.clip(at_, 0)], 0)
    base_f = _base_rtt(static, at_)[:, None]
    tau = ((jnp.arange(nf) + 0.5) * frame_interval)[None, :]
    rtt_f = base_f * (1 + 0.08 * e_rtt_f)
    wait_f = jnp.maximum(
        0.0, work0[node_f][:, None] + net_rate[node_f][:, None] * tau
    ) / static.node_slots[node_f][:, None]
    proc_f = (static.node_proc[node_f][:, None] * scale) \
        * (1 + 0.06 * e_proc_f)
    back_f = (rtt_f / 2) * (1 + 0.08 * e_back_f)
    lat_f = rtt_f / 2 + wait_f + jnp.maximum(proc_f, 0.1) + back_f \
        + data_f[:, None]
    lat_frame = jnp.where(frame_ok[:, None], lat_f, jnp.nan)
    return state._replace(lat_probe=lat_probe, lat_frame=lat_frame)


def _flush_impl(state, static, deaths, n_deaths, alpha):
    """Fold-only step: process queued breaks then fold the open window —
    what the host tick does lazily when metrics are read mid-window."""
    COMPILE_COUNTS["flush"] += 1
    u, k = state.cand.shape
    nf = state.lat_frame.shape[1]
    tn = static.task_node
    enodes, evals, cand, active, reinit, failovers = _process_deaths(
        state, tn, deaths, n_deaths)
    enodes, evals, overflow, fc, fs = _fold_window(
        state, enodes, evals, tn, alpha)
    return state._replace(
        ema_nodes=enodes, ema_vals=evals, ema_overflow=overflow,
        cand=cand, active=active, reinit=reinit,
        failovers=failovers, frame_count=fc, frame_sum=fs,
        lat_probe=jnp.full((u, k), jnp.nan, jnp.float32),
        lat_frame=jnp.full((u, nf), jnp.nan, jnp.float32))


_fused_tick = jax.jit(_tick_impl, donate_argnums=_DONATE,
                      static_argnames=("p_min", "border_cap",
                                       "refresh_cap"))
_fused_traffic = jax.jit(_traffic_impl, donate_argnums=_DONATE)
_fused_flush = jax.jit(_flush_impl, donate_argnums=_DONATE)


# ---------------------------------------------------------------------------
# mesh-sharded programs (ClientPool(mesh=...))
# ---------------------------------------------------------------------------

class MeshPrograms(NamedTuple):
    tick: object
    traffic: object
    flush: object


def _make_mesh_programs(mesh, users_axis: str, p_min: int, border_cap: int,
                        sharded: bool, refresh_cap: int = 0) -> MeshPrograms:
    """Build the shard_map-wrapped tick/traffic/flush programs for one
    mesh layout.  Each device runs the *same* ``_tick_impl`` body over
    its own (Ud, ...) user block — the block's shards collapse into one
    synthetic union shard whose task list is that device's concatenated
    region task lists (see ``MeshTickDriver``), which is exactly the
    per-shard loop because at ``p >= shard_precision`` a user's prefix
    cells only ever match home-region tasks.  The border band stays a
    *local* fixed-capacity pass against the replicated full node set
    (replicating O(N) node columns is far cheaper than a cross-device
    gather at edge-fleet sizes), so the body needs no collectives at
    all: one SPMD program serves every device, and churn — which changes
    task-list *content*, never shapes — re-traces nothing."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    ps_u = P(users_axis)        # leading dim sharded over the population
    ps_r = P()                  # replicated
    static_spec = FusedTickStatic(
        user_lat=ps_u, user_lon=ps_u, user_net=ps_u, user_code20=ps_u,
        task_lat=ps_r, task_lon=ps_r, task_aff=ps_r, task_code20=ps_r,
        task_cloud=ps_r, task_node=ps_r, node_proc=ps_r, node_slots=ps_r,
        shards=None)

    def tick_body(state, static, local_task, free, sched, alive, need,
                  deaths, n_deaths, alpha, margin, refresh_ok, dirty):
        COMPILE_COUNTS["mesh_tick"] += 1
        if sharded:
            ud = state.cand.shape[0]
            st = static._replace(shards=(ShardIx(
                user_ix=jnp.arange(ud, dtype=jnp.int32),
                task_ix=local_task[0]),))
        else:
            st = static
        new_state, outs = _tick_impl(
            state, st, free, sched, alive, need, deaths, n_deaths,
            alpha, margin, refresh_ok, dirty, p_min, border_cap,
            refresh_cap)
        # lift per-device () scalars to (1,) so the global outputs carry
        # one element per device ((D,) — reduced on the host)
        return new_state, outs._replace(
            border_overflow=outs.border_overflow.reshape(1),
            refresh_fallback=outs.refresh_fallback.reshape(1))

    def traffic_body(state, static, work0, net_rate, probe_ok, frame_ok,
                     e1p, e2p, e3p, e1f, e2f, e3f, data_f, scale,
                     frame_interval):
        COMPILE_COUNTS["mesh_traffic"] += 1
        return _traffic_impl(state, static, work0, net_rate, probe_ok,
                             frame_ok, e1p, e2p, e3p, e1f, e2f, e3f,
                             data_f, scale, frame_interval)

    def flush_body(state, static, deaths, n_deaths, alpha):
        COMPILE_COUNTS["mesh_flush"] += 1
        return _flush_impl(state, static, deaths, n_deaths, alpha)

    tick = jax.jit(shard_map(
        tick_body, mesh=mesh,
        in_specs=(ps_u, static_spec, ps_u, ps_r, ps_r, ps_r, ps_r,
                  ps_r, ps_r, ps_r, ps_r, ps_u, ps_u),
        out_specs=ps_u, check_rep=False), donate_argnums=_DONATE)
    traffic = jax.jit(shard_map(
        traffic_body, mesh=mesh,
        in_specs=(ps_u, static_spec, ps_r, ps_r, ps_u, ps_u,
                  ps_u, ps_u, ps_u, ps_u, ps_u, ps_u, ps_u, ps_r, ps_r),
        out_specs=ps_u, check_rep=False), donate_argnums=_DONATE)
    flush = jax.jit(shard_map(
        flush_body, mesh=mesh,
        in_specs=(ps_u, static_spec, ps_r, ps_r, ps_r),
        out_specs=ps_u, check_rep=False), donate_argnums=_DONATE)
    return MeshPrograms(tick=tick, traffic=traffic, flush=flush)


# ---------------------------------------------------------------------------
# host-side driver
# ---------------------------------------------------------------------------

class FusedTickDriver:
    """Owns the device state for one ``ClientPool`` (``tick="device"``)
    and the host glue a tick still needs: fluid admission through the
    captains, jitter draws on the simulator RNG in scalar order, switch
    records and mirror updates.  The pool delegates its probe-tick chain
    here; everything else (start/refresh bookkeeping, metrics surface)
    stays on the pool."""

    def __init__(self, pool, node_pad: int = 256, ema_slots: int = 32):
        self.pool = pool
        self.node_pad = node_pad
        self.ema_slots = ema_slots
        self.deaths: List[int] = []
        self._epoch = -1
        self.static: Optional[FusedTickStatic] = None
        self.state: Optional[FusedTickState] = None
        self.nf = int(pool.probe_period // pool.frame_interval)
        self._stash_dirty = False       # an unfolded window is stashed
        # region sharding (engine-configured): static user→shard routing
        # plus the two static knobs the jitted tick needs.  Routing also
        # depends on the engine's Beacon ownership map — a Beacon handoff
        # (owner_version bump) re-routes the dead domain's users to the
        # adopting shard, a one-time transient like a shard appearing
        self._u_shard = None    # ((precision, owner_version), routed codes)
        self._u_codes = None            # raw (U,) full-precision codes
        self._owner_version = -1
        self.p_min = 0                  # 0 = unsharded scoring
        self.border_cap = 0
        self._all_refresh = None        # cached all-True refresh mask
        self._no_dirty = None           # cached all-False dirty input
        # incremental refresh: sparse-gather capacity (0 = every-tick
        # dense refresh, the bit-for-bit historical program)
        self.refresh_cap = 0
        if pool.refresh_period is not None:
            self.refresh_cap = pool.refresh_cap \
                if pool.refresh_cap is not None \
                else self._default_border_cap()

    def _default_border_cap(self) -> int:
        """Fixed border-band capacity: the cross-shard pass costs
        O(border_cap × N) every tick regardless of how many users are
        actually in the band, so it defaults to U/8 (128-aligned) —
        generous for region-clustered populations, overridable via
        ``ClientPool(shard_border_cap=...)``.  Overflow raises rather
        than dropping users."""
        u = self.pool.n_users
        return min(u, max(128, -(-u // 8 // 128) * 128))

    # ------------------------------------------------------------ setup

    def _packed_user(self):
        from repro.core import geohash
        from repro.kernels.geo_topk.ops import pack_user_inputs
        from repro.core.selection import CODE_PRECISION
        pool = self.pool
        codes = geohash.encode_batch(pool.locs[:, 0], pool.locs[:, 1],
                                     CODE_PRECISION)
        return pack_user_inputs(pool.locs[:, 0], pool.locs[:, 1],
                                pool.net_ix, codes)

    def _node_cap(self) -> int:
        npad = self.node_pad
        return max(npad, -(-len(self.pool._node_ids) // npad) * npad)

    def _host_static_arrays(self, view):
        """Shared host-side assembly of the per-pool constants: packed
        task arrays, node->task map, node proc/slots, packed users."""
        pool = self.pool
        st = view.packed_static(self.node_pad)
        np_cap = self._node_cap()
        if self.static is not None:
            if np_cap != self.static.node_proc.shape[0] or \
                    st.n_pad != self.static.task_lat.shape[0]:
                raise RuntimeError(
                    "fused tick: node/task set outgrew its padding "
                    f"(tasks {st.n_pad}, nodes {np_cap}) — restart the "
                    "pool with a larger node_pad")
        tn = np.full(st.n_pad, -1, np.int32)
        tn[:len(pool.task_node)] = pool.task_node
        proc = np.zeros(np_cap, np.float32)
        slots = np.ones(np_cap, np.float32)
        for i, cap in enumerate(pool._node_caps):
            if cap is not None:
                # serving-profile unit time: static per node-epoch by the
                # linearity contract (request_ms(s) == request_ms()·s), so
                # the device program's node_proc·scale matches the host
                proc[i] = cap.request_ms()
                slots[i] = max(cap.spec.slots, 1)
        ulat, ulon, unet, ucode = self._packed_user()
        return st, tn, proc, slots, ulat, ulon, unet, ucode

    def _rebuild_static(self, view):
        pool = self.pool
        st, tn, proc, slots, ulat, ulon, unet, ucode = \
            self._host_static_arrays(view)
        self.static = FusedTickStatic(
            user_lat=jnp.asarray(ulat), user_lon=jnp.asarray(ulon),
            user_net=jnp.asarray(unet), user_code20=jnp.asarray(ucode),
            task_lat=st.lat, task_lon=st.lon, task_aff=st.aff,
            task_code20=st.code20, task_cloud=st.cloud,
            task_node=jnp.asarray(tn), node_proc=jnp.asarray(proc),
            node_slots=jnp.asarray(slots),
            shards=self._build_shards())
        self._epoch = view.epoch
        self._owner_version = pool.am.engine.owner_version

    def _build_shards(self) -> Optional[tuple]:
        """Per-shard index maps for the sharded scoring step (None when
        the engine is unsharded).  User→shard routing is computed once —
        locations never move; a shard's ``task_ix`` content changes under
        churn while its padded shape stays put (reused device arrays via
        the engine's per-shard adoption).  A shard appearing or vanishing
        changes the static pytree and retraces the tick once — a rare,
        coarse-region event, unlike per-tick churn."""
        pool = self.pool
        engine = pool.am.engine
        shard_view = engine.shard_view(
            pool.service_id, pool.am.tasks.get(pool.service_id, ()))
        if shard_view is None:
            self.p_min = 0
            self.border_cap = 0
            return None
        route_key = (shard_view.precision, shard_view.owner_version)
        if self._u_shard is None or self._u_shard[0] != route_key:
            if self._u_codes is None:
                from repro.core import geohash
                from repro.core.selection import CODE_PRECISION
                self._u_codes = geohash.encode_batch(
                    pool.locs[:, 0], pool.locs[:, 1], CODE_PRECISION)
            self._u_shard = (route_key, shard_view.route(self._u_codes))
        u_shard = self._u_shard[1]
        entries = []
        for sh in shard_view.shards:
            user_ix = np.nonzero(u_shard == sh.code)[0]
            if user_ix.size == 0:
                continue        # border pass covers its nodes if needed
            entries.append(ShardIx(
                user_ix=jnp.asarray(user_ix, jnp.int32),
                task_ix=jnp.asarray(sh.task_ix_padded(self.node_pad))))
        self.p_min = shard_view.precision
        self.border_cap = pool.shard_border_cap \
            if pool.shard_border_cap is not None \
            else self._default_border_cap()
        return tuple(entries)

    def init_state(self):
        """Upload the pool mirrors (populated by the host-side initial
        refresh) as the resident device state."""
        pool = self.pool
        view = pool._view()
        self._rebuild_static(view)
        u, k = pool.cand_task.shape
        self.state = FusedTickState(
            ema_nodes=jnp.full((u, self.ema_slots), -1, jnp.int32),
            ema_vals=jnp.full((u, self.ema_slots), jnp.nan, jnp.float32),
            ema_overflow=jnp.zeros((), bool),
            cand=jnp.asarray(pool.cand_task),
            active=jnp.asarray(pool.active),
            pending=jnp.asarray(pool.pending),
            running=jnp.asarray(pool.running),
            ticking=jnp.asarray(pool.ticking),
            reinit=jnp.zeros(u, bool),
            lat_probe=jnp.full((u, k), jnp.nan, jnp.float32),
            lat_frame=jnp.full((u, self.nf), jnp.nan, jnp.float32),
            cand_traffic=jnp.full((u, k), -1, jnp.int32),
            active_traffic=jnp.full(u, -1, jnp.int32),
            frame_count=jnp.zeros(u, jnp.int32),
            frame_sum=jnp.zeros(u, jnp.float32),
            failovers=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------- tick

    def _drain_deaths(self):
        deaths = self.deaths
        self.deaths = []
        if len(deaths) > DEATH_QUEUE_MAX:
            raise RuntimeError(
                f"{len(deaths)} breaks in one window > DEATH_QUEUE_MAX")
        arr = np.full(DEATH_QUEUE_MAX, -1, np.int32)
        arr[:len(deaths)] = deaths
        return arr, np.int32(len(deaths))

    def _refresh_mask(self):
        """(U,) bool — False for users inside a Beacon re-discovery
        window (``discovery_ms``); they keep their stale candidates for
        the tick, exactly like the host tick's filtered ``_refresh``."""
        m = self.pool._discovery_refresh_mask()
        if m is None:
            if self._all_refresh is None:
                self._all_refresh = np.ones(self.pool.n_users, bool)
            m = self._all_refresh
        return m

    def _dirty_input(self):
        """(U,) bool dirty rows for the tick program (pool order), or the
        cached all-False array when refresh is every-tick."""
        pool = self.pool
        if pool._rt is None:
            if self._no_dirty is None:
                self._no_dirty = np.zeros(pool.n_users, bool)
            return self._no_dirty
        t0 = time.perf_counter()
        dirty = pool._rt.dirty_mask(pool.sim.now)
        pool.phase_add("refresh_track", t0)
        return dirty

    def _note_refreshed(self, dirty, r_ok, outs):
        """Mirror the program's refresh set back into the tracker: clear
        marks, re-arm deadlines, account dirty fraction and fallbacks.
        (In-program reinit rows refresh too but have no host mark — the
        tracker only ever over-refreshes, never misses.)"""
        pool = self.pool
        rt = pool._rt
        if rt is None:
            return
        refreshed = dirty & pool.running & pool.ticking & r_ok
        if bool(np.asarray(outs.refresh_fallback).any()):
            rt.fallbacks += 1
        rt.note_refreshed(refreshed, pool.sim.now)
        rt.dirty_counts.append(int(refreshed.sum()))

    def _run_tick(self, free, sched, alive, need, deaths, n_deaths):
        """Run the tick program; returns per-user decision arrays in the
        pool's (original) user order."""
        pool = self.pool
        dirty = self._dirty_input()
        r_ok = self._refresh_mask()
        self.state, outs = _fused_tick(
            self.state, self.static, free, sched, alive, need, deaths,
            n_deaths, pool.alpha, pool.switch_margin, r_ok, dirty,
            p_min=self.p_min, border_cap=self.border_cap,
            refresh_cap=self.refresh_cap)
        self._stash_dirty = False       # tick folded the previous window
        if bool(np.asarray(outs.border_overflow).any()):
            raise RuntimeError(
                f"fused tick: border band exceeded {self.border_cap} "
                "users — restart the pool with a larger shard_border_cap "
                "(or a coarser shard_precision)")
        self._note_refreshed(dirty, r_ok, outs)
        return outs

    def tick(self):
        pool = self.pool
        t0 = time.perf_counter()
        view = pool._view()
        engine = pool.am.engine
        if view.epoch != self._epoch \
                or engine.owner_version != self._owner_version:
            # node-epoch change, or a Beacon handoff/re-home re-routed
            # regions (the transient: shard structure may retrace once)
            self._rebuild_static(view)
        free, sched, alive = view.padded_dynamic(
            self.node_pad, hidden=engine.hidden_nodes,
            locality=engine.data_locality.get(pool.service_id),
            queueing=engine.queueing.get(pool.service_id))
        need = np.int32(min(MIN_PROXIMITY_HITS, int(sched.sum())))
        deaths, n_deaths = self._drain_deaths()
        pool.phase_add("transport", t0)

        t0 = time.perf_counter()
        outs = self._run_tick(free, sched, alive, need, deaths, n_deaths)
        cand = self._pull(outs.cand)
        active = self._pull(outs.active)
        probe_ok = self._pull(outs.probe_ok)
        frame_ok = self._pull(outs.frame_ok)
        confirm = self._pull(outs.confirm)
        pool.phase_add("fused_tick", t0)

        t0 = time.perf_counter()
        # mirrors + switch records (scalar-identical timestamps/order)
        pool.cand_task = cand
        pool.active = active
        pool.pending = self._pull(outs.pending)
        pool.failovers = int(np.asarray(outs.failovers).sum())
        self.check_overflow()
        rows = np.nonzero(confirm)[0]
        # per-switch records match the host tick's (time, user, from, to)
        # stream; population-scale runs opt out via record_samples=False
        # (the host tick has no such toggle — it pays the append cost)
        if rows.size and pool.record_samples:
            from_node = self._pull(outs.from_node)
            now = pool.sim.now
            for u in rows:
                pool.switch_t.append(now)
                pool.switch_user.append(int(u))
                pool.switch_from.append(
                    pool._node_ids[int(from_node[u])])
                pool.switch_to.append(
                    pool._node_ids[pool.task_node[int(active[u])]])
        self._send_traffic(cand, active, probe_ok, frame_ok)
        pool.phase_add("transport", t0)

        if bool((pool.running & pool.ticking).any()):
            pool.ticks_run += 1
            pool.sim.after(pool.probe_period, self.tick)

    def _send_traffic(self, cand, active, probe_ok, frame_ok):
        """Admit one window of fluid traffic and stash its latencies:
        per-node ``arrive_batch`` in ascending node order, then the three
        jitter draws in the host tick's exact element order (probes
        row-major, then frames user-major)."""
        pool = self.pool
        nf = self.nf
        p_tasks = cand[probe_ok]
        p_nodes = pool.task_node[p_tasks]
        f_nodes = pool.task_node[active[frame_ok]]
        n_nodes = len(pool._node_ids)
        counts = np.bincount(p_nodes, minlength=n_nodes)
        counts += nf * np.bincount(f_nodes, minlength=n_nodes)
        pool.watch_node_indices(np.nonzero(counts)[0])

        p_cnt = int(probe_ok.sum())
        f_cnt = int(frame_ok.sum())
        total = p_cnt + f_cnt * nf
        if total == 0:
            return
        np_cap = self._node_cap()
        work0 = np.zeros(np_cap, np.float32)
        net_rate = np.zeros(np_cap, np.float32)
        now = pool.sim.now
        for nix in np.nonzero(counts)[0]:
            cap = pool._node_caps[nix]
            w0, in_rate, cap_rate = cap.arrive_batch(
                int(counts[nix]), pool.workload_scale, pool.probe_period,
                now)
            work0[nix] = w0
            net_rate[nix] = in_rate - cap_rate
        pool.requests_sent += total

        eps = [pool.sim.rng.standard_normal(total) for _ in range(3)]

        def split(e):
            dp = np.zeros(probe_ok.shape, np.float32)
            dp[probe_ok] = e[:p_cnt]
            df = np.zeros((len(frame_ok), nf), np.float32)
            df[frame_ok] = e[p_cnt:].reshape(-1, nf)
            return dp, df

        (e1p, e1f), (e2p, e2f), (e3p, e3f) = map(split, eps)
        # in-situ data access rides the frame (request) path only — the
        # per-user term is host-computed once and injected into every
        # backend identically (decision identity by construction)
        data_f = np.zeros(len(frame_ok), np.float32)
        data = pool._data_node_ms()
        if data is not None and f_nodes.size:
            data_f[frame_ok] = data[f_nodes]
            nearest, reps = pool._data_reps
            reads = pool.data_profile.reads_per_request * nf
            rep_counts = np.bincount(nearest[f_nodes],
                                     minlength=len(reps)) * reads
            pool.am.cargo_manager.note_read_load(
                pool.service_id, reps, rep_counts, pool.probe_period)
        self._push_traffic(work0, net_rate, probe_ok, frame_ok, data_f,
                           ((e1p, e1f), (e2p, e2f), (e3p, e3f)))
        self._stash_dirty = True
        if pool._lat_hist is not None:
            # frame-latency histogram (latency_hist=True): each window's
            # latency stash is pulled exactly once, right after it is
            # computed — one device round-trip per tick, bench-only
            lat = self._pull(self.state.lat_frame)
            lat = lat[np.isfinite(lat)]
            if lat.size:
                pool._lat_hist += np.histogram(
                    lat, bins=pool._lat_edges)[0]

    def _push_traffic(self, work0, net_rate, probe_ok, frame_ok, data_f,
                      splits):
        pool = self.pool
        (e1p, e1f), (e2p, e2f), (e3p, e3f) = splits
        self.state = _fused_traffic(
            self.state, self.static, work0, net_rate, probe_ok, frame_ok,
            e1p, e2p, e3p, e1f, e2f, e3f, data_f, pool.workload_scale,
            pool.frame_interval)

    # ------------------------------------------------------- maintenance

    def _pull(self, arr) -> np.ndarray:
        """Device per-user array -> host numpy in pool (original) user
        order; the mesh driver overrides with the inverse permutation."""
        return np.asarray(arr)

    def _run_flush(self, deaths, n_deaths):
        self.state = _fused_flush(self.state, self.static, deaths,
                                  n_deaths, self.pool.alpha)

    def flush(self):
        """Process queued breaks + fold the open window (metric reads).
        Free when nothing is pending — no device round-trip."""
        if self.state is None or not (self._stash_dirty or self.deaths):
            return
        deaths, n_deaths = self._drain_deaths()
        self._stash_dirty = False
        self._run_flush(deaths, n_deaths)
        pool = self.pool
        pool.cand_task = self._pull(self.state.cand)
        pool.active = self._pull(self.state.active)
        pool.failovers = int(np.asarray(self.state.failovers).sum())

    def sync_aggregates(self):
        self.flush()
        pool = self.pool
        pool.frame_count = self._pull(self.state.frame_count)\
            .astype(np.int64)
        pool.frame_sum = self._pull(self.state.frame_sum)\
            .astype(np.float64)

    def reset_aggregates(self):
        self.flush()
        self.state = self.state._replace(
            frame_count=jnp.zeros_like(self.state.frame_count),
            frame_sum=jnp.zeros_like(self.state.frame_sum))

    def set_running(self, running: np.ndarray):
        self.state = self.state._replace(running=jnp.asarray(running))

    def on_break(self, node_ix: int):
        self.deaths.append(int(node_ix))

    def check_overflow(self):
        if bool(np.asarray(self.state.ema_overflow).any()):
            raise RuntimeError(
                f"fused tick: a user outgrew its {self.ema_slots} EMA "
                "slots — restart the pool with a larger ema_slots")

    def _row(self, u: int) -> int:
        """Pool user index -> device state row (mesh driver permutes)."""
        return u

    def ema_dict(self, u: int):
        """Per-user node-id -> EMA map (tests/metrics; mirrors
        ``_EmaTable.as_dict``)."""
        self.flush()
        r = self._row(u)
        nodes = np.asarray(self.state.ema_nodes[r])
        vals = np.asarray(self.state.ema_vals[r], np.float64)
        ids = self.pool._node_ids
        return {ids[n]: float(v) for n, v in zip(nodes, vals)
                if n >= 0 and not np.isnan(v)}


# ---------------------------------------------------------------------------
# mesh driver
# ---------------------------------------------------------------------------

# pad-row fill per state field (device blocks are padded to a uniform
# per-device row count; pad rows are permanently not-running)
_STATE_PAD_FILL = dict(
    ema_nodes=-1, ema_vals=np.nan, cand=-1, active=-1, pending=-1,
    running=False, ticking=False, reinit=False, lat_probe=np.nan,
    lat_frame=np.nan, cand_traffic=-1, active_traffic=-1,
    frame_count=0, frame_sum=0.0)


class MeshTickDriver(FusedTickDriver):
    """Mesh-sharded fused tick (``ClientPool(mesh=...)``): the user
    population is split into per-device blocks by home region — region
    shards are bin-packed onto devices by user count — and every device
    runs the same SPMD tick body over only its own block.

    Identity with the single-device tick is structural, not numeric
    luck: a device block's region shards collapse into one synthetic
    union shard (its concatenated task lists), and at
    ``p >= shard_precision`` a user's proximity cells only ever match
    home-region tasks, so the union pass computes exactly the per-shard
    loop — same scores, same ascending-global-order ties.  Users the
    in-region widening cannot satisfy escalate to a per-device
    fixed-capacity border pass over the *replicated* full node set,
    which is verbatim the unsharded scoring pass — so even a user
    straddling a device boundary gets bit-identical candidates; device
    placement can only ever cost border capacity, never correctness.

    The host-visible decision stream stays in pool (original) user
    order: ``_perm``/``_pos`` translate between pool order and
    device-block order, so RNG draws, arrive_batch admission and switch
    records replay in the exact single-device sequence.  A Beacon
    handoff or node-epoch change that re-routes users across device
    boundaries re-homes them wholesale: state is pulled to pool order
    under the old placement and re-uploaded under the new one (at most
    one retrace — block shapes only ever grow, and churn changes task
    *content*, never shapes, so steady-state ticks never retrace)."""

    def __init__(self, pool, mesh, node_pad: int = 256,
                 ema_slots: int = 32):
        super().__init__(pool, node_pad=node_pad, ema_slots=ema_slots)
        from repro.distributed.sharding import make_pool_rules
        if len(mesh.axis_names) != 1:
            raise ValueError(
                "ClientPool mesh must be 1-D (a single users axis); "
                f"got axes {mesh.axis_names}")
        self.mesh = mesh
        self.users_axis = mesh.axis_names[0]
        self.n_dev = int(mesh.devices.size)
        self.rules = make_pool_rules(mesh)
        self._sharded = False
        self._ud = 0            # per-device user rows (monotonic)
        self._tloc = 0          # per-device task columns (monotonic)
        self._perm = None       # (Up,) device row -> pool user, -1 pad
        self._pos = None        # (U,) pool user -> device row
        self._valid = None      # (Up,) bool real rows
        self._local_task = None  # (D, Tloc) device-resident task lists
        self._programs = {}
        self._state_sh = None
        self._static_sh = None
        self._lt_sh = None

    # --------------------------------------------------------- placement

    def _default_border_cap(self) -> int:
        """Per-device border capacity (the border pass is local — each
        device escalates only its own block's unsatisfied users).  Also
        the per-device default for ``refresh_cap`` — before placement
        (``_ud`` unset) it returns a placeholder that
        ``_compute_placement`` re-derives."""
        ud = max(getattr(self, "_ud", 0), 1)
        return min(ud, max(128, -(-ud // 8 // 128) * 128))

    def _compute_placement(self):
        """Route users to region shards, bin-pack shards onto devices,
        and derive the block permutation + per-device task lists."""
        pool = self.pool
        engine = pool.am.engine
        D = self.n_dev
        u = pool.n_users
        if self._u_codes is None:
            from repro.core import geohash
            from repro.core.selection import CODE_PRECISION
            self._u_codes = geohash.encode_batch(
                pool.locs[:, 0], pool.locs[:, 1], CODE_PRECISION)
        shard_view = engine.shard_view(
            pool.service_id, pool.am.tasks.get(pool.service_id, ()))
        if shard_view is None:
            # unsharded engine: contiguous blocks, each device scores
            # its users against the full replicated set — identity by
            # construction (no region structure to exploit)
            self._sharded = False
            self.p_min = 0
            blocks = [b for b in
                      np.array_split(np.arange(u, dtype=np.int64), D)]
            local_cols = [np.full(1, -1, np.int32) for _ in range(D)]
        else:
            self._sharded = True
            from repro.core.selection import assign_shards_to_devices
            route_key = (shard_view.precision, shard_view.owner_version)
            if self._u_shard is None or self._u_shard[0] != route_key:
                self._u_shard = (route_key,
                                 shard_view.route(self._u_codes))
            u_shard = self._u_shard[1]
            shards = [(sh, np.nonzero(u_shard == sh.code)[0])
                      for sh in shard_view.shards]
            shards = [(sh, ix) for sh, ix in shards if ix.size]
            assign, _ = assign_shards_to_devices(
                [ix.size for _, ix in shards], D)
            users_d = [[] for _ in range(D)]
            tasks_d = [[] for _ in range(D)]
            for (sh, ix), d in zip(shards, assign):
                users_d[d].append(ix)
                tasks_d[d].append(sh.task_ix_padded(self.node_pad))
            # users routed to no shard always escalate to the (local,
            # full-set) border pass — park them on the lightest device
            homed = np.zeros(u, bool)
            for _, ix in shards:
                homed[ix] = True
            orphans = np.nonzero(~homed)[0]
            if orphans.size:
                d = int(np.argmin([sum(x.size for x in b)
                                   for b in users_d]))
                users_d[d].append(orphans)
            blocks = [np.concatenate(b).astype(np.int64) if b
                      else np.empty(0, np.int64) for b in users_d]
            local_cols = [np.concatenate(t) if t
                          else np.full(1, -1, np.int32) for t in tasks_d]
            self.p_min = shard_view.precision
        # uniform per-device sizes, monotonic: a handoff can only grow
        # them (one retrace), steady-state churn changes content only
        need_ud = max(1, max(b.size for b in blocks))
        self._ud = max(self._ud, -(-need_ud // 64) * 64)
        self._tloc = max(self._tloc, max(c.size for c in local_cols))
        up = D * self._ud
        perm = np.full(up, -1, np.int64)
        for d, b in enumerate(blocks):
            perm[d * self._ud: d * self._ud + b.size] = b
        valid = perm >= 0
        pos = np.empty(u, np.int64)
        pos[perm[valid]] = np.nonzero(valid)[0]
        self._perm, self._pos, self._valid = perm, pos, valid
        lt = np.full((D, self._tloc), -1, np.int32)
        for d, c in enumerate(local_cols):
            lt[d, :c.size] = c
        self.border_cap = pool.shard_border_cap \
            if pool.shard_border_cap is not None \
            else self._default_border_cap()
        if pool.refresh_period is not None and pool.refresh_cap is None:
            # per-device sparse capacity needs _ud — re-derive now that
            # placement fixed it (monotonic, so the program cache key
            # changes at most when a block grows)
            self.refresh_cap = self._default_border_cap()
        return lt

    def _to_dev(self, arr, fill=0):
        """Pool-order (U, ...) host array -> padded device-order
        (Up, ...)."""
        arr = np.asarray(arr)
        out = np.full((self._perm.shape[0],) + arr.shape[1:], fill,
                      arr.dtype)
        out[self._valid] = arr[self._perm[self._valid]]
        return out

    def _pull(self, arr) -> np.ndarray:
        return np.asarray(arr)[self._pos]

    def _row(self, u: int) -> int:
        return int(self._pos[u])

    # ------------------------------------------------------------ setup

    def _rebuild_static(self, view):
        from repro.distributed.sharding import (POOL_LOCAL_TASK_AXES,
                                                POOL_STATE_AXES,
                                                POOL_STATIC_AXES,
                                                pool_shardings)
        pool = self.pool
        st, tn, proc, slots, ulat, ulon, unet, ucode = \
            self._host_static_arrays(view)
        old = (self._perm, self._pos) if self._perm is not None else None
        lt = self._compute_placement()
        if self._static_sh is None:
            self._static_sh = pool_shardings(
                self.mesh, POOL_STATIC_AXES, self.rules)
            self._state_sh = pool_shardings(
                self.mesh, POOL_STATE_AXES, self.rules)
            self._lt_sh = pool_shardings(
                self.mesh, POOL_LOCAL_TASK_AXES,
                self.rules)["local_task"]
        host = dict(
            user_lat=self._to_dev(ulat), user_lon=self._to_dev(ulon),
            user_net=self._to_dev(unet), user_code20=self._to_dev(ucode),
            task_lat=np.asarray(st.lat), task_lon=np.asarray(st.lon),
            task_aff=np.asarray(st.aff),
            task_code20=np.asarray(st.code20),
            task_cloud=np.asarray(st.cloud), task_node=tn,
            node_proc=proc, node_slots=slots)
        self.static = FusedTickStatic(
            shards=None,
            **{k: jax.device_put(v, self._static_sh[k])
               for k, v in host.items()})
        self._local_task = jax.device_put(lt, self._lt_sh)
        self._epoch = view.epoch
        self._owner_version = pool.am.engine.owner_version
        if self.state is not None and old is not None and \
                not (old[0].shape == self._perm.shape
                     and np.array_equal(old[0], self._perm)):
            self._repack_state(old[1])

    def _upload_state(self, host, *, failovers: int, overflow: bool):
        """Upload pool-order host state under the current placement."""
        dev = {f: self._to_dev(host[f], _STATE_PAD_FILL[f])
               for f in _STATE_PAD_FILL}
        fo = np.zeros(self.n_dev, np.int32)
        fo[0] = failovers               # (D,) — the host reads the sum
        ov = np.zeros(self.n_dev, bool)
        ov[0] = overflow
        dev["failovers"] = fo
        dev["ema_overflow"] = ov
        self.state = FusedTickState(
            **{k: jax.device_put(v, self._state_sh[k])
               for k, v in dev.items()})

    def _repack_state(self, old_pos):
        """Re-home protocol: a handoff re-routed users across device
        boundaries — pull the state to pool order under the old
        placement, re-upload under the new one."""
        s = self.state
        host = {f: np.asarray(getattr(s, f))[old_pos]
                for f in _STATE_PAD_FILL}
        self._upload_state(
            host, failovers=int(np.asarray(s.failovers).sum()),
            overflow=bool(np.asarray(s.ema_overflow).any()))

    def init_state(self):
        pool = self.pool
        view = pool._view()
        self._rebuild_static(view)
        u, k = pool.cand_task.shape
        host = dict(
            ema_nodes=np.full((u, self.ema_slots), -1, np.int32),
            ema_vals=np.full((u, self.ema_slots), np.nan, np.float32),
            cand=np.asarray(pool.cand_task),
            active=np.asarray(pool.active),
            pending=np.asarray(pool.pending),
            running=np.asarray(pool.running),
            ticking=np.asarray(pool.ticking),
            reinit=np.zeros(u, bool),
            lat_probe=np.full((u, k), np.nan, np.float32),
            lat_frame=np.full((u, self.nf), np.nan, np.float32),
            cand_traffic=np.full((u, k), -1, np.int32),
            active_traffic=np.full(u, -1, np.int32),
            frame_count=np.zeros(u, np.int32),
            frame_sum=np.zeros(u, np.float32))
        self._upload_state(host, failovers=0, overflow=False)

    # ------------------------------------------------------------- tick

    def _programs_for(self) -> MeshPrograms:
        key = (self.p_min, self.border_cap, self._sharded,
               self.refresh_cap)
        prog = self._programs.get(key)
        if prog is None:
            prog = _make_mesh_programs(self.mesh, self.users_axis,
                                       self.p_min, self.border_cap,
                                       self._sharded, self.refresh_cap)
            self._programs[key] = prog
        return prog

    def _run_tick(self, free, sched, alive, need, deaths, n_deaths):
        pool = self.pool
        prog = self._programs_for()
        dirty = self._dirty_input()
        r_ok = self._refresh_mask()
        self.state, outs = prog.tick(
            self.state, self.static, self._local_task, free, sched,
            alive, need, deaths, n_deaths, pool.alpha,
            pool.switch_margin, self._to_dev(r_ok, False),
            self._to_dev(dirty, False))
        self._stash_dirty = False
        if bool(np.asarray(outs.border_overflow).any()):
            raise RuntimeError(
                f"fused tick: a device's border band exceeded "
                f"{self.border_cap} users — restart the pool with a "
                "larger shard_border_cap (or a coarser shard_precision)")
        self._note_refreshed(dirty, r_ok, outs)
        return outs

    def _push_traffic(self, work0, net_rate, probe_ok, frame_ok, data_f,
                      splits):
        pool = self.pool
        prog = self._programs_for()
        td = self._to_dev
        (e1p, e1f), (e2p, e2f), (e3p, e3f) = splits
        self.state = prog.traffic(
            self.state, self.static, work0, net_rate,
            td(probe_ok, False), td(frame_ok, False),
            td(e1p), td(e2p), td(e3p), td(e1f), td(e2f), td(e3f),
            td(data_f), pool.workload_scale, pool.frame_interval)

    def _run_flush(self, deaths, n_deaths):
        prog = self._programs_for()
        self.state = prog.flush(self.state, self.static, deaths,
                                n_deaths, self.pool.alpha)

    # ------------------------------------------------------- maintenance

    def reset_aggregates(self):
        self.flush()
        up = self._perm.shape[0]
        self.state = self.state._replace(
            frame_count=jax.device_put(
                np.zeros(up, np.asarray(self.state.frame_count).dtype),
                self._state_sh["frame_count"]),
            frame_sum=jax.device_put(
                np.zeros(up, np.asarray(self.state.frame_sum).dtype),
                self._state_sh["frame_sum"]))

    def set_running(self, running: np.ndarray):
        self.state = self.state._replace(running=jax.device_put(
            self._to_dev(running, False), self._state_sh["running"]))
