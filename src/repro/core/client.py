"""Armada client SDK (paper §4): 2-step selection + multi-connection FT.

Step 2 of service selection happens HERE: the client probes every candidate
with a real (small) request and keeps an EMA of end-to-end latency per
candidate.  The best candidate serves the workload; probing repeats
periodically and asynchronously, so overload and churn show up in the EMAs
and trigger switches.  All TopN connections stay warm — on a connection
break the client flips to the second-best candidate with zero downtime.

``mode`` selects the paper's baselines:
  armada      2-step selection + probing + failover (the system)
  geo         always the geographically closest node
  dedicated   dedicated nodes only (D6/A/B/C), probing within them
  cloud       cloud only
  reconnect   armada selection, but on failure waits + re-queries (Fig 10a)
  edge2cloud  armada selection, but fails over to cloud (Fig 10b)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import geohash
from repro.core.app_manager import ApplicationManager, Task
from repro.core.captain import Request
from repro.core.cluster import Topology
from repro.core.sim import Simulator

RECONNECT_DELAY_MS = 2000.0


@dataclass
class LatencySample:
    t: float
    ms: float
    node: str
    is_probe: bool = False


class Client:
    def __init__(self, sim: Simulator, topo: Topology,
                 am: ApplicationManager, client_id: str, service_id: str,
                 *, mode: str = "armada", frame_interval_ms: float = 0.0,
                 probe_period_ms: float = 2000.0, ema_alpha: float = 0.4,
                 switch_margin: float = 0.95, workload_scale: float = 1.0,
                 proc_scale_override: Optional[float] = None):
        self.sim = sim
        self.topo = topo
        self.am = am
        self.client_id = client_id
        self.service_id = service_id
        self.mode = mode
        self.loc = topo.nodes[client_id].loc
        self.net = topo.nodes[client_id].net_type
        self.frame_interval = frame_interval_ms
        self.probe_period = probe_period_ms
        self.alpha = ema_alpha
        self.switch_margin = switch_margin
        self.workload_scale = workload_scale

        self.candidates: List[Task] = []
        self.ema: Dict[str, float] = {}
        self.active: Optional[Task] = None
        self.running = False
        self.samples: List[LatencySample] = []
        self.switches: List[dict] = []
        self.downtime_until = 0.0
        self._pending_switch: Optional[str] = None   # two-round confirmation

    # ------------------------------------------------------------- control

    def start(self):
        self.running = True
        self.am.user_join(self.service_id, self)
        self._refresh_candidates(initial=True)

    def stop(self):
        self.running = False
        self.am.user_leave(self.service_id, self)
        for t in self.candidates:
            if t.captain is not None:
                t.captain.connections.discard(self)

    # -------------------------------------------------- candidate handling

    def _task_node(self, t: Task) -> str:
        return t.captain.node_id

    def _refresh_candidates(self, initial: bool = False):
        if not self.running:
            return
        # mode baselines filter the WIDE list, then trim to TopN — otherwise
        # a "dedicated-only" client would leak onto volunteer nodes
        wide = self.am.candidate_list(self.service_id, self.loc, self.net,
                                      top_n=64)
        cands = self._apply_mode_filter(wide)[:self.am.top_n]
        # keep warm connections to every candidate
        for t in self.candidates:
            if t not in cands and t.captain is not None:
                t.captain.connections.discard(self)
        for t in cands:
            if t.captain is not None:
                t.captain.connections.add(self)
        self.candidates = cands
        if not cands:
            self.sim.after(500.0, self._refresh_candidates)
            return
        # step 2: probe every candidate
        for t in cands:
            self._send(t, is_probe=True)
        if initial:
            # pick provisional best by RTT until probes return
            self.active = min(
                cands, key=lambda t: self.topo.rtt(self.client_id,
                                                   self._task_node(t)))
            self._send_frame()
            self.sim.after(self.probe_period, self._probe_tick)

    def _apply_mode_filter(self, cands: List[Task]) -> List[Task]:
        if self.mode == "geo":
            if not cands:
                return cands
            best = min(cands, key=lambda t: geohash.distance_km(
                *t.captain.spec.loc, *self.loc))
            return [best]
        if self.mode == "dedicated":
            ded = [t for t in cands if t.captain.spec.dedicated
                   and not t.captain.spec.is_cloud]
            return ded or cands
        if self.mode == "cloud":
            cl = [t for t in cands if t.captain.spec.is_cloud]
            return cl
        return cands

    def _probe_tick(self):
        if not self.running:
            return
        self._refresh_candidates()
        self._maybe_switch()
        self.sim.after(self.probe_period, self._probe_tick)

    def _maybe_switch(self):
        """Switch to a better candidate only when it beats the active EMA
        by the margin on TWO consecutive probe rounds — damps the herd
        oscillation naive probing causes after mass failures."""
        if not self.candidates:
            return
        known = [t for t in self.candidates
                 if self._task_node(t) in self.ema]
        if not known or self.active is None:
            return
        best = min(known, key=lambda t: self.ema[self._task_node(t)])
        cur = self._task_node(self.active)
        better = (best is not self.active and cur in self.ema
                  and self.ema[self._task_node(best)]
                  < self.switch_margin * self.ema[cur])
        if not better:
            self._pending_switch = None
            return
        if self._pending_switch != self._task_node(best):
            self._pending_switch = self._task_node(best)
            return
        self.switches.append({"t": self.sim.now, "from": cur,
                              "to": self._task_node(best)})
        self.active = best
        self._pending_switch = None

    # ------------------------------------------------------------ traffic

    def _send(self, task: Task, is_probe: bool):
        if task.captain is None or not task.captain.alive:
            return
        node = task.captain.node_id
        rtt = self.sim.jitter(self.topo.rtt(self.client_id, node), 0.08)
        req = Request(client=self, task_id=task.task_id,
                      sent_at=self.sim.now, rtt=rtt, node_id=node,
                      proc_scale=self.workload_scale, is_probe=is_probe,
                      on_done=self._on_response)
        self.sim.after(rtt / 2, task.captain.arrive, req)

    def _send_frame(self):
        if not self.running or self.active is None:
            return
        self._send(self.active, is_probe=False)

    def _on_response(self, req: Request):
        if not self.running:
            return
        ms = self.sim.now - req.sent_at
        node = req.node_id
        prev = self.ema.get(node)
        self.ema[node] = ms if prev is None else \
            self.alpha * ms + (1 - self.alpha) * prev
        if req.is_probe:
            self.samples.append(LatencySample(self.sim.now, ms, node, True))
            return
        self.samples.append(LatencySample(self.sim.now, ms, node))
        if self.frame_interval > 0:
            self.sim.after(self.frame_interval, self._send_frame)
        else:
            self._send_frame()

    # ------------------------------------------------------- fault handling

    def on_connection_break(self, node_id: str):
        """A warm connection broke (node failed/left)."""
        if not self.running:
            return
        self.ema.pop(node_id, None)
        dead = [t for t in self.candidates
                if t.captain is None or not t.captain.alive]
        for t in dead:
            self.candidates.remove(t)
        active_died = (self.active is None or self.active.captain is None
                       or not self.active.captain.alive)
        if not active_died:
            return
        if self.mode == "reconnect":
            # baseline: tear down, wait, re-query the control plane
            self.active = None
            self.downtime_until = self.sim.now + RECONNECT_DELAY_MS

            def _reconnect():
                self._refresh_candidates()
                if self.candidates:
                    self.active = self.candidates[0]
                    self._send_frame()
            self.sim.after(RECONNECT_DELAY_MS, _reconnect)
            return
        if self.mode == "edge2cloud":
            cloud = [t for t in self.am.tasks[self.service_id]
                     if t.status == "running" and t.captain is not None
                     and t.captain.spec.is_cloud]
            if cloud:
                self.active = cloud[0]
                cloud[0].captain.connections.add(self)
                self._send_frame()
                return
        # armada: instant switch to the best remaining warm candidate
        if self.candidates:
            known = [t for t in self.candidates
                     if self._task_node(t) in self.ema]
            self.active = min(
                known, key=lambda t: self.ema[self._task_node(t)]) \
                if known else self.candidates[0]
            self._send_frame()            # zero downtime: next frame flows
        else:
            self._refresh_candidates(initial=True)

    # ------------------------------------------------------------- metrics

    def mean_latency(self, since: float = 0.0) -> float:
        xs = [s.ms for s in self.samples if not s.is_probe and s.t >= since]
        return sum(xs) / len(xs) if xs else float("nan")
