"""Armada client SDK (paper §4): 2-step selection + multi-connection FT.

Step 2 of service selection happens HERE: the client probes every candidate
with a real (small) request and keeps an EMA of end-to-end latency per
candidate.  The best candidate serves the workload; probing repeats
periodically and asynchronously, so overload and churn show up in the EMAs
and trigger switches.  All TopN connections stay warm — on a connection
break the client flips to the second-best candidate with zero downtime.

``mode`` selects the paper's baselines:
  armada      2-step selection + probing + failover (the system)
  geo         always the geographically closest node
  dedicated   dedicated nodes only (D6/A/B/C), probing within them
  cloud       cloud only
  reconnect   armada selection, but on failure waits + re-queries (Fig 10a)
  edge2cloud  armada selection, but fails over to cloud (Fig 10b)

Scalar-vs-pool responsibility map
---------------------------------
This class drives ONE user through per-request simulator events; the
population-scale path is ``repro.core.client_pool.ClientPool`` (SoA
arrays, one selection call + one vectorized EMA/switch update per tick).
The *policy* — what to probe, when to switch, where to fail over — lives
in ``client_pool``'s pure array functions and is shared by both:

  =====================  ==========================  ====================
  concern                scalar ``Client``           ``ClientPool``
  =====================  ==========================  ====================
  event loop             per-user heap events        pool-level tick
  wide-list size         ``WIDE_TOP_N`` (shared)     ``WIDE_TOP_N``
  baseline filters       ``mode_filter`` (U=1 row)   ``mode_filter``
  latency EMAs           ``ema_fold`` (U=1 row)      ``ema_fold`` batched
  two-round switches     ``switch_decide`` (U=1)     ``switch_decide``
  break failover         inline (this file)          ``failover_pick``
  transport              ``Captain.arrive``          events | fluid batch
  =====================  ==========================  ====================

A pool with ``transport="events"`` reproduces U scalar Clients
bit-for-bit (tests/test_client_pool.py); keep this class as the readable
reference and parity oracle.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.app_manager import ApplicationManager, Task
from repro.core.captain import Request
from repro.core.client_pool import (LatencySample, MODE_INDEX,
                                    RECONNECT_DELAY_MS, WIDE_TOP_N,
                                    ema_fold, mode_filter, switch_decide)
from repro.core.cluster import Topology
from repro.core.sim import Simulator


class Client:
    def __init__(self, sim: Simulator, topo: Topology,
                 am: ApplicationManager, client_id: str, service_id: str,
                 *, mode: str = "armada", frame_interval_ms: float = 0.0,
                 probe_period_ms: float = 2000.0, ema_alpha: float = 0.4,
                 switch_margin: float = 0.95, workload_scale: float = 1.0,
                 proc_scale_override: Optional[float] = None):
        self.sim = sim
        self.topo = topo
        self.am = am
        self.client_id = client_id
        self.service_id = service_id
        self.mode = mode
        self.loc = topo.nodes[client_id].loc
        self.net = topo.nodes[client_id].net_type
        self.frame_interval = frame_interval_ms
        self.probe_period = probe_period_ms
        self.alpha = ema_alpha
        self.switch_margin = switch_margin
        self.workload_scale = workload_scale

        self.candidates: List[Task] = []
        self.ema: Dict[str, float] = {}
        self.active: Optional[Task] = None
        self.running = False
        self.samples: List[LatencySample] = []
        self.switches: List[dict] = []
        self.downtime_until = 0.0
        self._pending_switch: Optional[Task] = None  # two-round confirmation

    # ------------------------------------------------------------- control

    def start(self):
        self.running = True
        self.am.user_join(self.service_id, self)
        self._refresh_candidates(initial=True)

    def stop(self):
        self.running = False
        self.am.user_leave(self.service_id, self)
        for t in self.candidates:
            if t.captain is not None:
                t.captain.connections.discard(self)

    # -------------------------------------------------- candidate handling

    def _task_node(self, t: Task) -> str:
        return t.captain.node_id

    def _refresh_candidates(self, initial: bool = False):
        if not self.running:
            return
        # mode baselines filter the WIDE list, then trim to TopN — otherwise
        # a "dedicated-only" client would leak onto volunteer nodes
        wide = self.am.candidate_list(self.service_id, self.loc, self.net,
                                      top_n=WIDE_TOP_N)
        cands = self._apply_mode_filter(wide)[:self.am.top_n]
        # keep warm connections to every candidate
        for t in self.candidates:
            if t not in cands and t.captain is not None:
                t.captain.connections.discard(self)
        for t in cands:
            if t.captain is not None:
                t.captain.connections.add(self)
        self.candidates = cands
        if not cands:
            self.sim.after(500.0, self._refresh_candidates)
            return
        # step 2: probe every candidate
        for t in cands:
            self._send(t, is_probe=True)
        if initial:
            # pick provisional best by RTT until probes return
            self.active = min(
                cands, key=lambda t: self.topo.rtt(self.client_id,
                                                   self._task_node(t)))
            self._send_frame()
            self.sim.after(self.probe_period, self._probe_tick)

    def _apply_mode_filter(self, cands: List[Task]) -> List[Task]:
        """Baseline filter over the wide list — the shared ``mode_filter``
        array policy applied to a single-user row."""
        if not cands:
            return list(cands)
        out = mode_filter(
            np.arange(len(cands), dtype=np.int32)[None, :],
            np.array([MODE_INDEX.get(self.mode, MODE_INDEX["armada"])],
                     np.int8),
            len(cands),
            np.array([t.captain.spec.is_cloud for t in cands]),
            np.array([t.captain.spec.dedicated for t in cands]),
            np.array([t.captain.spec.loc[0] for t in cands]),
            np.array([t.captain.spec.loc[1] for t in cands]),
            np.array([self.loc[0]]), np.array([self.loc[1]]))
        return [cands[j] for j in out[0] if j >= 0]

    def _probe_tick(self):
        if not self.running:
            return
        self._refresh_candidates()
        self._maybe_switch()
        self.sim.after(self.probe_period, self._probe_tick)

    def _maybe_switch(self):
        """Switch to a better candidate only when the pending nomination
        still beats the active EMA by the margin one probe round later —
        damps the herd oscillation naive probing causes after mass
        failures without starving when the candidate list churns.
        Decision logic is the shared ``switch_decide`` array policy on a
        U=1 row; the pending target's EMA/liveness are looked up directly
        so it confirms even after dropping off the candidate list."""
        if not self.candidates:
            return
        cands = self.candidates
        nodes = [self._task_node(t) for t in cands]
        cur = None if self.active is None else self._task_node(self.active)
        # slot ids stand in for task identity; active/pending tasks
        # outside the candidate list get sentinel ids no slot can equal
        try:
            a_ix = next(i for i, t in enumerate(cands) if t is self.active)
        except StopIteration:
            a_ix = -1 if self.active is None else len(cands)
        p = self._pending_switch
        try:
            p_ix = -1 if p is None else next(
                i for i, t in enumerate(cands) if t is p)
        except StopIteration:
            p_ix = len(cands) + 1
        pend_ema = (np.nan if p is None
                    else self.ema.get(self._task_node(p), np.nan))
        pend_alive = (p is not None and p.captain is not None
                      and p.captain.alive)
        confirm, target, new_pending = switch_decide(
            np.arange(len(nodes), dtype=np.int64)[None, :],
            np.array([[self.ema.get(n, np.nan) for n in nodes]]),
            np.array([a_ix]),
            np.array([np.nan if cur is None
                      else self.ema.get(cur, np.nan)]),
            np.array([p_ix]), np.array([pend_ema]),
            np.array([pend_alive]), self.switch_margin)
        np_ix = int(new_pending[0])
        self._pending_switch = (None if np_ix < 0
                                else cands[np_ix] if np_ix < len(cands)
                                else p)
        if confirm[0]:
            t_ix = int(target[0])
            best = cands[t_ix] if t_ix < len(cands) else p
            self.switches.append({"t": self.sim.now, "from": cur,
                                  "to": self._task_node(best)})
            self.active = best

    # ------------------------------------------------------------ traffic

    def _send(self, task: Task, is_probe: bool):
        if task.captain is None or not task.captain.alive:
            return
        node = task.captain.node_id
        rtt = self.sim.jitter(self.topo.rtt(self.client_id, node), 0.08)
        req = Request(client=self, task_id=task.task_id,
                      sent_at=self.sim.now, rtt=rtt, node_id=node,
                      proc_scale=self.workload_scale, is_probe=is_probe,
                      on_done=self._on_response)
        self.sim.after(rtt / 2, task.captain.arrive, req)

    def _send_frame(self):
        if not self.running or self.active is None:
            return
        self._send(self.active, is_probe=False)

    def _on_response(self, req: Request):
        if not self.running:
            return
        ms = self.sim.now - req.sent_at
        node = req.node_id
        prev = self.ema.get(node, np.nan)
        self.ema[node] = float(ema_fold(
            np.array([prev]), np.array([ms]), self.alpha)[0])
        if req.is_probe:
            self.samples.append(LatencySample(self.sim.now, ms, node, True))
            return
        self.samples.append(LatencySample(self.sim.now, ms, node))
        if self.frame_interval > 0:
            self.sim.after(self.frame_interval, self._send_frame)
        else:
            self._send_frame()

    # ------------------------------------------------------- fault handling

    def on_connection_break(self, node_id: str):
        """A warm connection broke (node failed/left)."""
        if not self.running:
            return
        self.ema.pop(node_id, None)
        dead = [t for t in self.candidates
                if t.captain is None or not t.captain.alive]
        for t in dead:
            self.candidates.remove(t)
        active_died = (self.active is None or self.active.captain is None
                       or not self.active.captain.alive)
        if not active_died:
            return
        if self.mode == "reconnect":
            # baseline: tear down, wait, re-query the control plane
            self.active = None
            self.downtime_until = self.sim.now + RECONNECT_DELAY_MS

            def _reconnect():
                self._refresh_candidates()
                if self.candidates:
                    self.active = self.candidates[0]
                    self._send_frame()
            self.sim.after(RECONNECT_DELAY_MS, _reconnect)
            return
        if self.mode == "edge2cloud":
            cloud = [t for t in self.am.tasks[self.service_id]
                     if t.status == "running" and t.captain is not None
                     and t.captain.spec.is_cloud]
            if cloud:
                self.active = cloud[0]
                cloud[0].captain.connections.add(self)
                self._send_frame()
                return
        # armada: instant switch to the best remaining warm candidate
        if self.candidates:
            known = [t for t in self.candidates
                     if self._task_node(t) in self.ema]
            self.active = min(
                known, key=lambda t: self.ema[self._task_node(t)]) \
                if known else self.candidates[0]
            self._send_frame()            # zero downtime: next frame flows
        else:
            self._refresh_candidates(initial=True)

    # ------------------------------------------------------------- metrics

    def mean_latency(self, since: float = 0.0) -> float:
        xs = [s.ms for s in self.samples if not s.is_probe and s.t >= since]
        return sum(xs) / len(xs) if xs else float("nan")
