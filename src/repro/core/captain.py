"""Captain: an Armada edge compute node (paper §3.3.2).

Hosts service replicas (tasks), processes offloaded frames through a
``slots``-server queue, reports load/layers via heartbeats, and notifies
warm-connected clients on failure (the multi-connection strategy's break
signal).  Processing time = node's per-frame speed × service workload scale
× jitter — calibrated against the real jitted models in
benchmarks/bench_heterogeneity.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.cluster import NodeSpec, Topology
from repro.core.sim import Simulator


@dataclass
class Request:
    client: "object"              # repro.core.client.Client
    task_id: str
    sent_at: float
    rtt: float
    node_id: str = ""
    proc_scale: float = 1.0
    is_probe: bool = False
    on_done: Optional[Callable] = None
    storage_ops: int = 0          # cargo reads/writes piggybacked (facerec)


class Captain:
    def __init__(self, sim: Simulator, topo: Topology, spec: NodeSpec):
        self.sim = sim
        self.topo = topo
        self.spec = spec
        self.node_id = spec.node_id
        self.alive = True
        self.tasks: Dict[str, "object"] = {}         # task_id -> Task
        self.connections: Set[object] = set()
        self.queue: List[Request] = []
        self.busy = 0
        self.processed = 0
        self.registered_at: Optional[float] = None

    # ------------------------------------------------------------- status

    def load(self) -> float:
        return (self.busy + len(self.queue)) / max(self.spec.slots, 1)

    def free_fraction(self) -> float:
        return max(0.0, 1.0 - self.load())

    def heartbeat(self) -> Dict:
        return {"node": self.node_id, "load": self.load(),
                "layers": set(self.spec.layers), "alive": self.alive,
                "tasks": list(self.tasks)}

    # ------------------------------------------------------------ serving

    def arrive(self, req: Request):
        if not self.alive:
            return                       # connection break handles clients
        if self.busy < self.spec.slots:
            self._start(req)
        else:
            self.queue.append(req)

    def _start(self, req: Request):
        self.busy += 1
        proc = self.sim.jitter(self.spec.proc_ms * req.proc_scale, 0.06)
        self.sim.after(max(proc, 0.1), self._finish, req)

    def _finish(self, req: Request):
        if not self.alive:
            return
        self.busy -= 1
        self.processed += 1
        if self.queue:
            self._start(self.queue.pop(0))
        back = self.sim.jitter(req.rtt / 2, 0.08)
        if req.on_done is not None:
            self.sim.after(back, req.on_done, req)

    # ------------------------------------------------------------ failure

    def fail(self):
        """Node churn: volunteer leaves / crashes. Warm connections break
        immediately (the paper's zero-downtime switch signal)."""
        if not self.alive:
            return
        self.alive = False
        self.queue.clear()
        self.busy = 0
        self.sim.log("node_fail", node=self.node_id)
        for client in list(self.connections):
            self.sim.after(0.1, client.on_connection_break, self.node_id)
        self.connections.clear()

    def recover(self):
        self.alive = True
        self.sim.log("node_recover", node=self.node_id)
