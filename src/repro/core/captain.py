"""Captain: an Armada edge compute node (paper §3.3.2).

Hosts service replicas (tasks), processes offloaded frames through a
``slots``-server queue, reports load/layers via heartbeats, and notifies
warm-connected clients on failure (the multi-connection strategy's break
signal).  Per-request processing time comes from the captain's
:class:`~repro.serving.profile.ServingProfile` (``request_ms`` — the
served model's calibrated frame/decode time × node speed × service
workload scale × jitter, calibrated against the real jitted models in
benchmarks/bench_heterogeneity.py); nodes without an attached profile
keep the historical synthetic draw ``spec.proc_ms × scale`` exactly.
Heartbeats additionally carry serving occupancy, the expected queueing
delay (consumed by SelectionEngine's queueing-aware load term), and the
real-mode measured decode EMA.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import NodeSpec, Topology
from repro.core.sim import Simulator


@dataclass
class Request:
    client: "object"              # repro.core.client.Client / ClientPool
    task_id: str
    sent_at: float
    rtt: float
    node_id: str = ""
    proc_scale: float = 1.0
    is_probe: bool = False
    on_done: Optional[Callable] = None
    storage_ops: int = 0          # cargo reads/writes piggybacked (facerec)
    user_ix: int = -1             # pool user index (events transport)


class ConnectionSet:
    """Insertion-ordered set of warm connections.

    Failure notifications draw RNG (the failover frame's jitter), so their
    order must be deterministic and reproducible across processes — a plain
    ``set`` of client objects iterates in id()-hash order, which varies
    run to run.  Backing the set with a dict preserves the order clients
    opened their connections, which is also the order the vectorized
    ``ClientPool`` replays them in.
    """

    def __init__(self):
        self._d: Dict[object, None] = {}

    def add(self, obj):
        self._d[obj] = None

    def discard(self, obj):
        self._d.pop(obj, None)

    def clear(self):
        self._d.clear()

    def __iter__(self):
        return iter(self._d)

    def __len__(self):
        return len(self._d)

    def __contains__(self, obj):
        return obj in self._d


class Captain:
    def __init__(self, sim: Simulator, topo: Topology, spec: NodeSpec):
        self.sim = sim
        self.topo = topo
        self.spec = spec
        self.node_id = spec.node_id
        self.alive = True
        self.tasks: Dict[str, "object"] = {}         # task_id -> Task
        # serving profile (repro.serving.profile.ServingProfile) — the
        # latency model behind this node.  None = synthetic: request time
        # is spec.proc_ms, read live so topology-level proc_ms rescaling
        # keeps working
        self.profile = spec.profile
        self.connections = ConnectionSet()
        self.queue: List[Request] = []
        self.busy = 0
        self.processed = 0
        self.registered_at: Optional[float] = None
        # fluid data plane (ClientPool batched transport): pending work in
        # proc-milliseconds, drained at ``slots`` work-ms per wall-ms
        self.fluid_work = 0.0
        self.fluid_updated = 0.0

    # ------------------------------------------------------------- status

    def request_ms(self, proc_scale: float = 1.0) -> float:
        """Effective per-request service time (ms) through the serving
        profile — ``spec.proc_ms * proc_scale`` when no profile is
        attached.  Linear in ``proc_scale`` by contract: the fused
        device tick bakes ``request_ms(1.0)`` into a static per-node
        scalar and multiplies by the workload scale on device."""
        if self.profile is None:
            return self.spec.proc_ms * proc_scale
        return self.profile.request_ms(proc_scale)

    def load(self) -> float:
        return (self.busy + len(self.queue) + self._fluid_requests()) \
            / max(self.spec.slots, 1)

    def free_fraction(self) -> float:
        return max(0.0, 1.0 - self.load())

    def queueing_delay_ms(self) -> float:
        """Expected wait (ms) for a request arriving now: backlog ahead
        of it (events queue + lazily-drained fluid work) over the node's
        drain capacity.  Unlike ``free_fraction`` — which clamps at 0
        once the backlog exceeds the slot count — this keeps growing
        with the backlog, so the selection engine's queueing-aware load
        term can tell a slightly-busy node from a drowning one."""
        unit = self.request_ms()
        work = (len(self.queue) + self._fluid_requests()) * unit
        return work / max(self.spec.slots, 1)

    def heartbeat(self) -> Dict:
        p = self.profile
        return {"node": self.node_id, "load": self.load(),
                "layers": set(self.spec.layers), "alive": self.alive,
                "tasks": list(self.tasks),
                # serving-aware data plane: occupancy + expected queueing
                # delay feed the engine's queueing-aware scoring;
                # decode_ms surfaces the real-mode measured decode/frame
                # EMA (None for surrogate/synthetic nodes)
                "model": p.model_id if p is not None else "synthetic",
                "occupancy": min(1.0, self.load()),
                "queue_ms": self.queueing_delay_ms(),
                "decode_ms": p.measured_ms() if p is not None else None}

    # ------------------------------------------------------------ serving

    def arrive(self, req: Request):
        if not self.alive:
            return                       # connection break handles clients
        if self.busy < self.spec.slots:
            self._start(req)
        else:
            self.queue.append(req)

    def _start(self, req: Request):
        self.busy += 1
        proc = self.sim.jitter(self.request_ms(req.proc_scale), 0.06)
        self.sim.after(max(proc, 0.1), self._finish, req)

    def _finish(self, req: Request):
        if not self.alive:
            return
        self.busy -= 1
        self.processed += 1
        if self.queue:
            self._start(self.queue.pop(0))
        back = self.sim.jitter(req.rtt / 2, 0.08)
        if req.on_done is not None:
            self.sim.after(back, req.on_done, req)

    # ----------------------------------------------- fluid batched serving

    def _fluid_requests(self) -> float:
        """Fluid backlog expressed in request-equivalents (for ``load``).

        Read-only lazy drain: a node that stopped receiving batches must
        not report its last committed backlog forever (selection would
        deprioritize it permanently and ``scale_down`` could never reclaim
        it)."""
        if self.fluid_work <= 0.0:
            return 0.0
        dt = self.sim.now - self.fluid_updated
        work = self.fluid_work - self.spec.slots * dt if dt > 0 \
            else self.fluid_work
        return max(0.0, work) / max(self.request_ms(), 1e-9)

    def drain_fluid(self, now: float):
        """Lazily drain the fluid backlog up to ``now`` (capacity =
        ``slots`` work-ms per wall-ms).  ``fluid_updated`` never moves
        backwards — capacity already credited to a committed window must
        not be credited again by a second batch in the same window."""
        dt = now - self.fluid_updated
        if dt > 0:
            self.fluid_work = max(
                0.0, self.fluid_work - self.spec.slots * dt)
            self.fluid_updated = now

    def arrive_batch(self, n_requests: float, proc_scale: float,
                     window_ms: float, now: float
                     ) -> Tuple[float, float, float]:
        """Admit a tick's worth of pool traffic as fluid work.

        ``n_requests`` requests of ``request_ms(proc_scale)`` work each,
        uniformly spread over ``[now, now + window_ms)``.  Returns
        ``(work0, in_rate, cap_rate)`` — the backlog at window start (ms of
        work), the arrival work rate, and the drain rate — from which the
        caller computes per-request queueing delays vectorized:
        ``wait(tau) = max(0, work0 + (in_rate - cap_rate) * tau) / slots``.

        The terminal backlog is committed immediately, and drain capacity
        is credited only for wall-time not yet accounted — overlapping
        batches from several pools stack their work without double-counting
        the node's capacity over the shared window.
        """
        self.drain_fluid(now)
        work0 = self.fluid_work
        work_in = n_requests * self.request_ms() * proc_scale
        cap_rate = float(self.spec.slots)
        in_rate = work_in / max(window_ms, 1e-9)
        end = now + window_ms
        credit = max(0.0, end - max(self.fluid_updated, now))
        self.fluid_work = max(0.0, work0 + work_in - cap_rate * credit)
        self.fluid_updated = max(self.fluid_updated, end)
        self.processed += int(n_requests)
        return work0, in_rate, cap_rate

    # ------------------------------------------------------------ failure

    def fail(self):
        """Node churn: volunteer leaves / crashes. Warm connections break
        immediately (the paper's zero-downtime switch signal)."""
        if not self.alive:
            return
        self.alive = False
        self.queue.clear()
        self.busy = 0
        self.fluid_work = 0.0
        self.sim.log("node_fail", node=self.node_id)
        for client in list(self.connections):
            self.sim.after(0.1, client.on_connection_break, self.node_id)
        self.connections.clear()

    def recover(self):
        self.alive = True
        self.sim.log("node_recover", node=self.node_id)
