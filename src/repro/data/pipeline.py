"""Deterministic, restartable synthetic token pipeline.

Production shape: each data-parallel host reads only its shard of the
global batch (``host_index``/``host_count``), batches are a pure function
of (seed, step) so restart-from-checkpoint replays identically without
persisting reader state, and a background prefetch thread keeps
``prefetch`` batches ahead of the step loop.

The generator synthesizes a Zipf-ish unigram stream with short-range
structure (n-gram copy process) — enough signal for loss to drop during
the examples' training runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, *, batch: int, seq: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 prefetch: int = 2):
        assert batch % host_count == 0
        self.cfg = cfg
        self.global_batch = batch
        self.local_batch = batch // host_count
        self.seq = seq
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_step = 0

    # ------------------------------------------------------------- batches

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host): restart-deterministic."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.host_index)
        V = self.cfg.vocab_size
        B, T = self.local_batch, self.seq
        # zipf-ish unigrams
        ranks = rng.zipf(1.3, size=(B, T + 1)).astype(np.int64)
        toks = np.clip(ranks, 1, V - 1).astype(np.int32)
        # short-range copy structure: repeat a window with p=0.3
        for b in range(min(B, 8)):
            if rng.random() < 0.3 and T > 16:
                start = int(rng.integers(0, T - 16))
                toks[b, start + 8:start + 16] = toks[b, start:start + 8]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ------------------------------------------------------------ prefetch

    def start(self, from_step: int = 0):
        self.stop()
        self._stop.clear()
        self._next_step = from_step
        self._q = queue.Queue(maxsize=self.prefetch)
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._next_step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.batch_at(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        if self._q is None:
            b = self.batch_at(self._next_step)
            self._next_step += 1
            return b
        step, b = self._q.get()
        return b

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
            self._thread = None
        self._q = None
