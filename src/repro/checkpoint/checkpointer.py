"""Fault-tolerant checkpointing (no orbax in this container).

Design for 1000+ nodes, scaled down to this box:

* **sharded**: each host writes only its param shards (here: one host, but
  the layout keys every leaf by pytree path and records shard metadata)
* **async**: the step thread snapshots device arrays to host memory and a
  writer thread persists them — training never blocks on disk
* **atomic**: writes go to ``step_N.tmp/`` then rename to ``step_N/``;
  restore picks the newest COMPLETE step, so a crash mid-write is harmless
* **replicated**: an optional Cargo replica set mirrors the manifest +
  shards across storage nodes (volatile-compute assumption, paper §3.4)
* **self-validating**: every shard carries a checksum, verified on restore
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import pickle
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
        return out
    if isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
        return out
    out[prefix] = tree
    return out


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True, cargo_replicas=None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self.cargo_replicas = cargo_replicas or []
        self._thread: Optional[threading.Thread] = None
        self.write_log: List[dict] = []

    # ---------------------------------------------------------------- save

    def save(self, step: int, state: Dict[str, Any]):
        """Snapshot to host (blocking) + persist (async by default)."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()                               # one writer in flight
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray]):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "shards": {}}
        for key, arr in host.items():
            fn = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
            path = tmp / fn
            np.save(path, arr, allow_pickle=False)
            digest = hashlib.md5(path.read_bytes()).hexdigest()
            manifest["shards"][key] = {
                "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "md5": digest,
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        self.write_log.append({"step": step, "bytes": sum(
            a.nbytes for a in host.values())})
        self._gc()
        self._replicate(final, manifest)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def _replicate(self, final: pathlib.Path, manifest: dict):
        """Mirror manifest+shards into Cargo replicas (volatile compute)."""
        for cargo in self.cargo_replicas:
            store = cargo.stores.setdefault("__ckpt__", {})
            store[f"manifest/{manifest['step']}"] = json.dumps(
                manifest).encode()
            for key, meta in manifest["shards"].items():
                store[f"{manifest['step']}/{key}"] = \
                    (final / meta["file"]).read_bytes()

    # ------------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Dict[str, Any]):
        """Restore into the structure (and shardings) of ``like``."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        final = self.dir / f"step_{step:08d}"
        manifest = json.loads((final / "manifest.json").read_text())
        flat_like = _flatten(like)
        out: Dict[str, np.ndarray] = {}
        for key, ref in flat_like.items():
            meta = manifest["shards"][key]
            path = final / meta["file"]
            digest = hashlib.md5(path.read_bytes()).hexdigest()
            if digest != meta["md5"]:
                raise IOError(f"checksum mismatch for {key}")
            out[key] = np.load(path)
        return _unflatten(out, like), step


def _unflatten(flat: Dict[str, np.ndarray], like, prefix=""):
    if isinstance(like, dict):
        return {k: _unflatten(flat, like[k],
                              f"{prefix}/{k}" if prefix else k)
                for k in sorted(like)}
    if isinstance(like, (list, tuple)) and not hasattr(like, "shape"):
        vals = [_unflatten(flat, v, f"{prefix}/{i}")
                for i, v in enumerate(like)]
        return type(like)(*vals) if hasattr(like, "_fields") else \
            type(like)(vals)
    arr = flat[prefix]
    if hasattr(like, "dtype"):
        arr = arr.astype(like.dtype)
    return jax.numpy.asarray(arr)
