"""Sharded AdamW (no optax in this container — built from scratch).

Moments are fp32 and inherit the parameter sharding (params are already 2D
ZeRO/TP sharded by the rules engine, so optimizer state is ZeRO-sharded for
free).  Update math runs in fp32 regardless of param dtype; global-norm
clipping and decoupled weight decay included.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # pytree like params (fp32)
    nu: Any                  # pytree like params (fp32)


class AdamW:
    def __init__(self, tc: TrainConfig):
        self.tc = tc

    def init(self, params) -> OptState:
        dt = jnp.dtype(self.tc.opt_state_dtype)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree.map(jnp.copy, zeros))

    def init_abstract(self, params) -> OptState:
        dt = jnp.dtype(self.tc.opt_state_dtype)
        z = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, dt), params)
        return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z)

    def update(self, grads, state: OptState, params, lr):
        tc = self.tc
        step = state.step + 1
        # global-norm clip in fp32
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9)) \
            if tc.grad_clip else 1.0

        b1, b2 = tc.beta1, tc.beta2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        sdt = jnp.dtype(tc.opt_state_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + tc.eps)
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m.astype(sdt), v.astype(sdt)

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step=step, mu=mu, nu=nu), gnorm
