"""Learning-rate schedules: cosine, constant, and MiniCPM's WSD.

WSD (warmup-stable-decay, arXiv:2404.06395): linear warmup -> long stable
plateau -> short (10-20%) sharp decay.  MiniCPM is one of the assigned
architectures, so WSD is a first-class schedule here.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def make_schedule(tc: TrainConfig):
    peak = tc.learning_rate
    warm = max(tc.warmup_steps, 1)

    def cosine(step):
        s = jnp.asarray(step, jnp.float32)
        warm_lr = peak * s / warm
        frac = jnp.clip((s - warm) / max(tc.decay_steps - warm, 1), 0.0, 1.0)
        cos_lr = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warm, warm_lr, cos_lr)

    def wsd(step):
        s = jnp.asarray(step, jnp.float32)
        warm_lr = peak * s / warm
        decay_start = tc.stable_steps
        decay_len = max(tc.decay_steps - tc.stable_steps, 1)
        frac = jnp.clip((s - decay_start) / decay_len, 0.0, 1.0)
        # exponential-style sharp decay to 10% of peak
        decay_lr = peak * jnp.power(0.1, frac)
        return jnp.where(s < warm, warm_lr,
                         jnp.where(s < decay_start, peak, decay_lr))

    def const(step):
        s = jnp.asarray(step, jnp.float32)
        return jnp.where(s < warm, peak * s / warm, peak)

    return {"cosine": cosine, "wsd": wsd, "const": const}[tc.schedule]
