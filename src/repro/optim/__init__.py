from repro.optim.adamw import AdamW, OptState  # noqa: F401
from repro.optim.schedule import make_schedule  # noqa: F401
