"""Dense decoder-only transformer (llama/qwen family) + VLM variant.

Layers are weight-stacked and scanned (``jax.lax.scan``) so HLO size is O(1)
in depth — required to compile the 126-layer/405B config on this container
and the production-idiomatic choice on TPU.  The same class provides
``loss`` (train), ``prefill`` (cache build) and ``decode_step`` (serve).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.distributed.api import shard
from repro.models import layers as nn
from repro.models.modules import P, abstract_params, init_params


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots,
            prevent_cse=False)
    raise ValueError(f"unknown remat mode {mode!r}")


class DenseLM:
    """Decoder-only LM.  Subclasses override the FFN (MoE) or inputs (VLM)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params

    def _ffn_param_tree(self) -> Dict[str, Any]:
        c = self.cfg
        return nn.swiglu_params(c.d_model, c.d_ff, layers=c.num_layers)

    def param_tree(self) -> Dict[str, Any]:
        c = self.cfg
        L = c.num_layers
        tree: Dict[str, Any] = {
            "embed": P((c.vocab_size, c.d_model), ("vocab", "embed"),
                       init="embed"),
            "blocks": {
                "attn_norm": P((L, c.d_model), ("layers", "embed"),
                               init="ones"),
                "attn": nn.attention_params(c.attention, c.d_model, layers=L),
                "mlp_norm": P((L, c.d_model), ("layers", "embed"),
                              init="ones"),
                "mlp": self._ffn_param_tree(),
            },
            "final_norm": P((c.d_model,), ("embed",), init="ones"),
        }
        if not c.tie_embeddings:
            tree["unembed"] = P((c.d_model, c.vocab_size), ("embed", "vocab"))
        self._extend_param_tree(tree)
        return tree

    def _extend_param_tree(self, tree):                   # VLM hook
        pass

    def init(self, rng, dtype="float32"):
        return init_params(self.param_tree(), rng, dtype)

    def abstract(self, dtype="bfloat16"):
        return abstract_params(self.param_tree(), dtype)

    # ------------------------------------------------------------ forward

    def _ffn_apply(self, lp, x):
        return nn.swiglu(lp, x), 0.0

    def _block(self, lp, x, positions):
        c = self.cfg
        h = nn.rmsnorm(x, lp["attn_norm"], c.norm_eps)
        x = x + nn.attention_full(lp["attn"], c.attention, h, positions,
                                  eps=c.norm_eps)
        h = nn.rmsnorm(x, lp["mlp_norm"], c.norm_eps)
        f, aux = self._ffn_apply(lp["mlp"], h)
        x = x + f
        return shard(x, "batch", "act_seq", "act_embed"), aux

    def _embed_inputs(self, params, batch):
        """Returns (x (B,T,D), positions, loss_mask or None)."""
        tokens = batch["tokens"]
        x = nn.embed_tokens(params["embed"], tokens)
        positions = jnp.broadcast_to(
            jnp.arange(tokens.shape[1]), tokens.shape)
        return x, positions, batch.get("mask")

    def hidden_states(self, params, batch, *, remat="none"):
        """Full forward through the block stack. Returns (h, aux, kv)."""
        x, positions, _ = self._embed_inputs(params, batch)

        def body(carry, lp):
            y, aux = self._block(lp, carry, positions)
            return y, aux

        x, auxs = jax.lax.scan(_remat(body, remat), x, params["blocks"])
        x = nn.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        return x, jnp.sum(auxs) if auxs is not None else 0.0

    def _unembed(self, params, x):
        c = self.cfg
        w = params["embed"] if c.tie_embeddings else params["unembed"]
        return nn.logits_from(x, w, tied=c.tie_embeddings)

    # -------------------------------------------------------------- train

    def loss(self, params, batch, *, remat="full"):
        x, aux = self.hidden_states(params, batch, remat=remat)
        logits = self._unembed(params, x)
        mask = batch.get("mask")
        loss = nn.softmax_xent(logits, batch["labels"], mask)
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.router_aux_coef * aux / self.cfg.num_layers
        return loss

    # ------------------------------------------------------------ serving

    def prefill(self, params, batch, max_seq: int):
        """Build the KV cache from a (padded) prompt batch.

        batch["lengths"]: (B,) valid prompt lengths.  Returns (last-token
        logits (B, V), cache).
        """
        c = self.cfg
        x, positions, _ = self._embed_inputs(params, batch)
        B, T = x.shape[0], x.shape[1]

        def body(carry, lp):
            h = nn.rmsnorm(carry, lp["attn_norm"], c.norm_eps)
            a, (k, v) = nn.attention_full(lp["attn"], c.attention, h,
                                          positions, eps=c.norm_eps,
                                          return_kv=True)
            y = carry + a
            h = nn.rmsnorm(y, lp["mlp_norm"], c.norm_eps)
            f, _ = self._ffn_apply(lp["mlp"], h)
            y = y + f
            return shard(y, "batch", "act_seq", "act_embed"), (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)

        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        # (L, B, T, Hkv, Dh) -> (L, B, Hkv, S, Dh), padded to max_seq
        a = c.attention
        pad = max_seq - T
        ks = jnp.moveaxis(ks, 3, 2)
        vs = jnp.moveaxis(vs, 3, 2)
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache = {"k": shard(ks, "layers", "batch", "kv_heads_act", "kv_seq", None),
                 "v": shard(vs, "layers", "batch", "kv_heads_act", "kv_seq", None),
                 "lengths": lengths}
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return self._unembed(params, x_last[:, None])[:, 0], cache

    def _decode_positions(self, cache, batch):
        return cache["lengths"][:, None]                   # (B, 1)

    def decode_step(self, params, cache, batch):
        """One token for every sequence.  batch["tokens"]: (B, 1)."""
        c = self.cfg
        x = nn.embed_tokens(params["embed"], batch["tokens"])   # (B,1,D)
        positions = self._decode_positions(cache, batch)
        lengths = cache["lengths"]

        def body(carry, xs):
            lp, kc, vc = xs
            h = nn.rmsnorm(carry, lp["attn_norm"], c.norm_eps)
            a, kc, vc = nn.attention_decode(
                lp["attn"], c.attention, h, positions, kc, vc, lengths,
                eps=c.norm_eps)
            y = carry + a
            h = nn.rmsnorm(y, lp["mlp_norm"], c.norm_eps)
            f, _ = self._ffn_apply(lp["mlp"], h)
            return y + f, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        logits = self._unembed(params, x)[:, 0]
        new_cache = {"k": k_new, "v": v_new, "lengths": lengths + 1}
        return logits, new_cache

    def decode_step_fori(self, params, cache, batch):
        """In-place decode (§Perf cell C iteration 3).

        The scan-based ``decode_step`` consumes each layer's cache slice as
        scan-xs and re-emits the whole updated slice as scan-ys — every
        step rewrites the full (B,Hkv,S,D) slab per layer even though only
        one token changed.  This variant keeps the stacked (L,B,Hkv,S,D)
        caches in the fori-loop carry and dynamic-update-slices ONLY the
        new token's (1,1,1,1,D) entries, cutting the cache write traffic
        from O(cache) to O(tokens) per step.  Numerically identical to
        ``decode_step`` (tests/test_models.py::test_decode_fori_matches).
        """
        c = self.cfg
        a = c.attention
        x = nn.embed_tokens(params["embed"], batch["tokens"])   # (B,1,D)
        lengths = cache["lengths"]
        positions = self._decode_positions(cache, batch)
        B = x.shape[0]
        L = c.num_layers

        def write_token(big, new, layer):
            # big: (L,B,Hkv,S,Dh); new: (B,Hkv,Dh) at per-row positions.
            # vmap over the batch axis of the FULL buffer lowers to one
            # scatter of B tiny (1,Hkv,1,Dh) updates — O(tokens), never a
            # slab rewrite.
            def per_row(col, nb, pos):
                # col: (L,Hkv,S,Dh) — one sequence's cache, all layers
                return jax.lax.dynamic_update_slice(
                    col, nb[None, :, None, :].astype(col.dtype),
                    (layer, 0, pos, 0))
            return jax.vmap(per_row, in_axes=(1, 0, 0),
                            out_axes=1)(big, new, lengths)

        def body(l, carry):
            x, kc, vc = carry
            lp = jax.tree.map(
                lambda p: jax.lax.dynamic_index_in_dim(p, l, 0,
                                                       keepdims=False),
                params["blocks"])
            h = nn.rmsnorm(x, lp["attn_norm"], c.norm_eps)
            q, k, v = nn._project_qkv(lp["attn"], a, h, positions,
                                      c.norm_eps)
            kc = write_token(kc, k[:, 0], l)
            vc = write_token(vc, v[:, 0], l)
            k_l = jax.lax.dynamic_index_in_dim(kc, l, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(vc, l, 0, keepdims=False)
            from repro.kernels.decode_attention import decode_mha
            o = decode_mha(q[:, 0], k_l, v_l, lengths + 1)
            x = x + o.reshape(B, 1, a.q_dim) @ lp["attn"]["wo"]
            h = nn.rmsnorm(x, lp["mlp_norm"], c.norm_eps)
            f, _ = self._ffn_apply(lp["mlp"], h)
            return (x + f, kc, vc)

        x, k_new, v_new = jax.lax.fori_loop(
            0, L, body, (x, cache["k"], cache["v"]))
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        logits = self._unembed(params, x)[:, 0]
        return logits, {"k": k_new, "v": v_new, "lengths": lengths + 1}

    # ------------------------------------------------------------- shapes

    def init_cache_abstract(self, batch: int, max_seq: int,
                            dtype="bfloat16"):
        c, a = self.cfg, self.cfg.attention
        kv = jax.ShapeDtypeStruct(
            (c.num_layers, batch, a.num_kv_heads, max_seq, a.head_dim), dtype)
        return {"k": kv, "v": kv,
                "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.init_cache_abstract(batch, max_seq, dtype))

    def input_specs(self, shape: ShapeConfig, *, dtype="bfloat16"):
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        B, T = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
        if shape.kind == "train":
            return {"tokens": tok, "labels": tok}
        if shape.kind == "prefill":
            return {"tokens": tok,
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}
        # decode: one new token against a T-long cache
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


class VLM(DenseLM):
    """Qwen2-VL-style: dense LM with a stubbed patch frontend and M-RoPE.

    ``input_specs`` provides precomputed patch embeddings per the assignment
    (the ViT tower is out of scope); seq_len counts patches + text tokens.
    """

    def _extend_param_tree(self, tree):
        c = self.cfg
        if c.num_patches:
            tree["patch_proj"] = P((c.d_model, c.d_model),
                                   ("embed_in", "embed"))

    def _embed_inputs(self, params, batch):
        c = self.cfg
        tokens = batch["tokens"]
        x = nn.embed_tokens(params["embed"], tokens)
        if c.num_patches and "patches" in batch:
            px = batch["patches"].astype(x.dtype) @ params["patch_proj"]
            x = jnp.concatenate([px, x], axis=1)
        positions = batch["positions"]                     # (B, T, 3)
        mask = batch.get("mask")
        return x, positions, mask

    def _decode_positions(self, cache, batch):
        return batch["positions"]                          # (B, 1, 3)

    def input_specs(self, shape: ShapeConfig, *, dtype="bfloat16"):
        c = self.cfg
        B, T = shape.global_batch, shape.seq_len
        n_text = T - c.num_patches
        patches = jax.ShapeDtypeStruct((B, c.num_patches, c.d_model), dtype)
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, n_text), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, T), jnp.int32),
                    "patches": patches,
                    "positions": jax.ShapeDtypeStruct((B, T, 3), jnp.int32),
                    "mask": jax.ShapeDtypeStruct((B, T), jnp.bool_)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, n_text), jnp.int32),
                    "patches": patches,
                    "positions": jax.ShapeDtypeStruct((B, T, 3), jnp.int32),
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((B, 1, 3), jnp.int32)}
