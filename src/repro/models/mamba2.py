"""Mamba2 blocks and the Zamba2 hybrid (Mamba2 stack + shared attention).

The SSD scan runs through repro.kernels.ssm_scan (Pallas on TPU, chunked-jnp
oracle elsewhere).  Zamba2's distinguishing feature — ONE weight-tied
attention+MLP block applied every ``hybrid_attn_every`` Mamba blocks — maps
naturally onto a scan over "super-blocks": the shared block's weights are
closure-captured (not scan xs), so they are stored once but applied at every
site, exactly like the paper's parameter sharing.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.distributed.api import shard
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.ssm_scan.ref import ssd_decode_step
from repro.models import layers as nn
from repro.models.modules import P, abstract_params, init_params
from repro.models.transformer import _remat


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim         # x, B, C go through the conv
    return d_in, H, s.state_dim, conv_ch


def mamba2_param_tree(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Any]:
    d_in, H, N, conv_ch = mamba2_dims(cfg)
    s = cfg.ssm
    la = ("layers",) * len(lead)
    proj_out = 2 * d_in + 2 * N + H          # z, x, B, C, dt
    return {
        "norm": P(lead + (cfg.d_model,), la + ("embed",), init="ones"),
        "in_proj": P(lead + (cfg.d_model, proj_out), la + ("embed", "inner")),
        "conv_w": P(lead + (s.conv_width, conv_ch), la + ("conv", "inner"),
                    scale=0.3),
        "conv_b": P(lead + (conv_ch,), la + ("inner",), init="zeros"),
        "A_log": P(lead + (H,), la + ("ssm_heads",), init="zeros"),
        "D": P(lead + (H,), la + ("ssm_heads",), init="ones"),
        "dt_bias": P(lead + (H,), la + ("ssm_heads",), init="zeros"),
        "out_norm": P(lead + (d_in,), la + ("inner",), init="ones"),
        "out_proj": P(lead + (d_in, cfg.d_model), la + ("inner", "embed")),
    }


def _mamba2_project(lp, cfg, x):
    d_in, H, N, conv_ch = mamba2_dims(cfg)
    zxbcdt = x @ lp["in_proj"]
    z, rest = jnp.split(zxbcdt, [d_in], axis=-1)
    conv_in, dt = jnp.split(rest, [conv_ch], axis=-1)
    return z, conv_in, dt


def _mamba2_ssd_inputs(lp, cfg, conv_out, dt):
    d_in, H, N, _ = mamba2_dims(cfg)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])     # (..,H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    g = dt * A
    xh = xin.reshape(xin.shape[:-1] + (H, cfg.ssm.head_dim))
    return xh, g, dt, Bc, Cc


def mamba2_block(lp, cfg: ModelConfig, x):
    """Train/prefill form.  x: (B, T, d_model)."""
    d_in, H, N, _ = mamba2_dims(cfg)
    h = nn.rmsnorm(x, lp["norm"], cfg.norm_eps)
    z, conv_in, dt = _mamba2_project(lp, cfg, h)
    conv_out = jax.nn.silu(
        nn.causal_depthwise_conv(conv_in, lp["conv_w"], lp["conv_b"]))
    xh, g, s, Bc, Cc = _mamba2_ssd_inputs(lp, cfg, conv_out, dt)
    y, _ = ssd_scan(xh, g, s, Bc.astype(xh.dtype), Cc.astype(xh.dtype),
                    lp["D"].astype(jnp.float32), chunk=cfg.ssm.chunk)
    y = y.reshape(y.shape[:2] + (d_in,))
    y = nn.rmsnorm(y * jax.nn.silu(z), lp["out_norm"], cfg.norm_eps)
    return x + y @ lp["out_proj"]


def mamba2_block_decode(lp, cfg: ModelConfig, x, conv_state, ssm_state):
    """One-token decode.  x: (B, 1, d); conv_state: (B, K-1, conv_ch);
    ssm_state: (B, H, P, N) fp32.  Returns (x, conv_state, ssm_state)."""
    d_in, H, N, conv_ch = mamba2_dims(cfg)
    h = nn.rmsnorm(x, lp["norm"], cfg.norm_eps)
    z, conv_in, dt = _mamba2_project(lp, cfg, h)
    window = jnp.concatenate(
        [conv_state, conv_in.astype(conv_state.dtype)], axis=1)  # (B, K, ch)
    conv_out = jax.nn.silu(nn.causal_depthwise_conv_step(
        window, lp["conv_w"], lp["conv_b"]))[:, None]            # (B, 1, ch)
    xh, g, s, Bc, Cc = _mamba2_ssd_inputs(lp, cfg, conv_out, dt)
    y, ssm_state = ssd_decode_step(
        ssm_state, xh[:, 0].astype(jnp.float32), g[:, 0], s[:, 0],
        Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32),
        lp["D"].astype(jnp.float32))
    y = y.astype(x.dtype).reshape(x.shape[0], 1, d_in)
    y = nn.rmsnorm(y * jax.nn.silu(z), lp["out_norm"], cfg.norm_eps)
    return x + y @ lp["out_proj"], window[:, 1:], ssm_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------


class Zamba2:
    """Mamba2 backbone with a single shared attention+MLP block applied after
    every ``hybrid_attn_every`` Mamba2 blocks."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        every = cfg.hybrid_attn_every
        self.n_super = cfg.num_layers // every
        self.tail = cfg.num_layers - self.n_super * every
        self.every = every

    # ------------------------------------------------------------- params

    def param_tree(self) -> Dict[str, Any]:
        c = self.cfg
        tree = {
            "embed": P((c.vocab_size, c.d_model), ("vocab", "embed"),
                       init="embed"),
            "mamba": mamba2_param_tree(c, (self.n_super, self.every)),
            "shared_attn": {
                "attn_norm": P((c.d_model,), ("embed",), init="ones"),
                "attn": nn.attention_params(c.attention, c.d_model),
                "mlp_norm": P((c.d_model,), ("embed",), init="ones"),
                "mlp": nn.swiglu_params(c.d_model, c.d_ff),
            },
            "final_norm": P((c.d_model,), ("embed",), init="ones"),
            "unembed": P((c.d_model, c.vocab_size), ("embed", "vocab")),
        }
        if self.tail:
            tree["mamba_tail"] = mamba2_param_tree(c, (self.tail,))
        return tree

    def init(self, rng, dtype="float32"):
        return init_params(self.param_tree(), rng, dtype)

    def abstract(self, dtype="bfloat16"):
        return abstract_params(self.param_tree(), dtype)

    # ------------------------------------------------------------ forward

    def _shared_block(self, sp, x, positions):
        c = self.cfg
        h = nn.rmsnorm(x, sp["attn_norm"], c.norm_eps)
        x = x + nn.attention_full(sp["attn"], c.attention, h, positions,
                                  eps=c.norm_eps)
        h = nn.rmsnorm(x, sp["mlp_norm"], c.norm_eps)
        return x + nn.swiglu(sp["mlp"], h)

    def hidden_states(self, params, batch, *, remat="none"):
        c = self.cfg
        tokens = batch["tokens"]
        x = nn.embed_tokens(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
        sp = params["shared_attn"]

        def super_body(carry, mp):
            def inner(ic, ilp):
                return mamba2_block(ilp, c, ic), None
            y, _ = jax.lax.scan(_remat(inner, remat), carry, mp)
            y = self._shared_block(sp, y, positions)
            return shard(y, "batch", "act_seq", "act_embed"), None

        x, _ = jax.lax.scan(super_body, x, params["mamba"])
        if self.tail:
            def inner(ic, ilp):
                return mamba2_block(ilp, c, ic), None
            x, _ = jax.lax.scan(_remat(inner, remat), x,
                                params["mamba_tail"])
        return nn.rmsnorm(x, params["final_norm"], c.norm_eps), 0.0

    def loss(self, params, batch, *, remat="full"):
        x, _ = self.hidden_states(params, batch, remat=remat)
        logits = nn.logits_from(x, params["unembed"], tied=False)
        return nn.softmax_xent(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving

    def _abstract_states(self, batch: int, dtype="bfloat16"):
        c = self.cfg
        d_in, H, N, conv_ch = mamba2_dims(c)
        K = c.ssm.conv_width
        a = c.attention

        def stk(lead, shape, dt):
            return jax.ShapeDtypeStruct(lead + shape, dt)

        states = {
            "conv": stk((self.n_super, self.every),
                        (batch, K - 1, conv_ch), dtype),
            "ssm": stk((self.n_super, self.every),
                       (batch, H, c.ssm.head_dim, N), jnp.float32),
        }
        if self.tail:
            states["conv_tail"] = stk((self.tail,), (batch, K - 1, conv_ch),
                                      dtype)
            states["ssm_tail"] = stk((self.tail,),
                                     (batch, H, c.ssm.head_dim, N),
                                     jnp.float32)
        return states

    def init_cache_abstract(self, batch: int, max_seq: int, dtype="bfloat16"):
        c, a = self.cfg, self.cfg.attention
        cache = self._abstract_states(batch, dtype)
        cache["k"] = jax.ShapeDtypeStruct(
            (self.n_super, batch, a.num_kv_heads, max_seq, a.head_dim), dtype)
        cache["v"] = cache["k"]
        cache["lengths"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return cache

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.init_cache_abstract(batch, max_seq, dtype))

    def prefill(self, params, batch, max_seq: int):
        """Prefill via the train-form forward; SSD final states and shared-
        attention K/V become the cache."""
        c = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = nn.embed_tokens(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(T), tokens.shape)
        sp = params["shared_attn"]
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)

        def mamba_prefill(ic, ilp):
            # mamba2_block with state extraction
            d_in, H, N, _ = mamba2_dims(c)
            h = nn.rmsnorm(ic, ilp["norm"], c.norm_eps)
            z, conv_in, dt = _mamba2_project(ilp, c, h)
            conv_out = jax.nn.silu(nn.causal_depthwise_conv(
                conv_in, ilp["conv_w"], ilp["conv_b"]))
            xh, g, s, Bc, Cc = _mamba2_ssd_inputs(ilp, c, conv_out, dt)
            y, hf = ssd_scan(xh, g, s, Bc.astype(xh.dtype),
                             Cc.astype(xh.dtype),
                             ilp["D"].astype(jnp.float32), chunk=c.ssm.chunk)
            y = y.reshape(y.shape[:2] + (d_in,))
            y = nn.rmsnorm(y * jax.nn.silu(z), ilp["out_norm"], c.norm_eps)
            K = c.ssm.conv_width
            conv_state = conv_in[:, -(K - 1):].astype(ic.dtype) if T >= K - 1 \
                else jnp.pad(conv_in, ((0, 0), (K - 1 - T, 0), (0, 0))).astype(ic.dtype)
            return ic + y @ ilp["out_proj"], (conv_state, hf)

        def super_body(carry, mp):
            y, (convs, ssms) = jax.lax.scan(mamba_prefill, carry, mp)
            h = nn.rmsnorm(y, sp["attn_norm"], c.norm_eps)
            a_out, (k, v) = nn.attention_full(
                sp["attn"], c.attention, h, positions, eps=c.norm_eps,
                return_kv=True)
            y = y + a_out
            h = nn.rmsnorm(y, sp["mlp_norm"], c.norm_eps)
            y = y + nn.swiglu(sp["mlp"], h)
            return y, (convs, ssms, k, v)

        x, (convs, ssms, ks, vs) = jax.lax.scan(super_body, x,
                                                params["mamba"])
        cache = {"conv": convs, "ssm": ssms, "lengths": lengths}
        pad = max_seq - T
        ks = jnp.moveaxis(ks, 3, 2)
        vs = jnp.moveaxis(vs, 3, 2)
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache["k"], cache["v"] = ks, vs
        if self.tail:
            x, (convs_t, ssms_t) = jax.lax.scan(mamba_prefill, x,
                                                params["mamba_tail"])
            cache["conv_tail"], cache["ssm_tail"] = convs_t, ssms_t
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return x_last @ params["unembed"], cache

    def decode_step(self, params, cache, batch):
        c = self.cfg
        x = nn.embed_tokens(params["embed"], batch["tokens"])   # (B, 1, d)
        lengths = cache["lengths"]
        sp = params["shared_attn"]

        def mamba_dec(carry, xs):
            ilp, conv_s, ssm_s = xs
            y, conv_s, ssm_s = mamba2_block_decode(ilp, c, carry, conv_s,
                                                   ssm_s)
            return y, (conv_s, ssm_s)

        def super_dec(carry, xs):
            mp, conv_s, ssm_s, kc, vc = xs
            y, (conv_s, ssm_s) = jax.lax.scan(mamba_dec, carry,
                                              (mp, conv_s, ssm_s))
            h = nn.rmsnorm(y, sp["attn_norm"], c.norm_eps)
            a_out, kc, vc = nn.attention_decode(
                sp["attn"], c.attention, h, lengths[:, None], kc, vc,
                lengths, eps=c.norm_eps)
            y = y + a_out
            h = nn.rmsnorm(y, sp["mlp_norm"], c.norm_eps)
            y = y + nn.swiglu(sp["mlp"], h)
            return y, (conv_s, ssm_s, kc, vc)

        x, (convs, ssms, k_new, v_new) = jax.lax.scan(
            super_dec, x,
            (params["mamba"], cache["conv"], cache["ssm"], cache["k"],
             cache["v"]))
        new_cache = dict(cache, conv=convs, ssm=ssms, k=k_new, v=v_new,
                         lengths=lengths + 1)
        if self.tail:
            x, (ct, st) = jax.lax.scan(
                mamba_dec, x,
                (params["mamba_tail"], cache["conv_tail"],
                 cache["ssm_tail"]))
            new_cache["conv_tail"], new_cache["ssm_tail"] = ct, st
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        return (x @ params["unembed"])[:, 0], new_cache

    # ------------------------------------------------------------- shapes

    def input_specs(self, shape: ShapeConfig, *, dtype="bfloat16"):
        B, T = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
        if shape.kind == "train":
            return {"tokens": tok, "labels": tok}
        if shape.kind == "prefill":
            return {"tokens": tok,
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
