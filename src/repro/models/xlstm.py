"""xLSTM block stack: mLSTM (matrix memory) + sLSTM (scalar memory).

The mLSTM matrix-memory recurrence C_t = σ(f̃_t)·C_{t-1} + exp(ĩ_t)·v_t k_tᵀ
is an instance of the generalized SSD primitive (g = logσ(f̃), s = exp(ĩ),
x = v, B = k, C = q) — so training/prefill reuse the validated
repro.kernels.ssm_scan Pallas kernel with per-head B/C, with the mLSTM
normalizer n folded in as an extra channel of x (x_aug = [v, 1]).
q/k/v are block-diagonal per head as in the reference implementation.

sLSTM has a nonlinear recurrence (no parallel form): a lax.scan over time
with per-head block-diagonal recurrent weights and the standard m-stabilizer.
The block layout follows the 1.3B config: one sLSTM block every
``slstm_every`` blocks, the rest mLSTM; we scan over "super-blocks" of
``slstm_every`` layers so the stacked-weights trick still applies.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.distributed.api import shard
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.ssm_scan.ref import ssd_decode_step
from repro.models import layers as nn
from repro.models.modules import P, abstract_params, init_params
from repro.models.transformer import _remat


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    x = cfg.xlstm
    d_in = int(x.mlstm_proj_factor * cfg.d_model)
    H = x.num_heads
    return d_in, H, d_in // H


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_param_tree(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Any]:
    d_in, H, Ph = _dims(cfg)
    x = cfg.xlstm
    la = ("layers",) * len(lead)
    return {
        "norm": P(lead + (cfg.d_model,), la + ("embed",), init="ones"),
        "w_up": P(lead + (cfg.d_model, 2 * d_in), la + ("embed", "inner")),
        "conv_w": P(lead + (x.conv_width, d_in), la + ("conv", "inner"),
                    scale=0.3),
        "conv_b": P(lead + (d_in,), la + ("inner",), init="zeros"),
        "wq": P(lead + (H, Ph, Ph), la + ("ssm_heads", "head_in", "head_out")),
        "wk": P(lead + (H, Ph, Ph), la + ("ssm_heads", "head_in", "head_out")),
        "wv": P(lead + (H, Ph, Ph), la + ("ssm_heads", "head_in", "head_out")),
        "w_i": P(lead + (d_in, H), la + ("inner", "ssm_heads"), scale=0.01),
        "b_i": P(lead + (H,), la + ("ssm_heads",), init="zeros"),
        "w_f": P(lead + (d_in, H), la + ("inner", "ssm_heads"), scale=0.01),
        "b_f": P(lead + (H,), la + ("ssm_heads",), init="ones", scale=3.0),
        "out_norm": P(lead + (d_in,), la + ("inner",), init="ones"),
        "w_down": P(lead + (d_in, cfg.d_model), la + ("inner", "embed")),
    }


def _mlstm_qkv_gates(lp, cfg, xm, conv_out):
    """xm, conv_out: (..., d_in) -> q,k,v (..., H, Ph), g, s (..., H)."""
    d_in, H, Ph = _dims(cfg)
    xh = conv_out.reshape(conv_out.shape[:-1] + (H, Ph))
    vh = xm.reshape(xm.shape[:-1] + (H, Ph))
    q = jnp.einsum("...hp,hpq->...hq", xh, lp["wq"])
    k = jnp.einsum("...hp,hpq->...hq", xh, lp["wk"]) * (Ph ** -0.5)
    v = jnp.einsum("...hp,hpq->...hq", vh, lp["wv"])
    i_log = (xm @ lp["w_i"] + lp["b_i"]).astype(jnp.float32)
    f_log = (xm @ lp["w_f"] + lp["b_f"]).astype(jnp.float32)
    g = jax.nn.log_sigmoid(f_log)
    s = jnp.exp(jnp.minimum(i_log, 10.0))       # clamp for safety
    return q, k, v, g, s


def mlstm_block(lp, cfg: ModelConfig, x):
    """Train/prefill form via the SSD kernel.  x: (B, T, d_model)."""
    d_in, H, Ph = _dims(cfg)
    h = nn.rmsnorm(x, lp["norm"], cfg.norm_eps)
    up = h @ lp["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    conv_out = jax.nn.silu(
        nn.causal_depthwise_conv(xm, lp["conv_w"], lp["conv_b"]))
    q, k, v, g, s = _mlstm_qkv_gates(lp, cfg, xm, conv_out)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    x_aug = jnp.concatenate([v, ones], axis=-1)           # normalizer channel
    y_aug, _ = ssd_scan(x_aug, g, s, k, q,
                        jnp.zeros((H,), jnp.float32), chunk=64)
    num, den = y_aug[..., :Ph], y_aug[..., Ph:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(y.shape[:2] + (d_in,))
    y = nn.rmsnorm(y, lp["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ lp["w_down"]


def mlstm_block_decode(lp, cfg: ModelConfig, x, conv_state, mem_state):
    """One-token decode.  conv_state: (B, K-1, d_in); mem_state:
    (B, H, Ph+1, Ph) fp32 (the SSD state with the normalizer channel)."""
    d_in, H, Ph = _dims(cfg)
    h = nn.rmsnorm(x, lp["norm"], cfg.norm_eps)
    up = h @ lp["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([conv_state, xm.astype(conv_state.dtype)],
                             axis=1)
    conv_out = jax.nn.silu(nn.causal_depthwise_conv_step(
        window, lp["conv_w"], lp["conv_b"]))[:, None]
    q, k, v, g, s = _mlstm_qkv_gates(lp, cfg, xm, conv_out)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    x_aug = jnp.concatenate([v, ones], axis=-1)[:, 0]     # (B, H, Ph+1)
    y_aug, mem_state = ssd_decode_step(
        mem_state, x_aug.astype(jnp.float32), g[:, 0], s[:, 0],
        k[:, 0].astype(jnp.float32), q[:, 0].astype(jnp.float32),
        jnp.zeros((H,), jnp.float32))
    num, den = y_aug[..., :Ph], y_aug[..., Ph:]
    y = (num / jnp.maximum(jnp.abs(den), 1.0)).astype(x.dtype)
    y = y.reshape(x.shape[0], 1, d_in)
    y = nn.rmsnorm(y, lp["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ lp["w_down"], window[:, 1:], mem_state


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_param_tree(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Any]:
    d = cfg.d_model
    x = cfg.xlstm
    H = x.num_heads
    Ph = d // H
    d_ff = int(x.slstm_proj_factor * d)
    la = ("layers",) * len(lead)
    return {
        "norm": P(lead + (d,), la + ("embed",), init="ones"),
        "w_gates": P(lead + (d, 4 * d), la + ("embed", "inner"), scale=0.02),
        "r_gates": P(lead + (H, Ph, 4 * Ph),
                     la + ("ssm_heads", "head_in", "head_out"), scale=0.02),
        "b_gates": P(lead + (4 * d,), la + ("inner",), init="zeros"),
        "ffn_norm": P(lead + (d,), la + ("embed",), init="ones"),
        "ffn": {
            "w_in": P(lead + (d, d_ff), la + ("embed", "ff")),
            "w_out": P(lead + (d_ff, d), la + ("ff", "embed")),
        },
    }


def _slstm_step(carry, wx_t, r_gates, H, Ph):
    """carry: (h, c, n, m) each (B, d).  wx_t: (B, 4d) precomputed Wx+b."""
    h, c, n, m = carry
    B, d = h.shape
    rh = jnp.einsum("bhp,hpq->bhq", h.reshape(B, H, Ph), r_gates)
    rh = rh.reshape(B, H, 4, Ph).swapaxes(1, 2).reshape(B, 4 * d)
    gates = (wx_t + rh).astype(jnp.float32)
    i_t, f_t, z_t, o_t = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(f_t + m, i_t)
    i = jnp.exp(i_t - m_new)
    f = jnp.exp(f_t + m - m_new)
    z = jnp.tanh(z_t)
    o = jax.nn.sigmoid(o_t)
    c = f * c + i * z
    n = f * n + i
    h_new = o * c / jnp.maximum(n, 1.0)
    return (h_new.astype(h.dtype), c, n, m_new), h_new


def slstm_scan(lp, cfg: ModelConfig, x, state=None):
    """x: (B, T, d).  Returns (y, final_state).  Sequential over T."""
    H = cfg.xlstm.num_heads
    d = cfg.d_model
    Ph = d // H
    B, T, _ = x.shape
    wx = x @ lp["w_gates"] + lp["b_gates"]                # (B, T, 4d)
    if state is None:
        zero = jnp.zeros((B, d), jnp.float32)
        state = (jnp.zeros((B, d), x.dtype), zero, zero,
                 jnp.full((B, d), -1e9, jnp.float32))

    def step(carry, wx_t):
        return _slstm_step(carry, wx_t, lp["r_gates"], H, Ph)

    state, ys = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def slstm_block(lp, cfg: ModelConfig, x, state=None):
    h = nn.rmsnorm(x, lp["norm"], cfg.norm_eps)
    y, state = slstm_scan(lp, cfg, h, state)
    x = x + y
    h = nn.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
    return x + nn.gelu_mlp(lp["ffn"], h), state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


class XLSTM:
    """Super-blocks of (1 sLSTM + (slstm_every-1) mLSTM), scanned."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        every = cfg.xlstm.slstm_every
        assert cfg.num_layers % every == 0, (cfg.num_layers, every)
        self.n_super = cfg.num_layers // every
        self.n_mlstm = every - 1

    def param_tree(self) -> Dict[str, Any]:
        c = self.cfg
        return {
            "embed": P((c.vocab_size, c.d_model), ("vocab", "embed"),
                       init="embed"),
            "slstm": slstm_param_tree(c, (self.n_super,)),
            "mlstm": mlstm_param_tree(c, (self.n_super, self.n_mlstm)),
            "final_norm": P((c.d_model,), ("embed",), init="ones"),
            "unembed": P((c.d_model, c.vocab_size), ("embed", "vocab")),
        }

    def init(self, rng, dtype="float32"):
        return init_params(self.param_tree(), rng, dtype)

    def abstract(self, dtype="bfloat16"):
        return abstract_params(self.param_tree(), dtype)

    # ------------------------------------------------------------ forward

    def hidden_states(self, params, batch, *, remat="none"):
        c = self.cfg
        x = nn.embed_tokens(params["embed"], batch["tokens"])

        def super_body(carry, xs):
            slp, mlp_stack = xs
            y, _ = slstm_block(slp, c, carry)

            def inner(ic, ilp):
                return mlstm_block(ilp, c, ic), None

            y, _ = jax.lax.scan(_remat(inner, remat), y, mlp_stack)
            return shard(y, "batch", "act_seq", "act_embed"), None

        x, _ = jax.lax.scan(super_body, x,
                            (params["slstm"], params["mlstm"]))
        return nn.rmsnorm(x, params["final_norm"], c.norm_eps), 0.0

    def loss(self, params, batch, *, remat="full"):
        x, _ = self.hidden_states(params, batch, remat=remat)
        logits = nn.logits_from(x, params["unembed"], tied=False)
        return nn.softmax_xent(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving

    def init_cache_abstract(self, batch: int, max_seq: int, dtype="bfloat16"):
        c = self.cfg
        d_in, H, Ph = _dims(c)
        K = c.xlstm.conv_width
        d = c.d_model
        f32 = jnp.float32
        return {
            "s_h": jax.ShapeDtypeStruct((self.n_super, batch, d), dtype),
            "s_c": jax.ShapeDtypeStruct((self.n_super, batch, d), f32),
            "s_n": jax.ShapeDtypeStruct((self.n_super, batch, d), f32),
            "s_m": jax.ShapeDtypeStruct((self.n_super, batch, d), f32),
            "m_conv": jax.ShapeDtypeStruct(
                (self.n_super, self.n_mlstm, batch, K - 1, d_in), dtype),
            "m_mem": jax.ShapeDtypeStruct(
                (self.n_super, self.n_mlstm, batch, H, Ph + 1, Ph), f32),
            "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             self.init_cache_abstract(batch, max_seq, dtype))
        cache["s_m"] = jnp.full(cache["s_m"].shape, -1e9, jnp.float32)
        return cache

    def prefill(self, params, batch, max_seq: int):
        """Prefill by running the chunked forward and extracting states."""
        c = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        x = nn.embed_tokens(params["embed"], tokens)
        d_in, H, Ph = _dims(c)
        K = c.xlstm.conv_width

        def mlstm_prefill(ic, ilp):
            h = nn.rmsnorm(ic, ilp["norm"], c.norm_eps)
            up = h @ ilp["w_up"]
            xm, z = jnp.split(up, 2, axis=-1)
            conv_out = jax.nn.silu(nn.causal_depthwise_conv(
                xm, ilp["conv_w"], ilp["conv_b"]))
            q, k, v, g, s = _mlstm_qkv_gates(ilp, c, xm, conv_out)
            ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
            x_aug = jnp.concatenate([v, ones], axis=-1)
            y_aug, hf = ssd_scan(x_aug, g, s, k, q,
                                 jnp.zeros((H,), jnp.float32), chunk=64)
            num, den = y_aug[..., :Ph], y_aug[..., Ph:]
            y = num / jnp.maximum(jnp.abs(den), 1.0)
            y = y.reshape(y.shape[:2] + (d_in,))
            y = nn.rmsnorm(y, ilp["out_norm"], c.norm_eps) * jax.nn.silu(z)
            conv_state = xm[:, -(K - 1):].astype(ic.dtype) if T >= K - 1 else \
                jnp.pad(xm, ((0, 0), (K - 1 - T, 0), (0, 0))).astype(ic.dtype)
            return ic + y @ ilp["w_down"], (conv_state, hf)

        def super_body(carry, xs):
            slp, mlp_stack = xs
            y, (sh, sc, sn, sm) = slstm_block(slp, c, carry)
            y, (convs, mems) = jax.lax.scan(mlstm_prefill, y, mlp_stack)
            return y, (sh, sc, sn, sm, convs, mems)

        x, (sh, sc, sn, sm, convs, mems) = jax.lax.scan(
            super_body, x, (params["slstm"], params["mlstm"]))
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        cache = {"s_h": sh, "s_c": sc, "s_n": sn, "s_m": sm,
                 "m_conv": convs, "m_mem": mems, "lengths": lengths}
        return x_last @ params["unembed"], cache

    def decode_step(self, params, cache, batch):
        c = self.cfg
        x = nn.embed_tokens(params["embed"], batch["tokens"])   # (B, 1, d)

        def mlstm_dec(carry, xs):
            ilp, conv_s, mem_s = xs
            y, conv_s, mem_s = mlstm_block_decode(ilp, c, carry, conv_s,
                                                  mem_s)
            return y, (conv_s, mem_s)

        def super_dec(carry, xs):
            slp, mlp_stack, sh, sc, sn, sm, conv_s, mem_s = xs
            h = nn.rmsnorm(carry, slp["norm"], c.norm_eps)
            y1, (sh, sc, sn, sm) = slstm_scan(slp, c, h, (sh, sc, sn, sm))
            y = carry + y1
            h = nn.rmsnorm(y, slp["ffn_norm"], c.norm_eps)
            y = y + nn.gelu_mlp(slp["ffn"], h)
            y, (conv_s, mem_s) = jax.lax.scan(mlstm_dec, y,
                                              (mlp_stack, conv_s, mem_s))
            return y, (sh, sc, sn, sm, conv_s, mem_s)

        x, (sh, sc, sn, sm, convs, mems) = jax.lax.scan(
            super_dec, x,
            (params["slstm"], params["mlstm"], cache["s_h"], cache["s_c"],
             cache["s_n"], cache["s_m"], cache["m_conv"], cache["m_mem"]))
        x = nn.rmsnorm(x, params["final_norm"], c.norm_eps)
        new_cache = {"s_h": sh, "s_c": sc, "s_n": sn, "s_m": sm,
                     "m_conv": convs, "m_mem": mems,
                     "lengths": cache["lengths"] + 1}
        return (x @ params["unembed"])[:, 0], new_cache

    def input_specs(self, shape: ShapeConfig, *, dtype="bfloat16"):
        B, T = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
        if shape.kind == "train":
            return {"tokens": tok, "labels": tok}
        if shape.kind == "prefill":
            return {"tokens": tok,
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
