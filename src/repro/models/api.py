"""Unified model API: build any assigned architecture from its config.

Every model exposes: param_tree / init / abstract, loss, prefill,
decode_step, init_cache(_abstract), input_specs, plus the logical-axis
metadata (cache_axes) the distribution layer needs to shard serve-time
state.  ``build_model`` dispatches on config.family.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models.mamba2 import Zamba2
from repro.models.moe import MoELM
from repro.models.transformer import DenseLM, VLM
from repro.models.whisper import WhisperEncDec
from repro.models.xlstm import XLSTM


def build_model(cfg: ModelConfig, *, moe_dispatch: str = "einsum",
                moe_group: int = 512):
    if cfg.family == "dense":
        return DenseLM(cfg)
    if cfg.family == "moe":
        return MoELM(cfg, dispatch=moe_dispatch, group_size=moe_group)
    if cfg.family == "vlm":
        return VLM(cfg)
    if cfg.family == "encdec":
        return WhisperEncDec(cfg)
    if cfg.family == "ssm":
        assert cfg.xlstm is not None
        return XLSTM(cfg)
    if cfg.family == "hybrid":
        return Zamba2(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Logical axes for inputs and caches (consumed by distributed.sharding)
# ---------------------------------------------------------------------------


def input_axes(specs: Dict[str, Any]) -> Dict[str, tuple]:
    """Batch-leading logical axes for every model input."""
    return {name: ("batch",) + (None,) * (s.ndim - 1)
            for name, s in specs.items()}


def cache_axes(model, cache_abstract) -> Dict[str, tuple]:
    """Logical axes for each cache leaf, keyed by cache dict key."""
    def axes_for(key: str, s) -> tuple:
        nd = s.ndim
        if key in ("k", "v"):
            return ("layers", "batch", "kv_heads_act", "kv_seq", None)
        if key in ("cross_k", "cross_v"):
            return ("layers", "batch", "kv_heads_act", None, None)
        if key == "lengths":
            return ("batch",)
        if key.startswith("conv") or key == "m_conv":
            return ("layers",) * (nd - 3) + ("batch", "conv", "inner")
        if key.startswith("ssm") or key == "m_mem":
            return ("layers",) * (nd - 4) + ("batch", "ssm_heads", None, None)
        if key.startswith("s_"):                      # sLSTM vector states
            return ("layers", "batch", "act_embed")
        return ("batch",) + (None,) * (nd - 1)
    return {k: axes_for(k, v) for k, v in cache_abstract.items()}


# ---------------------------------------------------------------------------
# Concrete batch synthesis (smoke tests, examples, data pipeline seed)
# ---------------------------------------------------------------------------


def make_batch(cfg: ModelConfig, kind: str, batch: int, seq: int,
               seed: int = 0, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """A concrete, well-formed batch for any family (small shapes only)."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size

    def toks(b, t):
        return jnp.asarray(rng.integers(0, V, (b, t)), jnp.int32)

    if cfg.family == "encdec":
        feats = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.encoder_feature_dim))
            .astype(np.float32), dtype)
        b = {"enc_feats": feats, "tokens": toks(batch, seq),
             "labels": toks(batch, seq)}
    elif cfg.family == "vlm" and cfg.num_patches:
        n_text = max(seq - cfg.num_patches, 1)
        total = n_text + cfg.num_patches
        if cfg.attention.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(total)[None, :, None],
                                   (batch, total, 3)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(jnp.arange(total)[None, :],
                                   (batch, total)).astype(jnp.int32)
        b = {"tokens": toks(batch, n_text), "labels": toks(batch, total),
             "patches": jnp.asarray(
                 rng.normal(size=(batch, cfg.num_patches, cfg.d_model))
                 .astype(np.float32), dtype),
             "positions": pos,
             "mask": jnp.concatenate(
                 [jnp.zeros((batch, cfg.num_patches), bool),
                  jnp.ones((batch, n_text), bool)], axis=1)}
    else:
        b = {"tokens": toks(batch, seq), "labels": toks(batch, seq)}

    if kind == "train":
        return b
    b.pop("labels", None)
    b.pop("mask", None)
    b["lengths"] = jnp.full((batch,), b["tokens"].shape[1], jnp.int32)
    return b
