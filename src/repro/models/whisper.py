"""Whisper-style encoder-decoder backbone (conv/mel frontend stubbed).

Per the assignment, ``input_specs`` provides precomputed frame embeddings
(batch, encoder_seq, feature_dim); the conv1d+mel frontend is out of scope.
Decoder positions use fixed sinusoids (the learned table would tie parameter
shapes to the input shape; noted in DESIGN.md).  No RoPE (rope_theta=0).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.distributed.api import shard
from repro.models import layers as nn
from repro.models.modules import P, abstract_params, init_params
from repro.models.transformer import _remat


class WhisperEncDec:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params

    def param_tree(self) -> Dict[str, Any]:
        c = self.cfg
        Le, Ld = c.num_encoder_layers, c.num_layers
        enc = {
            "attn_norm": P((Le, c.d_model), ("layers", "embed"), init="ones"),
            "attn": nn.attention_params(c.attention, c.d_model, layers=Le),
            "mlp_norm": P((Le, c.d_model), ("layers", "embed"), init="ones"),
            "mlp": nn.gelu_mlp_params(c.d_model, c.d_ff, layers=Le),
        }
        dec = {
            "self_norm": P((Ld, c.d_model), ("layers", "embed"), init="ones"),
            "self_attn": nn.attention_params(c.attention, c.d_model, layers=Ld),
            "cross_norm": P((Ld, c.d_model), ("layers", "embed"), init="ones"),
            "cross_attn": nn.attention_params(c.attention, c.d_model,
                                              layers=Ld),
            "mlp_norm": P((Ld, c.d_model), ("layers", "embed"), init="ones"),
            "mlp": nn.gelu_mlp_params(c.d_model, c.d_ff, layers=Ld),
        }
        return {
            "feat_proj": P((c.encoder_feature_dim, c.d_model),
                           ("embed_in", "embed")),
            "enc_pos": P((c.encoder_seq, c.d_model), (None, "embed"),
                         init="embed"),
            "enc_blocks": enc,
            "enc_norm": P((c.d_model,), ("embed",), init="ones"),
            "embed": P((c.vocab_size, c.d_model), ("vocab", "embed"),
                       init="embed"),
            "dec_blocks": dec,
            "dec_norm": P((c.d_model,), ("embed",), init="ones"),
            "unembed": P((c.d_model, c.vocab_size), ("embed", "vocab")),
        }

    def init(self, rng, dtype="float32"):
        return init_params(self.param_tree(), rng, dtype)

    def abstract(self, dtype="bfloat16"):
        return abstract_params(self.param_tree(), dtype)

    # ------------------------------------------------------------ encoder

    def encode(self, params, feats, *, remat="none"):
        c = self.cfg
        x = feats.astype(params["feat_proj"].dtype) @ params["feat_proj"]
        x = x + params["enc_pos"][None, :x.shape[1]]
        x = shard(x, "batch", "act_seq", "act_embed")
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, lp):
            h = nn.rmsnorm(carry, lp["attn_norm"], c.norm_eps)
            y = carry + nn.attention_full(lp["attn"], c.attention, h,
                                          positions, eps=c.norm_eps,
                                          causal=False)
            h = nn.rmsnorm(y, lp["mlp_norm"], c.norm_eps)
            y = y + nn.gelu_mlp(lp["mlp"], h)
            return shard(y, "batch", "act_seq", "act_embed"), None

        x, _ = jax.lax.scan(_remat(body, remat), x, params["enc_blocks"])
        return nn.rmsnorm(x, params["enc_norm"], c.norm_eps)

    # ------------------------------------------------------------ decoder

    def _embed_dec(self, params, tokens):
        x = nn.embed_tokens(params["embed"], tokens)
        pos = nn.sinusoid_positions(tokens.shape[1], self.cfg.d_model)
        return x + pos[None].astype(x.dtype)

    def decode_hidden(self, params, tokens, enc_out, *, remat="none",
                      return_kv=False):
        c = self.cfg
        x = self._embed_dec(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, lp):
            h = nn.rmsnorm(carry, lp["self_norm"], c.norm_eps)
            a, (k, v) = nn.attention_full(lp["self_attn"], c.attention, h,
                                          positions, eps=c.norm_eps,
                                          causal=True, return_kv=True)
            y = carry + a
            h = nn.rmsnorm(y, lp["cross_norm"], c.norm_eps)
            ca, (ck, cv) = nn.attention_full(lp["cross_attn"], c.attention, h,
                                             positions, eps=c.norm_eps,
                                             kv_from=enc_out, causal=False,
                                             return_kv=True)
            y = y + ca
            h = nn.rmsnorm(y, lp["mlp_norm"], c.norm_eps)
            y = y + nn.gelu_mlp(lp["mlp"], h)
            y = shard(y, "batch", "act_seq", "act_embed")
            if return_kv:
                return y, (k, v, ck, cv)
            return y, None

        x, kv = jax.lax.scan(_remat(body, remat), x, params["dec_blocks"])
        return nn.rmsnorm(x, params["dec_norm"], c.norm_eps), kv

    # -------------------------------------------------------------- train

    def hidden_states(self, params, batch, *, remat="none"):
        enc_out = self.encode(params, batch["enc_feats"], remat=remat)
        x, _ = self.decode_hidden(params, batch["tokens"], enc_out,
                                  remat=remat)
        return x, 0.0

    def loss(self, params, batch, *, remat="full"):
        x, _ = self.hidden_states(params, batch, remat=remat)
        logits = nn.logits_from(x, params["unembed"], tied=False)
        return nn.softmax_xent(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving

    def prefill(self, params, batch, max_seq: int):
        c = self.cfg
        enc_out = self.encode(params, batch["enc_feats"])
        x, kv = self.decode_hidden(params, batch["tokens"], enc_out,
                                   return_kv=True)
        ks, vs, cks, cvs = kv
        B, T = batch["tokens"].shape
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        pad = max_seq - T
        ks = jnp.moveaxis(ks, 3, 2)                # (L, B, Hkv, T, Dh)
        vs = jnp.moveaxis(vs, 3, 2)
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache = {
            "k": ks, "v": vs,
            "cross_k": jnp.moveaxis(cks, 3, 2),    # (L, B, Hkv, Tenc, Dh)
            "cross_v": jnp.moveaxis(cvs, 3, 2),
            "lengths": lengths,
        }
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = x_last @ params["unembed"]
        return logits, cache

    def decode_step(self, params, cache, batch):
        c = self.cfg
        tokens = batch["tokens"]                    # (B, 1)
        B = tokens.shape[0]
        lengths = cache["lengths"]
        x = nn.embed_tokens(params["embed"], tokens)
        # per-row sinusoid at the current position
        pos_table = nn.sinusoid_positions(cache["k"].shape[3], c.d_model)
        x = x + jnp.take(pos_table, lengths, axis=0)[:, None].astype(x.dtype)
        enc_len = cache["cross_k"].shape[3]

        def body(carry, xs):
            lp, kc, vc, ck, cv = xs
            h = nn.rmsnorm(carry, lp["self_norm"], c.norm_eps)
            a, kc, vc = nn.attention_decode(
                lp["self_attn"], c.attention, h, lengths[:, None], kc, vc,
                lengths, eps=c.norm_eps)
            y = carry + a
            h = nn.rmsnorm(y, lp["cross_norm"], c.norm_eps)
            y = y + nn.cross_attention_decode(
                lp["cross_attn"], c.attention, h, ck, cv, enc_len)
            h = nn.rmsnorm(y, lp["mlp_norm"], c.norm_eps)
            y = y + nn.gelu_mlp(lp["mlp"], h)
            return y, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        x = nn.rmsnorm(x, params["dec_norm"], c.norm_eps)
        logits = (x @ params["unembed"])[:, 0]
        new_cache = dict(cache, k=k_new, v=v_new, lengths=lengths + 1)
        return logits, new_cache

    # ------------------------------------------------------------- shapes

    def init_cache_abstract(self, batch: int, max_seq: int, dtype="bfloat16"):
        c, a = self.cfg, self.cfg.attention
        kv = jax.ShapeDtypeStruct(
            (c.num_layers, batch, a.num_kv_heads, max_seq, a.head_dim), dtype)
        ckv = jax.ShapeDtypeStruct(
            (c.num_layers, batch, a.num_kv_heads, c.encoder_seq, a.head_dim),
            dtype)
        return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv,
                "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.init_cache_abstract(batch, max_seq, dtype))

    def input_specs(self, shape: ShapeConfig, *, dtype="bfloat16"):
        c = self.cfg
        B, T = shape.global_batch, shape.seq_len
        feats = jax.ShapeDtypeStruct(
            (B, c.encoder_seq, c.encoder_feature_dim), dtype)
        tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
        if shape.kind == "train":
            return {"enc_feats": feats, "tokens": tok, "labels": tok}
        if shape.kind == "prefill":
            return {"enc_feats": feats, "tokens": tok,
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
