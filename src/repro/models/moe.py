"""Mixture-of-experts FFN (DeepSeek fine-grained + Grok coarse top-k).

Two dispatch strategies, selectable per call — this is a first-class perf
lever in EXPERIMENTS.md §Perf:

* ``einsum``  — GShard-style one-hot dispatch/combine einsums over
  (groups, group_size, experts, capacity).  The classic pjit-native path:
  with groups sharded over ("pod","data") and experts over "model", XLA
  inserts the canonical all-to-all pair around the expert computation.
* ``gmm``     — dispatch to a dense (E, capacity_total, D) buffer and run
  the Pallas grouped-matmul kernel (repro.kernels.moe_gmm) per FFN matrix.

Tokens are processed in groups of ``group_size`` so the dispatch one-hots
stay small (memory ∝ S·E·C per group, see DESIGN.md).  Router aux loss is
the standard load-balancing term E·Σ_e f_e·p̄_e.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.api import shard
from repro.kernels.moe_gmm import gmm
from repro.models import layers as nn
from repro.models.modules import P
from repro.models.transformer import DenseLM

GROUP_SIZE = 512


def moe_param_tree(cfg: ModelConfig, layers: int) -> Dict[str, Any]:
    m = cfg.moe
    L, D, Fe, E = layers, cfg.d_model, m.d_expert, m.num_experts
    tree = {
        "router": P((L, D, E), ("layers", "embed", "experts_dim"),
                    scale=D ** -0.5),
        "w_gate": P((L, E, D, Fe), ("layers", "experts", "embed", "expert_ff")),
        "w_up": P((L, E, D, Fe), ("layers", "experts", "embed", "expert_ff")),
        "w_down": P((L, E, Fe, D), ("layers", "experts", "expert_ff", "embed")),
    }
    if m.num_shared_experts:
        tree["shared"] = nn.swiglu_params(
            D, Fe * m.num_shared_experts, layers=L)
    return tree


def _capacity(group_size: int, m) -> int:
    return max(int(group_size * m.experts_per_token / m.num_experts
                   * m.capacity_factor), 1)


def moe_apply(lp, cfg: ModelConfig, x, *, method: str = "einsum",
              group_size: int = GROUP_SIZE) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, T, D = x.shape
    E, K = m.num_experts, m.experts_per_token
    S = min(group_size, B * T)
    G = (B * T) // S
    xg = x.reshape(G, S, D)

    logits = xg @ lp["router"].astype(jnp.float32)          # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ix = jax.lax.top_k(probs, K)                 # (G, S, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)  # renormalize

    # load-balancing aux: E * sum_e (token fraction to e) * (mean prob of e)
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(top_ix, E), axis=2), axis=(0, 1)) / K
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    C = _capacity(S, m)
    onehot = jax.nn.one_hot(top_ix, E, dtype=jnp.float32)   # (G, S, K, E)
    flat = onehot.reshape(G, S * K, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                 # pos among expert's tokens
    ranks = jnp.sum(ranks.reshape(G, S, K, E) * onehot,
                    axis=-1).astype(jnp.int32)              # (G, S, K)
    keep = ranks < C                                        # capacity drop
    w = top_w * keep                                        # (G, S, K)

    if method == "einsum":
        # dispatch (G,S,E,C): combine over K slots
        disp = jnp.einsum(
            "gske,gskc->gsec", onehot,
            jax.nn.one_hot(ranks, C, dtype=jnp.float32) * keep[..., None])
        comb = jnp.einsum("gske,gskc,gsk->gsec", onehot,
                          jax.nn.one_hot(ranks, C, dtype=jnp.float32), w)
        xe = jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), xg)
        xe = shard(xe, None, "experts", None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, lp["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, lp["w_up"])
        h = shard(h, None, "experts", None, "expert_ff_act")
        ye = jnp.einsum("gecf,efd->gecd", h, lp["w_down"])
        out = jnp.einsum("gsec,gecd->gsd", comb.astype(x.dtype), ye)
    elif method == "gmm":
        # scatter tokens into a dense (E, G*C, D) buffer, run the Pallas
        # grouped matmul, gather back with combine weights.
        slot = jnp.where(keep, ranks, C - 1).astype(jnp.int32)   # (G,S,K)
        e_ix = top_ix.reshape(G, S * K)
        s_ix = slot.reshape(G, S * K)
        src = jnp.repeat(xg, K, axis=1)                     # (G, S*K, D)
        keep_f = keep.reshape(G, S * K, 1)
        buf = jnp.zeros((G, E, C, D), x.dtype)
        gi = jnp.arange(G)[:, None]
        buf = buf.at[gi, e_ix, s_ix].add(src * keep_f.astype(x.dtype))
        be = jnp.moveaxis(buf, 1, 0).reshape(E, G * C, D)
        h = jax.nn.silu(gmm(be, lp["w_gate"])) * gmm(be, lp["w_up"])
        ye = gmm(h, lp["w_down"])                           # (E, G*C, D)
        ye = jnp.moveaxis(ye.reshape(E, G, C, D), 0, 1)     # (G, E, C, D)
        yk = ye[gi, e_ix, s_ix]                             # (G, S*K, D)
        out = jnp.sum(
            yk.reshape(G, S, K, D) * w[..., None].astype(x.dtype), axis=2)
    else:
        raise ValueError(f"unknown moe dispatch {method!r}")

    if m.num_shared_experts:
        out = out + nn.swiglu(lp["shared"], xg)
    return out.reshape(B, T, D), aux.astype(jnp.float32)


class MoELM(DenseLM):
    """Dense attention + MoE FFN.  ``dispatch`` chooses the MoE path;
    ``group_size`` trades dispatch-tensor memory vs capacity-padding waste
    (a §Perf lever)."""

    def __init__(self, cfg: ModelConfig, dispatch: str = "einsum",
                 group_size: int = GROUP_SIZE):
        super().__init__(cfg)
        self.dispatch = dispatch
        self.group_size = group_size

    def _ffn_param_tree(self):
        return moe_param_tree(self.cfg, self.cfg.num_layers)

    def _ffn_apply(self, lp, x):
        return moe_apply(lp, self.cfg, x, method=self.dispatch,
                         group_size=self.group_size)
