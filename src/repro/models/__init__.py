"""Model zoo: dense / MoE / enc-dec / VLM / xLSTM / Mamba2-hybrid."""
