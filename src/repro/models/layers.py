"""Shared model layers: norms, RoPE/M-RoPE, attention, MLPs, embeddings.

All layers are pure functions over (param-dict, activations).  Parameter
*declarations* (P leaves) live next to the apply functions so structure,
init and sharding stay in one place.  Activation sharding uses the logical
``shard`` hook (no-op outside a mesh/rules context).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig
from repro.distributed.api import shard
from repro.kernels.decode_attention import decode_mha
from repro.kernels.flash_attention import mha
from repro.models.modules import P

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions, head_dim: int, theta: float,
                 sections: Tuple[int, ...] = ()):
    """positions: (..., ) or (..., 3) for M-RoPE -> angles (..., head_dim/2)."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if sections:
        # M-RoPE: rotary channels split into (t, h, w) sections, each driven
        # by its own position component.  positions: (..., 3)
        assert sum(sections) == half, (sections, half)
        comp_ix = jnp.repeat(
            jnp.arange(len(sections)), jnp.asarray(sections),
            total_repeat_length=half)                       # (half,)
        pc = jnp.take(positions.astype(jnp.float32), comp_ix, axis=-1)
        return pc * inv                                     # (..., half)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x, positions, theta: float, sections: Tuple[int, ...] = ()):
    """x: (B, T, H, D); positions: (B, T) or (B, T, 3) for M-RoPE."""
    *_, H, D = x.shape
    ang = _rope_angles(positions, D, theta, sections)       # (B, T, D/2)
    cos = jnp.cos(ang)[..., None, :]                        # (B, T, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(length: int, dim: int):
    """Whisper-style fixed sinusoidal embedding table (length, dim)."""
    half = dim // 2
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    pos = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (declaration + apply; full/prefill/decode modes)
# ---------------------------------------------------------------------------


def attention_params(a: AttentionConfig, d_model: int, *, layers: int = 0,
                     cross: bool = False) -> Dict[str, P]:
    """Param declarations; ``layers`` > 0 prepends a stacked scan axis."""
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    p = {
        "wq": P(lead + (d_model, a.q_dim), lax_ + ("embed", "heads")),
        "wk": P(lead + (d_model, a.kv_dim), lax_ + ("embed", "kv_heads")),
        "wv": P(lead + (d_model, a.kv_dim), lax_ + ("embed", "kv_heads")),
        "wo": P(lead + (a.q_dim, d_model), lax_ + ("heads", "embed")),
    }
    if a.qk_norm:
        p["q_norm"] = P(lead + (a.head_dim,), lax_ + ("head_dim",), init="ones")
        p["k_norm"] = P(lead + (a.head_dim,), lax_ + ("head_dim",), init="ones")
    return p


def _project_qkv(p, a: AttentionConfig, x, positions, eps,
                 kv_from=None, rope: bool = True):
    """Returns q (B,Tq,H,D), k, v (B,Tk,Hkv,D).  ``kv_from`` for cross-attn."""
    B, Tq, _ = x.shape
    src = x if kv_from is None else kv_from
    Tk = src.shape[1]
    q = (x @ p["wq"]).reshape(B, Tq, a.num_heads, a.head_dim)
    k = (src @ p["wk"]).reshape(B, Tk, a.num_kv_heads, a.head_dim)
    v = (src @ p["wv"]).reshape(B, Tk, a.num_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"], eps)
        k = rmsnorm(k, p["k_norm"], eps)
    if rope and a.rope_theta:
        q = apply_rope(q, positions, a.rope_theta, a.mrope_sections)
        if kv_from is None:
            k = apply_rope(k, positions, a.rope_theta, a.mrope_sections)
    return q, k, v


def attention_full(p, a: AttentionConfig, x, positions, *, eps=1e-6,
                   kv_from=None, causal=None, q_offset: int = 0,
                   return_kv: bool = False):
    """Full (train / prefill) attention.  x: (B, T, D_model)."""
    causal = a.causal if causal is None else causal
    q, k, v = _project_qkv(p, a, x, positions, eps, kv_from=kv_from)
    q = shard(q, "batch", "act_seq", "heads_act", None)
    k = shard(k, "batch", "act_seq", "kv_heads_act", None)
    v = shard(v, "batch", "act_seq", "kv_heads_act", None)
    o = mha(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=causal, q_offset=q_offset, window=a.window)
    o = o.swapaxes(1, 2).reshape(x.shape[0], x.shape[1], a.q_dim)
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(p, a: AttentionConfig, x, positions, k_cache, v_cache,
                     lengths, *, eps=1e-6):
    """One-token decode.  x: (B, 1, D); caches: (B, Hkv, S, D); lengths (B,).

    Writes the new k/v at each sequence's ``lengths`` slot, then attends over
    ``lengths + 1`` entries.  Returns (out (B,1,D), k_cache, v_cache).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, a, x, positions, eps)
    k1, v1 = k[:, 0], v[:, 0]                             # (B, Hkv, Dh)

    def write(cache, new, length):
        # cache: (Hkv, S, Dh); new: (Hkv, Dh)
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new[:, None, :], length, axis=1)

    k_cache = jax.vmap(write)(k_cache, k1, lengths)
    v_cache = jax.vmap(write)(v_cache, v1, lengths)
    o = decode_mha(q[:, 0], k_cache, v_cache, lengths + 1)
    out = o.reshape(B, 1, a.q_dim) @ p["wo"]
    return out, k_cache, v_cache


def cross_attention_decode(p, a: AttentionConfig, x, k_cache, v_cache,
                           enc_len: int):
    """Decode-time cross-attention against precomputed encoder K/V."""
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, a.num_heads, a.head_dim)
    lengths = jnp.full((B,), enc_len, jnp.int32)
    o = decode_mha(q, k_cache, v_cache, lengths)
    return o.reshape(B, 1, a.q_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_params(d_model: int, d_ff: int, *, layers: int = 0) -> Dict[str, P]:
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    return {
        "w_gate": P(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_up": P(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_down": P(lead + (d_ff, d_model), lax_ + ("ff", "embed")),
    }


def swiglu(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", "act_seq", "ff_act")
    return h @ p["w_down"]


def gelu_mlp_params(d_model: int, d_ff: int, *, layers: int = 0) -> Dict[str, P]:
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    return {
        "w_in": P(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_out": P(lead + (d_ff, d_model), lax_ + ("ff", "embed")),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu((x @ p["w_in"]).astype(jnp.float32), approximate=True)
    h = shard(h.astype(x.dtype), "batch", "act_seq", "ff_act")
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Causal depthwise convolution (Mamba2 / xLSTM frontends)
# ---------------------------------------------------------------------------


def causal_depthwise_conv(x, w, b):
    """x: (B, T, C); w: (K, C) depthwise taps; b: (C,).  Causal (left) pad."""
    K = w.shape[0]
    T = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):                      # K is tiny (4): unrolled slices
        out = out + xp[:, k:k + T, :] * w[k]
    return out + b


def causal_depthwise_conv_step(window, w, b):
    """One decode step. window: (B, K, C) (oldest..newest); returns (B, C)."""
    return jnp.sum(window * w[None], axis=1) + b


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_tokens(embed, tokens):
    return shard(jnp.take(embed, tokens, axis=0), "batch", "act_seq", "act_embed")


def logits_from(x, embed_or_unembed, *, tied: bool):
    w = embed_or_unembed.T if tied else embed_or_unembed
    return shard(x @ w.astype(x.dtype), "batch", "act_seq", "vocab_act")


def softmax_xent(logits, labels, mask=None, *, z_coef: float = 0.0):
    """Token-mean cross-entropy in fp32 with optional z-loss."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_coef:
        nll = nll + z_coef * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
