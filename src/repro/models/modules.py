"""Minimal functional module system on pytrees (no flax in this container).

A model declares its parameters ONCE as a nested dict of :class:`P` leaves
(shape + logical axes + initializer).  From that single declaration we derive:

* ``init_params``      — materialized, seeded parameter values
* ``abstract_params``  — ShapeDtypeStructs (dry-run: zero allocation)
* ``logical_specs``    — PartitionSpec-like tuples of logical axis names
* ``repro.distributed.sharding.mesh_specs`` — mesh PartitionSpecs via rules

Keeping declaration, init and sharding in one place is what makes the
40-cell dry-run tractable: sharding rules can never drift from the tree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim (or None)
    init: str = "normal"                     # normal|zeros|ones|scaled|embed
    scale: Optional[float] = None            # stddev override
    dtype: Optional[str] = None              # leaf dtype override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # weights are stored (in_dim..., out_dim); treat all but last as fan-in
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    n = 1
    for s in shape[:-1]:
        n *= s
    return max(n, 1)


def _stddev(p: P) -> float:
    if p.scale is not None:
        return p.scale
    if p.init == "embed":
        return 0.02
    return 1.0 / math.sqrt(_fan_in(p.shape if p.axes[0] != "layers"
                                   else p.shape[1:]))


def is_param(x) -> bool:
    return isinstance(x, P)


def tree_map_params(fn: Callable[[str, P], Any], tree: PyTree,
                    prefix: str = "") -> PyTree:
    """Map fn(path, P) over a declaration tree, preserving structure."""
    if is_param(tree):
        return fn(prefix, tree)
    if isinstance(tree, dict):
        return {k: tree_map_params(fn, v, f"{prefix}/{k}" if prefix else k)
                for k, v in tree.items()}
    raise TypeError(f"bad node at {prefix!r}: {type(tree)}")


def init_params(tree: PyTree, rng: jax.Array, dtype: str = "float32") -> PyTree:
    """Materialize parameters. Each leaf gets an independent fold_in'd key."""
    leaves = []
    tree_map_params(lambda path, p: leaves.append(path) or None, tree)
    path_ix = {path: i for i, path in enumerate(sorted(leaves))}

    def make(path: str, p: P):
        d = p.dtype or dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, d)
        if p.init == "ones":
            return jnp.ones(p.shape, d)
        key = jax.random.fold_in(rng, path_ix[path])
        std = _stddev(p)
        return (jax.random.normal(key, p.shape, "float32") * std).astype(d)

    return tree_map_params(make, tree)


def abstract_params(tree: PyTree, dtype: str = "bfloat16") -> PyTree:
    """ShapeDtypeStruct stand-ins — the dry-run path, no allocation."""
    return tree_map_params(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype), tree)


def logical_specs(tree: PyTree) -> PyTree:
    """Tree of logical-axis tuples, mirroring the param tree."""
    return tree_map_params(lambda _, p: p.axes, tree)


def param_bytes(tree: PyTree, dtype: str = "bfloat16") -> int:
    total = [0]
    itemsize = jnp.dtype(dtype).itemsize

    def acc(_, p):
        n = 1
        for s in p.shape:
            n *= s
        total[0] += n * jnp.dtype(p.dtype).itemsize if p.dtype else n * itemsize
        return None

    tree_map_params(acc, tree)
    return total[0]


def param_count_tree(tree: PyTree) -> int:
    total = [0]

    def acc(_, p):
        n = 1
        for s in p.shape:
            n *= s
        total[0] += n
        return None

    tree_map_params(acc, tree)
    return total[0]
