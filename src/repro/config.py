"""Configuration schema for the Armada-on-TPU framework.

Every architecture in ``repro.configs`` instantiates :class:`ModelConfig`;
every dry-run / train / serve entry point consumes (ModelConfig, ShapeConfig,
MeshConfig).  Configs are frozen dataclasses: hashable, printable, and safe to
use as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head (grouped-query) attention hyper-parameters."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False                 # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()  # Qwen2-VL M-RoPE (t, h, w) splits
    causal: bool = True
    window: int = 0                       # sliding window; 0 = full attention

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN block (DeepSeek-MoE fine-grained or classic)."""

    num_experts: int
    experts_per_token: int
    d_expert: int                        # per-expert FFN hidden size
    num_shared_experts: int = 0          # DeepSeek shared experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01        # load-balancing aux loss weight

    @property
    def active_experts(self) -> int:
        return self.experts_per_token + self.num_shared_experts


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block parameters."""

    state_dim: int = 64      # N: per-head SSM state size
    head_dim: int = 64       # P: channels per SSM head
    expand: int = 2          # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256         # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block-stack layout (arXiv:2405.04517)."""

    slstm_every: int = 8       # 1 sLSTM block per this many blocks (rest mLSTM)
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_width: int = 4
    num_heads: int = 4


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "encdec", "vlm", "ssm", "hybrid")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # encoder-decoder (whisper): encoder depth + fixed encoder sequence length
    num_encoder_layers: int = 0
    encoder_seq: int = 0
    encoder_feature_dim: int = 0          # stubbed modality frontend width
    # hybrid (zamba2): one shared attention block applied every k ssm blocks
    hybrid_attn_every: int = 0
    # vlm: number of visual patch embeddings prepended (stub frontend)
    num_patches: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    notes: str = ""

    def __post_init__(self):
        assert self.family in FAMILIES, self.family

    # -- parameter accounting (used for roofline MODEL_FLOPS = 6 N D) -------

    def _attn_params(self) -> int:
        a = self.attention
        qo = self.d_model * a.q_dim * 2          # Wq, Wo
        kv = self.d_model * a.kv_dim * 2         # Wk, Wv
        return qo + kv

    def _dense_ffn_params(self) -> int:
        # SwiGLU: gate, up, down
        return 3 * self.d_model * self.d_ff

    def _moe_ffn_params(self, active_only: bool) -> int:
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        routed = (m.experts_per_token if active_only else m.num_experts)
        router = self.d_model * m.num_experts
        return per_expert * (routed + m.num_shared_experts) + router

    def _ssm_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        nheads = d_in // s.head_dim
        in_proj = self.d_model * (2 * d_in + 2 * nheads * s.state_dim + nheads)
        out_proj = d_in * self.d_model
        conv = (d_in + 2 * nheads * s.state_dim) * s.conv_width
        return in_proj + out_proj + conv + 2 * nheads  # + A, D

    def _xlstm_params(self) -> int:
        x = self.xlstm
        d_in = int(x.mlstm_proj_factor * self.d_model)
        # mLSTM block: up(2x), block-diagonal per-head q,k,v on d_in, down.
        mlstm = (self.d_model * 2 * d_in          # up proj (x, gate branches)
                 + 3 * d_in * (d_in // x.num_heads)  # q,k,v (block-diagonal)
                 + 2 * d_in                       # i,f gate projections
                 + d_in * self.d_model)           # down
        d_ff = int(x.slstm_proj_factor * self.d_model)
        slstm = (4 * self.d_model * self.d_model           # input gates i,f,z,o
                 + 4 * self.d_model * (self.d_model // x.num_heads)  # recurrent
                 + 2 * self.d_model * d_ff)                # post-up/down FFN
        n_slstm = self.num_layers // x.slstm_every
        n_mlstm = self.num_layers - n_slstm
        return n_mlstm * mlstm + n_slstm * slstm

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count, excluding embeddings
        for the per-token FLOP estimate's body term; embeddings counted once."""
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            body = self._xlstm_params() if self.xlstm else self._ssm_params() * self.num_layers
            return emb + body
        if self.family == "hybrid":
            ssm_body = self._ssm_params() * self.num_layers
            n_attn = self.num_layers // max(self.hybrid_attn_every, 1)
            # zamba2: ONE shared attention+mlp block reused at every site
            shared = self._attn_params() + self._dense_ffn_params()
            active_body = ssm_body + n_attn * shared if active_only else ssm_body + shared
            # active compute re-applies the shared block; stored params count once
            return emb + (ssm_body + shared if not active_only else active_body)
        per_layer = self._attn_params()
        if self.moe is not None:
            per_layer += self._moe_ffn_params(active_only)
        else:
            per_layer += self._dense_ffn_params()
        dec = self.num_layers * per_layer
        enc = 0
        if self.num_encoder_layers:
            enc_layer = self._attn_params() + self._dense_ffn_params()
            # decoder additionally has cross-attention
            dec += self.num_layers * self._attn_params()
            enc = self.num_encoder_layers * enc_layer
        return emb + dec + enc

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in this assignment


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skip) for an (arch, shape) cell — see DESIGN.md §4."""
    if shape.name == "long_500k" and not model.is_subquadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % model.name
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / hardware
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class HardwareConfig:
    """TPU v5e roofline constants (per chip)."""

    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    ici_links: int = 4                # 2D torus: 4 links/chip
    hbm_bytes: int = 16 * 2**30


V5E = HardwareConfig()


# ---------------------------------------------------------------------------
# Training / serving run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"            # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    stable_steps: int = 8_000           # wsd only
    microbatches: int = 1               # gradient-accumulation chunks
    remat: str = "full"                 # "none" | "dots" | "full"
    zero_shard_optimizer: bool = True   # shard Adam states over data axis
    opt_state_dtype: str = "float32"    # bf16 moments fit 405B on v5e-256
    accum_dtype: str = "float32"        # microbatch grad-accumulation dtype
    grad_compression: str = "none"      # "none" | "int8"
    checkpoint_every: int = 50
    async_checkpoint: bool = True
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 2048
    prefill_chunk: int = 512
    top_n: int = 3                      # Armada candidate-list length
    probe_period_s: float = 2.0         # client probing period
    ema_alpha: float = 0.3              # probe latency smoothing
    kv_page_size: int = 128


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (see spec §f)."""
    a = model.attention
    small_hd = min(a.head_dim, 32)
    half = small_hd // 2
    small_attn = dataclasses.replace(
        a,
        num_heads=max(2, min(a.num_heads, 4)),
        num_kv_heads=max(1, min(a.num_kv_heads, 2)),
        head_dim=small_hd,
        mrope_sections=(half - 2 * (half // 4), half // 4, half // 4)
        if a.mrope_sections else (),
    )
    if small_attn.num_heads % max(small_attn.num_kv_heads, 1):
        small_attn = dataclasses.replace(small_attn, num_kv_heads=small_attn.num_heads)
    kw = dict(
        num_layers=min(model.num_layers, 4),
        d_model=64,
        d_ff=128 if model.d_ff else 0,
        vocab_size=256,
        attention=small_attn,
        num_encoder_layers=2 if model.num_encoder_layers else 0,
        encoder_seq=16 if model.encoder_seq else 0,
        encoder_feature_dim=24 if model.encoder_feature_dim else 0,
        num_patches=8 if model.num_patches else 0,
        hybrid_attn_every=2 if model.hybrid_attn_every else 0,
    )
    if model.moe is not None:
        kw["moe"] = dataclasses.replace(
            model.moe, num_experts=8, experts_per_token=2,
            d_expert=32, num_shared_experts=min(model.moe.num_shared_experts, 1))
    if model.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            model.ssm, state_dim=16, head_dim=16, chunk=16)
    if model.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(model.xlstm, slstm_every=2, num_heads=2)
    kw.update(overrides)
    return dataclasses.replace(model, **kw)
