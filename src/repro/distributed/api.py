"""Logical-axis sharding constraints (the MaxText/t5x pattern).

Model code annotates activations with *logical* axes: ``shard(x, "batch",
"seq", "embed")``.  A rules dict (logical axis -> mesh axis / tuple / None)
is installed with :func:`axis_rules`; outside any rules context ``shard`` is
a no-op, so the same model code runs on a laptop and lowers for a 512-chip
mesh unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

MeshAxis = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def current_rules() -> Optional[Dict[str, MeshAxis]]:
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Dict[str, MeshAxis]], mesh=None):
    old = current_rules()
    old_mesh = current_mesh()
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = old
        _state.mesh = old_mesh


def spec(*axes: Optional[str]) -> PS:
    """PartitionSpec for logical ``axes`` under the active rules."""
    rules = current_rules() or {}
    return PS(*[rules.get(a) if a is not None else None for a in axes])


def shard(x, *axes: Optional[str]):
    """Constrain activation ``x`` (no-op outside an axis_rules context).

    Dims whose size the target mesh axes don't divide are left unsharded
    (vocab 51866 over a 16-way axis, 36 heads over 16, ...).
    """
    rules = current_rules()
    if rules is None:
        return x
    s = spec(*axes)
    mesh = current_mesh()
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        from repro.distributed.sharding import sanitize_spec
        s = sanitize_spec(tuple(x.shape), s, sizes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
    return jax.lax.with_sharding_constraint(x, s)
