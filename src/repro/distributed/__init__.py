"""Distribution layer: logical-axis sharding rules, mesh helpers, collectives."""
