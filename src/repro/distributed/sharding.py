"""Sharding-rule engine: logical axes -> mesh axes, per (arch, shape, mesh).

Baseline strategy (recorded as such in EXPERIMENTS.md §Perf):

* params 2D-sharded: ``embed`` over "data" (ZeRO-3/FSDP style) and
  heads/ff/vocab over "model" (tensor parallel) — GSPMD inserts the
  all-gathers/reduce-scatters.
* activations: batch over ("pod","data"); residual stream replicated over
  "model" (Megatron convention); per-op ff/head shards inside blocks.
* MoE experts over "model" when the expert count divides it (DeepSeek's 64),
  otherwise expert_ff over "model" (Grok's 8).
* KV caches: kv-head axis over "model" when divisible, else the cache
  *sequence* axis over "model" (split-KV decode — the flash-decoding idea
  expressed as a sharding rule; GSPMD adds the partial-softmax reduction).

Variants ("seqpar", "expert_data", ...) are perf levers explored in
EXPERIMENTS.md §Perf; each returns a modified rules dict.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.config import MeshConfig, ModelConfig, ShapeConfig
from repro.models.modules import logical_specs, tree_map_params


def _divides(n: int, k: int) -> bool:
    return n % k == 0


def make_rules(cfg: ModelConfig, mesh: MeshConfig, shape: ShapeConfig,
               *, variant: str = "baseline") -> Dict[str, Any]:
    axes = dict(zip(mesh.axes, mesh.shape))
    model_k = axes.get("model", 1)
    data_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data")
                                       if a in axes)
    data_k = 1
    for a in data_axes:
        data_k *= axes[a]

    batch_rule: Any = data_axes if len(data_axes) > 1 else \
        (data_axes[0] if data_axes else None)
    if not _divides(shape.global_batch, data_k):
        # long_500k (batch=1): the data axis serves concurrent streams in
        # production; here the batch is replicated.
        batch_rule = None

    a = cfg.attention
    rules: Dict[str, Any] = {
        # ---- params
        "vocab": "model",
        "embed": "data",
        "embed_in": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "layers": None,
        "conv": None,
        "inner": "model",
        "ssm_heads": "model" if _divides(_ssm_heads(cfg), model_k) else None,
        "head_in": None,
        "head_out": None,
        # ---- activations
        "batch": batch_rule,
        "act_seq": None,
        "act_embed": None,
        "heads_act": "model" if _divides(a.num_heads, model_k) else None,
        "kv_heads_act": "model" if _divides(a.num_kv_heads, model_k) else None,
        "ff_act": "model",
        "vocab_act": "model",
        "kv_seq": None,
    }

    # KV cache: prefer head sharding; fall back to split-KV (sequence) decode
    if rules["kv_heads_act"] is None and shape.kind == "decode":
        rules["kv_seq"] = "model"

    if cfg.moe is not None:
        if _divides(cfg.moe.num_experts, model_k):
            rules.update(experts="model", expert_ff=None, expert_ff_act=None,
                         experts_dim=None)
        else:
            rules.update(experts=None, expert_ff="model",
                         expert_ff_act="model", experts_dim=None)
    else:
        rules.update(experts=None, expert_ff=None, expert_ff_act=None,
                     experts_dim=None)

    # xLSTM: tiny head count, block-diag per-head mats -> shard d_in only
    if cfg.xlstm is not None:
        rules["inner"] = "model" if _divides(
            2 * int(cfg.xlstm.mlstm_proj_factor * cfg.d_model), model_k) \
            else None

    if variant == "seqpar":
        # sequence-parallel residual stream (memory hillclimb lever)
        rules["act_seq"] = "model"
        rules["act_embed"] = None
    elif variant == "expert_data":
        # MoE experts over the data axis (capacity vs bandwidth trade)
        if cfg.moe is not None and _divides(cfg.moe.num_experts, data_k):
            rules.update(experts=data_axes if len(data_axes) > 1
                         else data_axes[0])
    elif variant == "zero_off":
        rules["embed"] = None
    elif variant == "nokvseq":
        # ablation: disable split-KV decode (cache seq replicated on model)
        rules["kv_seq"] = None
    elif variant == "serve_fast":
        # serving profile (EXPERIMENTS.md §Perf cell C): params are
        # read-only at serve time, so drop ZeRO-3 — replicate over "data"
        # — whenever the TP-sharded weights fit comfortably per chip.
        # Kills the per-layer weight all-gathers (−98 % collective/token).
        tp_bytes = 2 * cfg.param_count() / max(model_k, 1)
        if tp_bytes <= 6e9:
            rules["embed"] = None
    elif variant != "baseline":
        raise ValueError(f"unknown sharding variant {variant!r}")
    return rules


def _ssm_heads(cfg: ModelConfig) -> int:
    if cfg.ssm is not None:
        return (cfg.ssm.expand * cfg.d_model) // cfg.ssm.head_dim
    if cfg.xlstm is not None:
        return cfg.xlstm.num_heads
    return 1


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------


def mesh_axis_size(entry: Any, mesh_sizes: Dict[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh_sizes.get(entry, 1)
    n = 1
    for a in entry:
        n *= mesh_sizes.get(a, 1)
    return n


def sanitize_spec(shape: Tuple[int, ...], spec: PS,
                  mesh_sizes: Dict[str, int]) -> PS:
    """Drop sharding on dims the mesh axis size does not divide — jit
    in_shardings require exact divisibility (vocab 51866, d_ff 2730, ...).
    Also drops repeated mesh axes within one spec (a mesh axis may shard at
    most one positional dimension); first occurrence wins."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    used: set = set()
    for dim, entry in zip(shape, entries):
        k = mesh_axis_size(entry, mesh_sizes)
        keep = entry if (k == 1 or dim % k == 0) else None
        if keep is not None:
            axes = (keep,) if isinstance(keep, str) else tuple(keep)
            if any(a in used for a in axes):
                keep = None
            else:
                used.update(axes)
        out.append(keep)
    return PS(*out)


def spec_from_axes(axes: Tuple[Optional[str], ...],
                   rules: Dict[str, Any]) -> PS:
    return PS(*[rules.get(ax) if ax is not None else None for ax in axes])


def param_specs(model, rules: Dict[str, Any],
                mesh_sizes: Optional[Dict[str, int]] = None):
    """PartitionSpec tree mirroring the model's param tree."""
    def mk(_, p):
        s = spec_from_axes(p.axes, rules)
        if mesh_sizes:
            s = sanitize_spec(p.shape, s, mesh_sizes)
        return s
    return tree_map_params(mk, model.param_tree())


def param_shardings(mesh: Mesh, model, rules: Dict[str, Any]):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(model, rules, sizes))


def tree_specs(axes_tree: Dict[str, Tuple], rules: Dict[str, Any],
               shapes: Optional[Dict[str, Any]] = None,
               mesh_sizes: Optional[Dict[str, int]] = None):
    out = {}
    for k, ax in axes_tree.items():
        s = spec_from_axes(ax, rules)
        if shapes is not None and mesh_sizes:
            s = sanitize_spec(tuple(shapes[k].shape), s, mesh_sizes)
        out[k] = s
    return out


def tree_shardings(mesh: Mesh, axes_tree, rules, shapes=None):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return {k: NamedSharding(mesh, s)
            for k, s in tree_specs(axes_tree, rules, shapes, sizes).items()}


# ---------------------------------------------------------------------------
# ClientPool / fused-tick mesh rules (control-plane scale-out)
# ---------------------------------------------------------------------------
# The pool's SoA state has exactly one shardable logical axis: ``users``
# (the population, pre-permuted into per-device region blocks by the
# MeshTickDriver).  Node/task attribute arrays are O(N) and replicated on
# every device — at edge-fleet sizes (10k nodes ≈ hundreds of KB) that is
# far cheaper than paying a cross-device gather in the border pass, and it
# is what makes the border band a purely *local* fixed-capacity pass.

# logical axes per FusedTickState field (leading ``users`` throughout;
# () scalars are widened to one element per device, hence ("users",))
POOL_STATE_AXES = {
    "ema_nodes": ("users", None), "ema_vals": ("users", None),
    "ema_overflow": ("users",),
    "cand": ("users", None), "active": ("users",), "pending": ("users",),
    "running": ("users",), "ticking": ("users",), "reinit": ("users",),
    "lat_probe": ("users", None), "lat_frame": ("users", None),
    "cand_traffic": ("users", None), "active_traffic": ("users",),
    "frame_count": ("users",), "frame_sum": ("users",),
    "failovers": ("users",),
}

# FusedTickStatic: user attribute arrays ride the users axis, node/task
# arrays are replicated (the ``shards`` field is host-side only — the mesh
# driver passes per-device task lists separately)
POOL_STATIC_AXES = {
    "user_lat": ("users",), "user_lon": ("users",), "user_net": ("users",),
    "user_code20": ("users",),
    "task_lat": (None,), "task_lon": (None,), "task_aff": (None, None),
    "task_code20": (None,), "task_cloud": (None,), "task_node": (None,),
    "node_proc": (None,), "node_slots": (None,),
}

# per-device local task lists: (D, T_loc) — one row per device
POOL_LOCAL_TASK_AXES = {"local_task": ("users", None)}


def make_pool_rules(mesh: Mesh, *, users_axis: str = None) -> Dict[str, Any]:
    """Logical-axis -> mesh-axis rules for the mesh-sharded ClientPool.

    The pool mesh is 1-D (``users`` over all devices) by default; pass
    ``users_axis`` to place the population on one axis of a larger mesh
    (the remaining axes replicate — the control plane has no model
    dimension to shard)."""
    ax = users_axis if users_axis is not None else mesh.axis_names[0]
    if ax not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {ax!r} "
                         f"(axes: {mesh.axis_names})")
    return {"users": ax}


def pool_specs(axes_tree: Dict[str, Tuple],
               rules: Dict[str, Any]) -> Dict[str, PS]:
    """PartitionSpecs for one of the POOL_*_AXES trees."""
    return tree_specs(axes_tree, rules)


def pool_shardings(mesh: Mesh, axes_tree: Dict[str, Tuple],
                   rules: Dict[str, Any]) -> Dict[str, NamedSharding]:
    """NamedShardings for one of the POOL_*_AXES trees."""
    return tree_shardings(mesh, axes_tree, rules)
