"""llama3-405b — frontier-scale dense decoder.

[arXiv:2407.21783] 126 layers, d_model=16384, 128 heads (GQA kv=8),
d_ff=53248, vocab=128256.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    attention=AttentionConfig(
        num_heads=128, num_kv_heads=8, head_dim=128,
        rope_theta=500_000.0,
    ),
    norm_eps=1e-5,
    notes="the memory-pressure stress case: needs ZeRO-3 + microbatching",
)
