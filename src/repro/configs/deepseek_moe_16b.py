"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed experts, top-6.

[arXiv:2401.06066] 28 layers, d_model=2048, 16 heads (kv=16), per-expert
d_ff=1408, vocab=102400.
"""
from repro.config import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    d_ff=1408,          # per-expert hidden (fine-grained)
    vocab_size=102400,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        experts_per_token=6,
        d_expert=1408,
        num_shared_experts=2,
        capacity_factor=1.25,
    ),
    norm_eps=1e-6,
    notes="fine-grained MoE; all-to-all dispatch is the collective hot spot",
)
