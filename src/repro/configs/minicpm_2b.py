"""minicpm-2b — llama-like dense decoder trained with the WSD schedule.

[arXiv:2404.06395] 40 layers, d_model=2304, 36 heads (MHA kv=36), d_ff=5760,
vocab=122753.  The WSD (warmup-stable-decay) schedule lives in
repro.optim.schedule and is selected by this arch's TrainConfig.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    d_ff=5760,
    vocab_size=122753,
    attention=AttentionConfig(num_heads=36, num_kv_heads=36, head_dim=64),
    tie_embeddings=True,
    norm_eps=1e-5,
    notes="WSD schedule (optim/schedule.py); depth-scaled init per paper",
)
