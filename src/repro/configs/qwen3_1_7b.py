"""qwen3-1.7b — dense GQA decoder with qk_norm.

[hf:Qwen/Qwen3-1.7B family] 28 layers, d_model=2048, 16 heads (GQA kv=8),
d_ff=6144, vocab=151936.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    d_ff=6144,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0,
    ),
    tie_embeddings=True,
    norm_eps=1e-6,
)
