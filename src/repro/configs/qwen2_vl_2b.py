"""qwen2-vl-2b — VLM backbone with M-RoPE.

[arXiv:2409.12191] 28 layers, d_model=1536, 12 heads (GQA kv=2), d_ff=8960,
vocab=151936.  M-RoPE splits head_dim rotary channels into (temporal, height,
width) sections; the ViT patch frontend is a STUB (precomputed patch
embeddings prepended to the token stream).
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    d_ff=8960,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=12, num_kv_heads=2, head_dim=128,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),   # t/h/w rotary sections (sum = hd/2)
    ),
    num_patches=256,                   # stub visual prefix per request
    tie_embeddings=True,
    norm_eps=1e-6,
    notes="M-RoPE; dynamic-resolution ViT frontend stubbed as patch embeddings",
)
