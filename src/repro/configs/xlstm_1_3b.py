"""xlstm-1.3b — sLSTM + mLSTM block stack (attention-free).

[arXiv:2405.04517] 48 blocks, d_model=2048, 4 mLSTM heads, vocab=50304,
d_ff=0 (projection factors live inside the blocks).  Sub-quadratic: runs the
long_500k shape with O(1) recurrent state.
"""
from repro.config import AttentionConfig, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    d_ff=0,
    vocab_size=50304,
    # attention config is unused for compute; kept for uniform head metadata
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=512),
    xlstm=XLSTMConfig(slstm_every=8, num_heads=4),
    norm_eps=1e-5,
    notes="attention-free; Armada session offload stores recurrent state",
)
