"""whisper-large-v3 — encoder-decoder audio transformer backbone.

[arXiv:2212.04356] 32 enc + 32 dec layers, d_model=1280, 20 heads (MHA,
kv=20), d_ff=5120, vocab=51866.  The conv/mel frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings of shape
(batch, 1500, 1280).
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    num_encoder_layers=32,
    encoder_seq=1500,
    encoder_feature_dim=1280,
    d_model=1280,
    d_ff=5120,
    vocab_size=51866,
    attention=AttentionConfig(
        num_heads=20, num_kv_heads=20, head_dim=64,
        rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    ),
    norm_eps=1e-5,
    notes="enc-dec; conv frontend stubbed (frame embeddings fed directly)",
)
