"""qwen3-14b — dense GQA decoder with qk_norm.

[hf:Qwen/Qwen3-14B] 40 layers, d_model=5120, 40 heads (GQA kv=8),
d_ff=17408, vocab=151936.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=40, num_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0,
    ),
    norm_eps=1e-6,
)
