"""armada-facerec — the paper's face-recognition service (§5.2).

Face-embedding model producing 128-d descriptors (matching the paper's
<ID (8 bytes), vector (128*8 bytes)> Cargo records), exercising the storage
layer: read-only / write-only / read-followed-by-write workloads under
strong vs eventual consistency.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="armada-facerec",
    family="vlm",
    num_layers=4,
    d_model=192,
    d_ff=768,
    vocab_size=128,          # descriptor dimension (output head)
    attention=AttentionConfig(num_heads=6, num_kv_heads=6, head_dim=32,
                              causal=False),
    num_patches=64,
    norm_eps=1e-6,
    notes="paper §5.2 workload; descriptors stored in Cargo",
)
