"""zamba2-7b — Mamba2 backbone with a shared attention block.

[arXiv:2411.15242] 81 blocks, d_model=3584, 32 heads, d_ff=14336,
vocab=32000, ssm_state=64.  One shared (weight-tied) attention+MLP block is
applied every ``hybrid_attn_every`` Mamba2 blocks.  Sub-quadratic: runs
long_500k (Mamba2 state is O(1); the shared-attention decode step is linear
in cache length).
"""
from repro.config import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=112),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid_attn_every=6,
    norm_eps=1e-5,
    notes="shared attention block weight-tied across its application sites",
)
