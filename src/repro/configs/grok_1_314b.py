"""grok-1-314b — 314B-parameter MoE, 8 experts top-2.

[hf:xai-org/grok-1] 64 layers, d_model=6144, 48 heads (GQA kv=8),
d_ff=32768 per expert, vocab=131072.
"""
from repro.config import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131072,
    attention=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(
        num_experts=8,
        experts_per_token=2,
        d_expert=32768,
        num_shared_experts=0,
        capacity_factor=1.25,
    ),
    norm_eps=1e-5,
    notes="coarse MoE; expert-parallel over the model axis",
)
