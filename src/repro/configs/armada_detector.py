"""armada-detector — the paper's real-time object-detection service (§5.1).

A small vision-transformer-style detector standing in for the paper's
object-detection model: it is the *service payload* for the Armada control
plane benchmarks (selection, scalability, fault tolerance).  Sized so a
jitted forward runs in tens of ms on heterogeneous "edge nodes" — matching
Table 5's 24-58 ms/frame envelope when scaled by node speed factors.
"""
from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="armada-detector",
    family="vlm",
    num_layers=6,
    d_model=256,
    d_ff=1024,
    vocab_size=128,          # detection classes head
    attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=32,
                              causal=False),
    num_patches=196,         # 14x14 patches per frame (stub frontend)
    norm_eps=1e-6,
    notes="paper §5.1 workload; runs really on CPU in benchmarks",
)
