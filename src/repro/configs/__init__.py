"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config; ``reduced`` (from
repro.config) shrinks it for CPU smoke tests.  ``ARCH_IDS`` is the assignment
order used by the dry-run and roofline table.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCH_IDS = (
    "whisper-large-v3",
    "deepseek-moe-16b",
    "grok-1-314b",
    "qwen2-vl-2b",
    "qwen3-1.7b",
    "minicpm-2b",
    "qwen3-14b",
    "llama3-405b",
    "xlstm-1.3b",
    "zamba2-7b",
    # the paper's own workloads (Armada services), not part of the 40 cells:
    "armada-detector",
    "armada-facerec",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def assigned_archs() -> tuple:
    """The 10 graded architectures (excludes the paper's demo services)."""
    return ARCH_IDS[:10]
