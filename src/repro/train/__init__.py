"""Training substrate: step function, trainer loop, fault tolerance."""
