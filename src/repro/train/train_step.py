"""The pjit-able training step: loss -> grads -> AdamW, with microbatch
gradient accumulation (scan), global-norm clipping, and an optional int8
gradient-compression hook.

Grad accumulation is a scan over microbatches so only one microbatch's
activations are live at a time — this is what lets llama3-405b's train_4k
cell fit 16 GB/chip (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.optim import AdamW, make_schedule


def _int8_roundtrip(g):
    """Symmetric per-tensor int8 quantize/dequantize (compression hook).

    Models the bandwidth of int8 gradient exchange; the quantization error
    is really applied so experiments see its effect on convergence.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_train_step(model, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""
    opt = AdamW(tc)
    sched = make_schedule(tc)

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=tc.remat)

    def train_step(params, opt_state, batch):
        M = tc.microbatches
        if M > 1:
            adt = jnp.dtype(tc.accum_dtype)
            mbs = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)

            def accum(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: (a.astype(jnp.float32)
                                  + g.astype(jnp.float32)).astype(adt),
                    gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss = lsum / M
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if tc.grad_compression == "int8":
            grads = jax.tree.map(_int8_roundtrip, grads)

        lr = sched(opt_state.step)
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params,
                                                lr)
        # in-graph divergence guard: a non-finite loss keeps the old state
        # (donation-safe — the select happens inside the jitted step)
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        pick = lambda n, o: jnp.where(ok, n, o)
        params = jax.tree.map(pick, new_params, params)
        opt_state = type(new_opt)(
            step=pick(new_opt.step, opt_state.step),
            mu=jax.tree.map(pick, new_opt.mu, opt_state.mu),
            nu=jax.tree.map(pick, new_opt.nu, opt_state.nu))
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "skipped": (~ok).astype(jnp.int32)}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model, tc: TrainConfig) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch, remat="none")
    return eval_step
