"""Trainer: the fault-tolerant training loop.

Production behaviors, all exercised by tests/examples on CPU:

* checkpoint/restart — async sharded checkpoints every N steps; on (re)start
  the trainer restores the newest complete checkpoint and the data pipeline
  replays deterministically from that step
* preemption safety — ``SIGTERM``-style interruption between steps triggers
  a final synchronous checkpoint (``trainer.interrupt()`` in tests)
* straggler mitigation — per-step wall times feed an EMA; steps slower than
  ``straggler_factor``× the EMA are counted and surfaced; the Armada layer
  uses the same signal to demote slow serving replicas (probe-driven), and
  at cluster scale the hook is where over-dispatch would engage
* NaN/divergence guard — non-finite loss skips the update (grads dropped),
  counts toward ``skipped_steps``
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.config import ModelConfig, TrainConfig
from repro.data import TokenPipeline
from repro.optim import AdamW
from repro.train.train_step import make_train_step


@dataclass
class TrainMetrics:
    steps: List[dict] = field(default_factory=list)
    skipped_steps: int = 0
    straggler_steps: int = 0
    restarts: int = 0


class Trainer:
    def __init__(self, model, cfg: ModelConfig, tc: TrainConfig, *,
                 batch: int, seq: int, ckpt_dir: str,
                 straggler_factor: float = 3.0, dtype: str = "float32"):
        self.model = model
        self.cfg = cfg
        self.tc = tc
        self.batch = batch
        self.seq = seq
        self.ckpt = Checkpointer(ckpt_dir,
                                 async_write=tc.async_checkpoint)
        self.pipeline = TokenPipeline(cfg, batch=batch, seq=seq,
                                      seed=tc.seed)
        self.opt = AdamW(tc)
        self.step_fn = jax.jit(make_train_step(model, tc),
                               donate_argnums=(0, 1))
        self.metrics = TrainMetrics()
        self.straggler_factor = straggler_factor
        self._ema_ms: Optional[float] = None
        self._interrupted = False
        self.dtype = dtype
        self.params = None
        self.opt_state = None
        self.step = 0

    # ------------------------------------------------------------ lifecycle

    def init_or_restore(self, rng=None):
        rng = rng if rng is not None else jax.random.key(self.tc.seed)
        self.params = self.model.init(rng, self.dtype)
        self.opt_state = self.opt.init(self.params)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = {"params": self.params,
                     "opt": self.opt_state._asdict()}
            restored, step = self.ckpt.restore(latest, state)
            self.params = restored["params"]
            from repro.optim.adamw import OptState
            self.opt_state = OptState(**restored["opt"])
            self.step = step
            self.metrics.restarts += 1
        return self.step

    def interrupt(self):
        """Preemption signal: checkpoint at the next step boundary."""
        self._interrupted = True

    # ---------------------------------------------------------------- train

    def train(self, num_steps: int, log_every: int = 10) -> TrainMetrics:
        assert self.params is not None, "call init_or_restore() first"
        self.pipeline.start(from_step=self.step)
        try:
            end = self.step + num_steps
            while self.step < end:
                batch = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                self.params, self.opt_state, m = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(m["loss"])
                dt = (time.perf_counter() - t0) * 1e3
                # divergence guard ran in-graph (donation-safe)
                self.metrics.skipped_steps += int(m["skipped"])

                if self._ema_ms is not None and \
                        dt > self.straggler_factor * self._ema_ms:
                    self.metrics.straggler_steps += 1
                self._ema_ms = dt if self._ema_ms is None else \
                    0.2 * dt + 0.8 * self._ema_ms

                self.step += 1
                self.metrics.steps.append(
                    {"step": self.step, "loss": loss, "ms": dt,
                     "grad_norm": float(m["grad_norm"]),
                     "lr": float(m["lr"])})
                if self.step % self.tc.checkpoint_every == 0 \
                        or self._interrupted:
                    self._save()
                    if self._interrupted:
                        break
        finally:
            self.pipeline.stop()
        return self.metrics

    def _save(self):
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state._asdict()})
        if self._interrupted:
            self.ckpt.wait()
