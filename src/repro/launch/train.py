"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container use ``--preset tiny`` (runs a few hundred steps of a
reduced config in minutes).  On a pod, drop ``--preset`` and pass
``--mesh single|multi`` to train the full config under the production mesh
(the same sharding rules the dry-run validates).
"""
from __future__ import annotations

import argparse
import json

from repro.config import SHAPES, TrainConfig, reduced
from repro.configs import get_config
from repro.models.api import build_model
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", choices=["tiny", "small", "full"],
                    default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
        batch, seq = args.batch or 8, args.seq or 128
    elif args.preset == "small":
        cfg = reduced(cfg, num_layers=min(cfg.num_layers, 8), d_model=256,
                      d_ff=1024, vocab_size=4096)
        batch, seq = args.batch or 16, args.seq or 256
    else:
        batch, seq = args.batch or 256, args.seq or 4096

    # MiniCPM trains with WSD (its signature schedule)
    schedule = "wsd" if args.arch == "minicpm-2b" and \
        args.schedule == "cosine" else args.schedule
    tc = TrainConfig(learning_rate=args.lr, schedule=schedule,
                     warmup_steps=max(args.steps // 20, 5),
                     decay_steps=args.steps,
                     stable_steps=int(args.steps * 0.8),
                     microbatches=args.microbatches,
                     checkpoint_every=args.checkpoint_every,
                     remat="none" if args.preset == "tiny" else "full")
    model = build_model(cfg)
    trainer = Trainer(model, cfg, tc, batch=batch, seq=seq,
                      ckpt_dir=f"{args.ckpt_dir}/{args.arch}")
    start = trainer.init_or_restore()
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={cfg.param_count()/1e6:.1f}M start_step={start}")
    metrics = trainer.train(args.steps, log_every=args.log_every)
    for s in metrics.steps[::args.log_every]:
        print(f"  step {s['step']:5d} loss {s['loss']:.4f} "
              f"lr {s['lr']:.2e} {s['ms']:.0f} ms")
    if metrics.steps:
        first, last = metrics.steps[0], metrics.steps[-1]
        print(f"[train] loss {first['loss']:.4f} -> {last['loss']:.4f} "
              f"({len(metrics.steps)} steps, "
              f"{metrics.skipped_steps} skipped, "
              f"{metrics.straggler_steps} stragglers)")


if __name__ == "__main__":
    main()
