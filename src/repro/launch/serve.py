"""Serving driver: Armada replicas over real jitted engines.

``python -m repro.launch.serve --arch qwen3-1.7b --requests 12`` builds N
replica engines (reduced config on CPU), registers them as Armada service
replicas, routes a batch of generation requests through 2-step selection,
and reports per-request latency + the selected replicas.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import reduced
from repro.configs import get_config
from repro.models.api import build_model
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if cfg.family in ("encdec", "vlm"):
        print(f"[serve] {args.arch}: engine demo uses decoder-only reduced "
              f"configs; switching to qwen3-1.7b backbone")
        cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engines = [ServeEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=128) for _ in range(args.replicas)]
    rng = np.random.default_rng(0)

    # probe each replica once (step 2 of Armada selection, in-process)
    for i, e in enumerate(engines):
        e.submit(f"probe{i}", list(rng.integers(2, 100, 4)),
                 max_new_tokens=2)
        t0 = time.perf_counter()
        e.run_until_drained()
        print(f"[probe] replica {i}: {(time.perf_counter()-t0)*1e3:.1f} ms")

    t0 = time.perf_counter()
    lat = {}
    for r in range(args.requests):
        # least-loaded warm replica (queue depth = probe signal here)
        e = min(engines, key=lambda e: len(e.scheduler.queue)
                + sum(x is not None for x in e.scheduler.slots))
        e.submit(f"req{r}", list(rng.integers(2, 100, 8)),
                 max_new_tokens=args.max_new_tokens)
        lat[f"req{r}"] = time.perf_counter()
    done = {}
    while len(done) < args.requests:
        for e in engines:
            for rid, toks in e.step().items():
                if rid in lat:
                    done[rid] = (time.perf_counter() - lat[rid]) * 1e3
    total = time.perf_counter() - t0
    ms = sorted(done.values())
    print(f"[serve] {args.requests} requests on {args.replicas} replicas in "
          f"{total:.2f}s; p50={ms[len(ms)//2]:.0f}ms p95={ms[int(len(ms)*.95)-1]:.0f}ms")


if __name__ == "__main__":
    main()
