"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import os

import jax

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig


def _override(multi_pod: bool):
    """REPRO_MESH_OVERRIDE="4x2" / "2x2x2" shrinks the mesh for test-scale
    dry-runs (8 host devices) without touching production defaults."""
    env = os.environ.get("REPRO_MESH_OVERRIDE")
    if not env:
        return None
    parts = env.split(";")
    spec = parts[1] if multi_pod and len(parts) > 1 else parts[0]
    shape = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(shape):]
    return MeshConfig(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    cfg = mesh_config(multi_pod=multi_pod)
    return jax.make_mesh(cfg.shape, cfg.axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    ov = _override(multi_pod)
    if ov is not None:
        return ov
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_config(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes)


def make_local_mesh(axes=("data", "model")):
    """A mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((1,) * (len(axes) - 1) + (n,), axes)
