import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_HOST_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any jax import (jax locks the device count
on first init); they give this process 512 host devices so the production
meshes (16×16 single-pod, 2×16×16 multi-pod) can be built.

For each cell we jit the appropriate step (train_step / prefill_step /
serve_step) with full in/out shardings, ``.lower().compile()``, then record
memory_analysis / cost_analysis / collective stats to
``artifacts/dryrun/<cell>.json`` — the §Roofline table is generated from
those artifacts by benchmarks/bench_roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 1]
"""

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# gradient-accumulation depth per arch for train_4k (memory fit; DESIGN.md §5)
MICROBATCHES = {
    "llama3-405b": 32,
    "grok-1-314b": 16,
    "qwen3-14b": 4,
    "zamba2-7b": 4,
    "deepseek-moe-16b": 4,
    "whisper-large-v3": 2,
    "minicpm-2b": 2,
    "qwen2-vl-2b": 2,
    "qwen3-1.7b": 2,
    "xlstm-1.3b": 2,
}

# bf16 optimizer moments where fp32 states cannot fit the pod (DESIGN.md §5)
BF16_OPT = {"llama3-405b", "grok-1-314b"}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline", moe_dispatch: str = "einsum",
               microbatches: int | None = None, remat: str = "full",
               moe_group: int = 512, decode_impl: str = "scan"):
    """Lower+compile one cell; returns the artifact record dict."""
    import jax

    from repro.config import SHAPES, TrainConfig, shape_applicable
    from repro.configs import get_config
    from repro.distributed.api import axis_rules
    from repro.distributed.sharding import (make_rules, param_shardings,
                                            tree_shardings)
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.models.api import build_model, cache_axes, input_axes
    from repro.optim import AdamW
    from repro.telemetry import roofline as rf
    from repro.train.train_step import make_train_step
    from jax.sharding import NamedSharding, PartitionSpec as PS

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "moe_dispatch": moe_dispatch}
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mcfg = mesh_config(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mcfg, shape, variant=variant)
    model = build_model(cfg, moe_dispatch=moe_dispatch,
                        moe_group=moe_group)
    mb = microbatches if microbatches is not None else \
        MICROBATCHES.get(arch, 1)
    tc = TrainConfig(
        microbatches=mb if shape.kind == "train" else 1,
        remat=remat,
        opt_state_dtype="bfloat16" if arch in BF16_OPT else "float32",
        accum_dtype="bfloat16" if arch in BF16_OPT else "float32",
    )

    params_abs = model.abstract("bfloat16")
    p_shard = param_shardings(mesh, model, rules)
    specs = model.input_specs(shape)
    in_ax = input_axes(specs)
    b_shard = tree_shardings(mesh, in_ax, rules, shapes=specs)
    repl = NamedSharding(mesh, PS())

    t0 = time.time()
    with axis_rules(rules, mesh=mesh):
        if shape.kind == "train":
            step = make_train_step(model, tc)
            opt = AdamW(tc)
            opt_abs = opt.init_abstract(params_abs)
            o_shard = type(opt_abs)(step=repl,
                                    mu=jax.tree.map(lambda s: s, p_shard),
                                    nu=jax.tree.map(lambda s: s, p_shard))
            metrics_shard = {"loss": repl, "grad_norm": repl, "lr": repl,
                             "skipped": repl}
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, metrics_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch, max_seq=shape.seq_len)
            jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            cache_abs = model.init_cache_abstract(shape.global_batch,
                                                  shape.seq_len, "bfloat16")
            c_shard = tree_shardings(mesh, cache_axes(model, cache_abs),
                                     rules, shapes=cache_abs)
            step_fn = model.decode_step_fori if decode_impl == "fori" \
                else model.decode_step

            def serve_step(params, cache, batch):
                return step_fn(params, cache, batch)
            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, c_shard, b_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, specs)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(ma, f, None)
                if v is not None:
                    mem[f] = int(v)
    except Exception as e:          # CPU backend may not implement it
        mem["error"] = repr(e)

    roof, ca = rf.from_compiled(compiled, None, chips=mcfg.num_devices)
    coll = ca.pop("_walker_coll_by_kind", {})
    mf = rf.model_flops(cfg, shape)

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        microbatches=tc.microbatches,
        chips=mcfg.num_devices,
        memory_analysis=mem,
        cost_analysis={k: v for k, v in sorted(ca.items())
                       if isinstance(v, (int, float))},
        collectives={k: {"count": v["count"], "gbytes": v["bytes"] / 1e9}
                     for k, v in sorted(coll.items())},
        roofline=roof.as_dict(),
        model_flops=mf,
        model_flops_per_chip=mf / mcfg.num_devices,
        useful_flop_ratio=(mf / mcfg.num_devices) / roof.flops
        if roof.flops else None,
    )
    return rec


def cell_path(arch, shape, mesh_name, variant="baseline") -> pathlib.Path:
    tag = f"{arch}__{shape}__{mesh_name}"
    if variant != "baseline":
        tag += f"__{variant}"
    return ART_DIR / f"{tag}.json"


def run_cell_subprocess(arch, shape, mesh_name, variant, timeout=3600):
    """Run one cell in a fresh process (RAM + XLA isolation)."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh_name, "--variant", variant]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[2])
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env)
    return r.returncode, time.time() - t0, r.stdout[-2000:], r.stderr[-4000:]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--moe-dispatch", default="einsum")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moe-group", type=int, default=512)
    ap.add_argument("--decode-impl", default="scan",
                    choices=["scan", "fori"])
    ap.add_argument("--tag", default=None,
                    help="artifact name suffix for perf iterations")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    ART_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.config import SHAPES
        from repro.configs import assigned_archs
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in assigned_archs():
            for shape in SHAPES:
                for mesh_name in meshes:
                    p = cell_path(arch, shape, mesh_name, args.variant)
                    if p.exists() and not args.force:
                        print(f"[cached] {p.name}")
                        continue
                    print(f"[run] {arch} × {shape} × {mesh_name} ...",
                          flush=True)
                    code, dt, out, err = run_cell_subprocess(
                        arch, shape, mesh_name, args.variant)
                    if code != 0:
                        failures.append((arch, shape, mesh_name))
                        print(f"  FAILED ({dt:.0f}s)\n{err}", flush=True)
                    else:
                        print(f"  ok ({dt:.0f}s)", flush=True)
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells passed")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_name in meshes:
        rec = None
        try:
            rec = build_cell(args.arch, args.shape, mesh_name == "multi",
                             args.variant, args.moe_dispatch,
                             args.microbatches, args.remat,
                             args.moe_group, args.decode_impl)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
                   "variant": args.variant, "status": "error",
                   "error": traceback.format_exc()}
        rec["tag"] = args.tag or args.variant
        rec["remat"] = args.remat
        out = cell_path(args.arch, args.shape, mesh_name,
                        args.tag or args.variant)
        out.write_text(json.dumps(rec, indent=1, default=str))
        print(json.dumps({k: rec.get(k) for k in
                          ("arch", "shape", "mesh", "status", "compile_s",
                           "roofline")}, indent=1, default=str))
        if rec.get("status") == "error":
            print(rec["error"], file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
