"""Roofline terms from the compiled dry-run artifact (no real hardware).

Three terms, per (arch × shape × mesh) cell — see system DESIGN.md §6:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw × links)

``cost_analysis()`` supplies FLOPs and bytes (per-device, post-SPMD).
collective_bytes comes from parsing the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes max(operand, output) bytes, scaled by an op-specific wire
multiplier (all-reduce rides the wire twice: reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.config import HardwareConfig, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|[a-z0-9\[\],{}\s]*?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

# wire-traffic multiplier per output byte (ring algorithms, large-n limit)
_WIRE_MULT = {
    "all-gather": 1.0,        # each chip receives (n-1)/n of the output
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} from (post-SPMD, per-device) HLO text."""
    stats: Dict[str, Dict[str, float]] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        out_text, kind = m.group(1), m.group(2)
        if "-done" in line.split("=", 1)[-1][:120] and kind in line:
            # -done ops re-state the shape of the matching -start; count once
            key = line.strip().split(" = ")[0]
            if key in seen_done:
                continue
        if f"{kind}-done" in line:
            continue
        out_bytes = _shape_bytes(out_text)
        # operands appear inside the (...) call — parse the rest of the line
        rest = line[m.end():]
        in_bytes = _shape_bytes(rest.split("),")[0] if ")," in rest else rest)
        moved = max(out_bytes, in_bytes) * _WIRE_MULT[kind]
        s = stats.setdefault(kind, {"count": 0, "bytes": 0.0})
        s["count"] += 1
        s["bytes"] += moved
    return stats


def collective_bytes(hlo_text: str) -> float:
    return sum(s["bytes"] for s in collective_stats(hlo_text).values())


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective wire bytes
    chips: int
    hw: HardwareConfig = V5E

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.hw.ici_bw * self.hw.ici_links)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def compute_fraction(self) -> float:
        """How roofline-limited compute is: 1.0 = perfectly compute-bound."""
        if self.bound_time == 0:
            return 0.0
        return self.t_compute / self.bound_time

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "compute_fraction": self.compute_fraction(),
        }


def from_compiled(compiled, lowered_text: Optional[str], chips: int,
                  hw: HardwareConfig = V5E) -> Tuple[Roofline, Dict]:
    """Build a Roofline from a jax compiled object.

    Primary source: the while-aware HLO walker (telemetry.hlo_cost) — XLA's
    own cost_analysis counts scan bodies once, undercounting every
    scanned-layer model by ~num_layers.  The raw cost_analysis dict is
    returned alongside for reference.
    """
    from repro.telemetry import hlo_cost

    raw = compiled.cost_analysis() or {}
    if isinstance(raw, (list, tuple)):    # older jax wraps it in a list
        raw = raw[0] if raw else {}
    ca = dict(raw)
    cost = hlo_cost.analyze_compiled(compiled)
    roof = Roofline(flops=cost.flops, hbm_bytes=cost.bytes,
                    coll_bytes=cost.coll_bytes, chips=chips, hw=hw)
    ca["_walker_coll_by_kind"] = cost.coll
    return roof, ca


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), D = tokens."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.tokens
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
