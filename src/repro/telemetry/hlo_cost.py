"""While-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, ignoring
the trip count — which undercounts every scanned-layer model by ~num_layers
(verified in tests/test_telemetry.py).  Since all our models scan over
layers and microbatches, we walk the compiled per-device HLO ourselves:

* dot flops = 2 · |out| · |contracting dims|, via a per-computation symbol
  table (operands in compiled HLO are bare ``%names``)
* HBM traffic model: every materialized op reads its operands and writes its
  outputs (post-fusion HLO, so this matches what fusions actually do);
  fusion call sites count their parameters+root only
* ``while``: trip count from ``backend_config={"known_trip_count":...}``,
  body cost multiplied through (nested whiles compose)
* collectives: per-kind wire bytes = max(in, out) · ring multiplier,
  trip-count aware.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "after-all", "iota",
               "rng-bit-generator",
               # while-carry copies: elided by buffer aliasing on TPU; the
               # CPU backend materializes them, which would dominate and
               # misrepresent the TPU roofline (see DESIGN.md §6)
               "copy", "copy-start", "copy-done"}
# ops that touch only a slice of their big operand: traffic = 2·slice
_SLICE_READS = {"dynamic-slice", "gather", "slice"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    out_text: str
    kind: str
    rest: str           # everything after the opening paren

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.out_text)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_bytes(self, kind: str, n: float):
        self.bytes += n
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + n

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll.items():
            s = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            s["count"] += v["count"] * mult
            s["bytes"] += v["bytes"] * mult
        for k, v in other.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0.0) + v * mult


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        # strip metadata/backend_config payloads except trip counts
        work = line
        m = _INSTR_RE.match(work)
        if not m:
            continue
        name, out_text, kind, rest = m.groups()
        comps[cur].append(Instr(name, out_text, kind, rest))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry        # type: ignore
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    out = _shape_dims(instr.out_text)
    if out is None:
        return 0.0
    _, out_dims = out
    n_out = 1
    for d in out_dims:
        n_out *= d
    lhs_m = _OPERAND_RE.search(instr.rest)
    k = 1
    mc = _LHS_C_RE.search(instr.rest)
    if lhs_m and mc:
        lhs_shape = symtab.get(lhs_m.group(1))
        if lhs_shape:
            sd = _shape_dims(lhs_shape)
            if sd:
                dims = sd[1]
                for ix in (int(x) for x in mc.group(1).split(",") if x):
                    if ix < len(dims):
                        k *= dims[ix]
    return 2.0 * n_out * k


def _operand_names(instr: Instr) -> List[str]:
    # operands only appear up to the closing paren of the op call
    call = instr.rest.split("),")[0]
    return _OPERAND_RE.findall(call)


def _operand_bytes(instr: Instr, symtab: Dict[str, str],
                   skip_first: int = 0) -> int:
    total = 0
    for nm in _operand_names(instr)[skip_first:]:
        if nm in symtab:
            total += _shape_bytes(symtab[nm])
    return total


def _fusion_traffic(fused_name: str,
                    comps: Dict[str, List["Instr"]],
                    operands: List[str],
                    symtab: Dict[str, str]) -> Tuple[int, int]:
    """(read, write) HBM traffic of a fusion call site.

    Read: a parameter consumed ONLY by slice-type ops contributes the slice
    bytes, not the full buffer (per-layer weight slices under scan).
    Write: a root that is a dynamic-update-slice aliases its big operand on
    TPU — it writes only the update slice (KV-cache append pattern).
    """
    instrs = comps.get(fused_name, [])
    by_name = {i.name: i for i in instrs}
    inner_tab = {i.name: i.out_text for i in instrs}
    param_vars: Dict[int, str] = {}
    for ins in instrs:
        if ins.kind == "parameter":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                param_vars[int(m.group(1))] = ins.name

    read = 0
    for idx, op_name in enumerate(operands):
        full = _shape_bytes(symtab.get(op_name, ""))
        pvar = param_vars.get(idx)
        if pvar is None:
            read += full
            continue
        consumers = [i for i in instrs if pvar in _operand_names(i)]
        if consumers and all(
                i.kind in _SLICE_READS or
                (i.kind in _SLICE_WRITES and
                 _operand_names(i) and _operand_names(i)[0] == pvar)
                for i in consumers):
            sl = 0
            for i in consumers:
                if i.kind in _SLICE_READS:
                    sl += i.out_bytes
                else:                     # DUS: writes update-sized slice
                    sl += _operand_bytes(i, inner_tab, skip_first=1)
            read += min(sl, full)
        else:
            read += full

    def write_of(var: str) -> int:
        ins = by_name.get(var)
        if ins is None:                   # parameter passthrough
            return 0
        if ins.kind in _SLICE_WRITES:
            return _operand_bytes(ins, inner_tab, skip_first=1)
        return ins.out_bytes

    write = 0
    if instrs:
        root = instrs[-1]                 # HLO prints ROOT last
        if root.kind == "tuple":
            for nm in _operand_names(root):
                write += write_of(nm)
        else:
            write = write_of(root.name)
    return read, write


def analyze(hlo: str) -> Cost:
    comps = parse_computations(hlo)
    entry_name = comps.pop("__entry_name__", None)   # type: ignore
    comps.pop("__entry__", None)

    # symbol tables per computation
    symtabs: Dict[str, Dict[str, str]] = {}
    for cname, instrs in comps.items():
        tab: Dict[str, str] = {}
        for i in instrs:
            tab[i.name] = i.out_text
        symtabs[cname] = tab

    memo: Dict[str, Cost] = {}

    def cost_of(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        memo[cname] = Cost()                     # break cycles defensively
        c = Cost()
        tab = symtabs.get(cname, {})
        for ins in comps.get(cname, []):
            kind = ins.kind
            if kind == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                mb = _BODY_RE.search(ins.rest)
                if mb:
                    c.add(cost_of(mb.group(1)), trip)
                mcond = _COND_RE.search(ins.rest)
                if mcond:
                    c.add(cost_of(mcond.group(1)), trip)
                continue
            if kind in ("fusion", "call", "async-start"):
                mcall = _CALLS_RE.search(ins.rest)
                read, write = _operand_bytes(ins, tab), ins.out_bytes
                if mcall:
                    inner = cost_of(mcall.group(1))
                    c.flops += inner.flops
                    c.coll_bytes += inner.coll_bytes
                    for k, v in inner.coll.items():
                        s = c.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
                        s["count"] += v["count"]
                        s["bytes"] += v["bytes"]
                    read, write = _fusion_traffic(
                        mcall.group(1), comps, _operand_names(ins), tab)
                c.add_bytes(kind, read + write)
                continue
            if kind == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     ins.rest)
                if branches:
                    subs = [cost_of(b.strip().lstrip("%"))
                            for b in branches.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.bytes)
                        c.add(best)
                continue
            base = kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if kind.endswith("-done"):
                    continue
                moved = max(ins.out_bytes, _operand_bytes(ins, tab))
                moved *= _WIRE_MULT[base]
                c.coll_bytes += moved
                s = c.coll.setdefault(base, {"count": 0.0, "bytes": 0.0})
                s["count"] += 1
                s["bytes"] += moved
                c.add_bytes(base, ins.out_bytes + _operand_bytes(ins, tab))
                continue
            if kind == "dot":
                c.flops += _dot_flops(ins, tab)
            if kind in _SLICE_READS:
                # reads+writes only the slice, not the source buffer
                c.add_bytes(kind, 2 * ins.out_bytes)
                continue
            if kind in _SLICE_WRITES:
                # in-place on TPU: reads+writes only the update slice
                upd = _operand_bytes(ins, tab, skip_first=1)
                c.add_bytes(kind, 2 * upd)
                continue
            if kind not in _SKIP_BYTES:
                c.add_bytes(kind, ins.out_bytes + _operand_bytes(ins, tab))
        memo[cname] = c
        return c

    if entry_name is None:
        return Cost()
    # fusions/bodies are reachable only via call sites; cost_of(entry)
    # rolls everything up exactly once.
    return cost_of(entry_name)


def analyze_compiled(compiled) -> Cost:
    return analyze(compiled.as_text())
