"""Telemetry: roofline terms from compiled artifacts, HLO collective parsing."""
