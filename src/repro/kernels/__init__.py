"""Pallas TPU kernels for the compute hot spots of Armada-served models.

Each kernel subpackage ships three files:

* ``kernel.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU
  target; validated on CPU with ``interpret=True``)
* ``ops.py``    — jit'd public wrapper; dispatches pallas on TPU, the jnp
  reference on other backends (keeps the 512-device CPU dry-run lowerable)
* ``ref.py``    — pure-jnp oracle used by tests and as the CPU fallback

Kernels: flash_attention (prefill/train), decode_attention (single-token
serve), moe_gmm (grouped expert matmul), ssm_scan (Mamba2 chunked SSD),
geo_topk (fused control-plane edge selection: haversine + net affinity +
resource scoring with per-user top-k, paper §3.2 Algorithm 1).
"""
