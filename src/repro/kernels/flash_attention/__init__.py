from repro.kernels.flash_attention.ops import mha  # noqa: F401
