"""Pure-jnp oracle for flash attention (GQA, causal/window, offsets)."""
from __future__ import annotations

import jax.numpy as jnp


def mha_reference(q, k, v, *, causal=True, scale=None, q_offset=0,
                  kv_len=None, window=0):
    """Attention with grouped KV heads.

    q: (B, Hq, Tq, D);  k, v: (B, Hkv, Tk, D);  Hkv divides Hq.
    ``q_offset``: global position of q[0] (decode/chunked prefill).
    ``kv_len``: valid key length (rest masked; supports padded caches).
    ``window``: sliding-window size (0 = unlimited).
    Returns (B, Hq, Tq, D) in q.dtype.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # (B, Hkv, group, Tq, Tk)
    qg = qf.reshape(B, Hkv, group, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)

    q_pos = q_offset + jnp.arange(Tq)[:, None]
    k_pos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if kv_len is not None:
        mask &= k_pos < kv_len
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)           # fully-masked row guard
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Tq, D).astype(q.dtype)
