"""Public attention op: pallas on TPU, jnp reference elsewhere.

The CPU fallback keeps the 512-host-device dry-run lowerable (Pallas TPU
kernels only lower for TPU targets) while tests exercise the kernel in
``interpret=True``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "q_offset", "kv_len", "window",
                     "force_pallas", "interpret"))
def mha(q, k, v, *, causal=True, scale=None, q_offset=0, kv_len=None,
        window=0, force_pallas=False, interpret=False):
    """Grouped-query flash attention. Shapes: see ref.mha_reference."""
    if force_pallas or _on_tpu():
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset,
            kv_len=kv_len, window=window,
            interpret=interpret or not _on_tpu())
    return mha_reference(q, k, v, causal=causal, scale=scale,
                         q_offset=q_offset, kv_len=kv_len, window=window)
