"""Flash attention as a Pallas TPU kernel.

TPU adaptation (vs the CUDA flash-attention-2 algorithm): the online-softmax
recurrence is kept, but tiling targets VMEM and the MXU — (bq, d) query tiles
resident in VMEM, (bk, d) key/value tiles streamed HBM→VMEM by the Pallas
pipeline, s = q·kᵀ on the 128×128 systolic MXU.  The kv-block loop is the
innermost *sequential* grid dimension; running max / denominator / output
accumulator live in fp32 VMEM scratch across those grid steps (the TPU grid
is executed in order, which replaces the CUDA thread-block-local loop).
GQA is expressed through BlockSpec index maps — no KV head replication in
HBM.  Causal blocks above the diagonal are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, bq, bk, nk, q_offset, kv_len, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile's queries / keys
    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    run = jnp.bool_(True)
    if causal:
        # skip kv tiles entirely above the causal diagonal
        run = ki * bk <= q_offset + qi * bq + bq - 1
    if window:
        run = jnp.logical_and(run, (ki + 1) * bk - 1 >= q_offset + qi * bq - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                      # (bq, d)
        k = k_ref[0].astype(jnp.float32)                      # (bk, d)
        v = v_ref[0].astype(jnp.float32)                      # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq, 1)
        l_prev = l_scr[...]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        m_safe = jnp.where(jnp.isfinite(m_next), m_next, 0.0)
        p = jnp.exp(s - m_safe)                               # -inf rows -> 0
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_safe)                      # m_prev=-inf -> 0
        l_next = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha
        acc_scr[...] = acc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_next
        l_scr[...] = l_next

    @pl.when(ki == nk - 1)
    def _out():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, scale=None, q_offset=0,
                           kv_len=None, window=0, block_q=128, block_k=128,
                           interpret=False):
    """q: (B, Hq, Tq, D);  k, v: (B, Hkv, Tk, D) -> (B, Hq, Tq, D)."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kv_len = Tk if kv_len is None else kv_len

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    # pad sequence dims to tile multiples; padded keys masked via kv_len
    pq, pk = -Tq % bq, -Tk % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Tqp, Tkp = Tq + pq, Tk + pk
    nq, nk = Tqp // bq, Tkp // bk

    qr = q.reshape(B * Hq, Tqp, D)
    kr = k.reshape(B * Hkv, Tkp, D)
    vr = v.reshape(B * Hkv, Tkp, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // Hq) * Hkv + (bh % Hq) // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk,
        q_offset=q_offset, kv_len=kv_len, window=window)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qr, kr, vr)
    out = out.reshape(B, Hq, Tqp, D)
    return out[:, :, :Tq] if pq else out


def vmem_bytes(bq, bk, d, dtype_bytes=2):
    """Static VMEM budget check used by tests and block-size autotuning."""
    tiles = (bq * d + 2 * bk * d) * dtype_bytes        # q, k, v tiles
    scratch = (bq * 1 * 2 + bq * d) * 4                # m, l, acc fp32
    out = bq * d * dtype_bytes
    return 2 * tiles + scratch + out                   # x2: pipeline double-buffer
