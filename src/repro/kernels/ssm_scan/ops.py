"""Public SSD-scan op: pallas on TPU, chunked-jnp reference elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssd_scan_pallas
from repro.kernels.ssm_scan.ref import ssd_chunked_reference


@functools.partial(jax.jit,
                   static_argnames=("chunk", "force_pallas", "interpret"))
def ssd_scan(x, g, s, Bm, Cm, D, *, chunk=64, force_pallas=False,
             interpret=False):
    """Generalized SSD scan: h_t = e^{g_t} h + s_t x_t⊗B_t; y_t = C_t·h_t+D·x."""
    if force_pallas or jax.default_backend() == "tpu":
        return ssd_scan_pallas(
            x, g, s, Bm, Cm, D, chunk=chunk,
            interpret=interpret or jax.default_backend() != "tpu")
    return ssd_chunked_reference(x, g, s, Bm, Cm, D, chunk=chunk)
