from repro.kernels.ssm_scan.ops import ssd_scan  # noqa: F401
