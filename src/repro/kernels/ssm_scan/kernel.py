"""Generalized SSD chunked scan as a Pallas TPU kernel.

Primitive: h_t = exp(g_t)·h_{t-1} + s_t·x_t⊗B_t;  y_t = C_t·h_t + D·x_t.
Serves Mamba2 (g=dt·A, s=dt) and the xLSTM mLSTM matrix memory (g=logσ(f),
s=exp(i), x=v, B=k, C=q) — see ref.py.

GPU Mamba2 uses a warp-specialized chunked-scan (SSD) with inter-chunk state
passed through shared memory.  TPU adaptation: chunks become the innermost
*sequential* grid axis; the (P, N) inter-chunk state lives in fp32 VMEM
scratch across grid steps (the TPU grid is executed in order on one core, so
the carried state needs no cross-block reduction).  Within a chunk all the
work is MXU matmuls on VMEM tiles: (L,N)@(N,L) decay-masked score matrix,
(L,L)@(L,P) intra-chunk output, (P,L)@(L,N) state update — hardware-aligned
when chunk, P, N are multiples of 128/8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, g_ref, s_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                h_scr, *, L, nc, bc_load):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    g = g_ref[0, 0].astype(jnp.float32)          # (L, 1)
    s = s_ref[0, 0].astype(jnp.float32)          # (L, 1)
    Bc = bc_load(b_ref).astype(jnp.float32)      # (L, N)
    Cc = bc_load(c_ref).astype(jnp.float32)      # (L, N)
    d = d_ref[0, 0]                              # scalar skip

    cum = jnp.cumsum(g, axis=0)                  # (L, 1)
    rel = cum - cum.reshape(1, L)                # (L, L): cum_t - cum_s
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(row >= col, jnp.exp(rel), 0.0)

    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    Smat = cb * decay * s.reshape(1, L)
    y = jax.lax.dot_general(Smat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (L, P)
    h = h_scr[...]                                                 # (P, N)
    y += jnp.exp(cum) * jax.lax.dot_general(
        Cc, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y += d * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    w = s * jnp.exp(cum[L - 1] - cum)                              # (L, 1)
    h_scr[...] = jnp.exp(cum[L - 1]) * h + jax.lax.dot_general(
        x * w, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _hout():
        hout_ref[0, 0] = h_scr[...]


def ssd_scan_pallas(x, g, s, Bm, Cm, D, *, chunk=64, interpret=False):
    """x: (B,T,H,P); g, s: (B,T,H); Bm, Cm: (B,T,N) shared across heads
    (Mamba2 ngroups=1) or (B,T,H,N) per-head (mLSTM k/q); D: (H,).

    Returns y: (B,T,H,P), h_final: (B,H,P,N) fp32.
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    per_head = Bm.ndim == 4
    L = min(chunk, T)
    pad = -T % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))   # g=0,s=0 -> no-op steps
        s = jnp.pad(s, ((0, 0), (0, pad), (0, 0)))
        bc_pad = ((0, 0), (0, pad), (0, 0), (0, 0)) if per_head else \
            ((0, 0), (0, pad), (0, 0))
        Bm = jnp.pad(Bm, bc_pad)
        Cm = jnp.pad(Cm, bc_pad)
    Tp = T + pad
    nc = Tp // L

    xr = jnp.moveaxis(x, 2, 1)                      # (B, H, Tp, P)
    gr = jnp.moveaxis(g, 2, 1)[..., None]           # (B, H, Tp, 1)
    sr = jnp.moveaxis(s, 2, 1)[..., None]
    d2 = D.astype(jnp.float32).reshape(H, 1)

    if per_head:
        Bm = jnp.moveaxis(Bm, 2, 1)                 # (B, H, Tp, N)
        Cm = jnp.moveaxis(Cm, 2, 1)
        bc_spec = pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0))

        def _bc_load(ref):
            return ref[0, 0]
    else:
        bc_spec = pl.BlockSpec((1, L, N), lambda b, h, c: (b, c, 0))

        def _bc_load(ref):
            return ref[0]

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, L=L, nc=nc, bc_load=_bc_load),
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            bc_spec,
            bc_spec,
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Tp, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(xr, gr, sr, Bm, Cm, d2)
    y = jnp.moveaxis(y, 1, 2)[:, :T]                # (B, T, H, P)
    return y, h


def vmem_bytes(L, P, N, dtype_bytes=2):
    """Static VMEM budget for one grid step (double-buffered tiles)."""
    tiles = (L * P + 2 * L + 2 * L * N) * dtype_bytes
    scratch = P * N * 4
    work = 2 * L * L * 4                       # decay + score matrices
    return 2 * tiles + scratch + work + L * P * dtype_bytes
