"""Oracles for the generalized SSD (state-space-dual) scan.

The primitive recurrence (per batch, head):

    h_t = exp(g_t) * h_{t-1} + s_t * x_t ⊗ B_t        h: (P, N)
    y_t = C_t · h_t + D * x_t                          y: (P,)

with per-step decay-log ``g_t`` and input-scale ``s_t`` decoupled.  Mamba2 is
``g = dt*A, s = dt``; the xLSTM mLSTM matrix memory is ``g = logσ(f),
s = exp(i)`` (with x=v, B=k, C=q) — one kernel serves both architectures.

``ssd_sequential`` is the ground-truth per-timestep recurrence;
``ssd_chunked_reference`` is the chunked reformulation the Pallas kernel
implements; ``ssd_decode_step`` is the O(1) serving update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(x, g, s, Bm, Cm, D):
    """Ground-truth recurrence.

    x: (B, T, H, P); g, s: (B, T, H); Bm, Cm: (B, T, N) shared across heads
    (ngroups=1) or (B, T, H, N) per-head; D: (H,) skip.
    Returns y: (B, T, H, P), h_final: (B, H, P, N) fp32.
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    if Bm.ndim == 3:                       # broadcast shared B/C across heads
        Bm = jnp.broadcast_to(Bm[:, :, None, :], (Bsz, T, H, N))
        Cm = jnp.broadcast_to(Cm[:, :, None, :], (Bsz, T, H, N))

    def step(h, inputs):
        xt, gt, st, bt, ct = inputs
        decay = jnp.exp(gt)                                    # (B, H)
        upd = st[..., None, None] * xt[..., :, None] * bt[:, :, None, :]
        h = h * decay[..., None, None] + upd                   # (B,H,P,N)
        yt = jnp.einsum("bhpn,bhn->bhp", h, ct) + D[None, :, None] * xt
        return h, yt

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32)
               for a in (x, g, s, Bm, Cm))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def _chunk_body(h, args, D_h):
    """One chunk, one head. x (L,P), g/s (L,), B/C (L,N), h (P,N)."""
    x, g, s, Bc, Cc = args
    cum = jnp.cumsum(g)                               # (L,)
    L = x.shape[0]
    rel = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri, jnp.exp(rel), 0.0)
    S = (Cc @ Bc.T) * decay * s[None, :]
    y = S @ x
    y = y + jnp.exp(cum)[:, None] * (Cc @ h.T)
    y = y + D_h * x
    w = s * jnp.exp(cum[-1] - cum)
    h_new = jnp.exp(cum[-1]) * h + (x * w[:, None]).T @ Bc
    return h_new, y


def ssd_chunked_reference(x, g, s, Bm, Cm, D, *, chunk=64):
    """Chunked SSD — the algorithm the Pallas kernel implements."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    per_head = Bm.ndim == 4
    pad = -T % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))    # g=0,s=0 -> no-op steps
        s = jnp.pad(s, ((0, 0), (0, pad), (0, 0)))
        bc_pad = ((0, 0), (0, pad), (0, 0), (0, 0)) if per_head else \
            ((0, 0), (0, pad), (0, 0))
        Bm = jnp.pad(Bm, bc_pad)
        Cm = jnp.pad(Cm, bc_pad)
    Tp = T + pad
    nc = Tp // chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    gf = g.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    sf = s.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    bc_shape = (Bsz, nc, chunk, H, N) if per_head else (Bsz, nc, chunk, N)
    Bf = Bm.astype(jnp.float32).reshape(bc_shape)
    Cf = Cm.astype(jnp.float32).reshape(bc_shape)

    def per_bh(xb, gb, sb, Bb, Cb, D_h):
        def body(h, args):
            return _chunk_body(h, args, D_h)
        h0 = jnp.zeros((xb.shape[-1], Bb.shape[-1]), jnp.float32)
        h, ys = jax.lax.scan(body, h0, (xb, gb, sb, Bb, Cb))
        return ys, h

    # vmap heads then batch (inside the outer vmap, dim 0 is gone: head ax 2)
    bc_ax = 2 if per_head else None
    f = jax.vmap(per_bh, in_axes=(2, 2, 2, bc_ax, bc_ax, 0), out_axes=(1, 0))
    f = jax.vmap(f, in_axes=(0, 0, 0, 0, 0, None), out_axes=(0, 0))
    ys, h = f(xf, gf, sf, Bf, Cf, D.astype(jnp.float32))
    ys = jnp.moveaxis(ys, 2, 3).reshape(Bsz, Tp, H, P)[:, :T]
    return ys.astype(x.dtype), h


def ssd_decode_step(h, x, g, s, Bm, Cm, D):
    """O(1) decode update. h: (B,H,P,N); x: (B,H,P); g, s: (B,H);
    Bm, Cm: (B,N) shared or (B,H,N) per-head.  Returns (y: (B,H,P), h_new)."""
    if Bm.ndim == 2:
        Bm = Bm[:, None, :]
        Cm = Cm[:, None, :]
    decay = jnp.exp(g)
    upd = s[..., None, None] * x[..., :, None] * Bm[..., None, :]
    h = h * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", h.astype(jnp.float32),
                   jnp.broadcast_to(Cm, h.shape[:2] + Cm.shape[-1:]).astype(
                       jnp.float32)) + D[None, :, None] * x
    return y.astype(x.dtype), h
