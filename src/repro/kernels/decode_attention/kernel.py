"""Single-token decode attention as a Pallas TPU kernel.

Decode is memory-bound: the whole KV cache streams HBM→VMEM once per step
while compute is a rank-1 matmul per head.  TPU adaptation: instead of the
GPU "split-KV + cross-SM reduction" scheme (flash-decoding), we make the KV
sequence the innermost *sequential* grid axis — the Pallas pipeline
double-buffers (bs, d) cache tiles while online-softmax state for the
``group`` query heads that share a KV head lives in VMEM scratch.  Queries
are tiled (group, d) so the per-KV-head GQA bundle is one MXU matmul;
per-sequence cache lengths arrive as a VMEM scalar tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bs, ns):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]
    run = si * bs < length                      # skip tiles past the cache end

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                       # (group, d)
        k = k_ref[0].astype(jnp.float32)                       # (bs, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_next), m_next, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.exp(m_prev - m_safe)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_next

    @pl.when(si == ns - 1)
    def _out():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *, scale=None,
                            block_s=256, interpret=False):
    """q: (B, Hq, D); caches: (B, Hkv, S, D); lengths: (B,) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    bs = min(block_s, S)
    ps = -S % bs
    if ps:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, ps), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, ps), (0, 0)))
    Sp = S + ps
    ns = Sp // bs

    # one (group, d) query tile per (batch, kv head)
    qr = q.reshape(B, Hkv, group, D).reshape(B * Hkv, group, D)
    kr = k_cache.reshape(B * Hkv, Sp, D)
    vr = v_cache.reshape(B * Hkv, Sp, D)
    lens = jnp.broadcast_to(lengths[:, None, None], (B, Hkv, 1)).reshape(
        B * Hkv, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs, ns=ns)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, si: (bh, 0)),       # lengths
            pl.BlockSpec((1, group, D), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, si: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, D), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(lens, qr, kr, vr)
    return out.reshape(B, Hq, D)
