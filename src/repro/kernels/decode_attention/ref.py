"""Pure-jnp oracle for single-token decode attention over a padded KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def decode_mha_reference(q, k_cache, v_cache, lengths, *, scale=None):
    """One decode step of GQA attention.

    q: (B, Hq, D) — the new token's queries.
    k_cache, v_cache: (B, Hkv, S, D) — padded caches.
    lengths: (B,) int32 — number of valid cache entries per sequence
             (includes the just-written current token).
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, kf)                  # (B,Hkv,g,S)
    mask = jnp.arange(S)[None, :] < lengths[:, None]           # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
    return o.reshape(B, Hq, D).astype(q.dtype)
