"""Public decode-attention op: pallas on TPU, jnp reference elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_mha_reference


@functools.partial(jax.jit,
                   static_argnames=("scale", "force_pallas", "interpret"))
def decode_mha(q, k_cache, v_cache, lengths, *, scale=None,
               force_pallas=False, interpret=False):
    if force_pallas or jax.default_backend() == "tpu":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale,
            interpret=interpret or jax.default_backend() != "tpu")
    return decode_mha_reference(q, k_cache, v_cache, lengths, scale=scale)
