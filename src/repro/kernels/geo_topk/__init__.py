from repro.kernels.geo_topk.ops import (GeoTopKInputs, geo_topk,  # noqa: F401
                                        pack_inputs)
