from repro.kernels.geo_topk.ops import (GeoTopKInputs, geo_topk,  # noqa: F401
                                        pack_inputs, pack_node_inputs,
                                        pack_user_inputs)
from repro.kernels.geo_topk import tune  # noqa: F401
