"""(block_u, node_tile) autotuner for the geo_topk kernels.

The single-tile kernel shipped with a fixed ``block_u=128`` and an
all-nodes-in-VMEM layout; past the VMEM wall the node-tiled variant
opens a second axis.  This module sweeps both per backend — the same
scheme the attention kernels use for their block sizes — and caches the
winner so ``ops.geo_topk`` picks it up transparently:

* ``candidate_configs(u, n, k)`` enumerates ``(block_u, node_tile)``
  pairs whose static VMEM budget fits (``node_tile=None`` means the
  untiled kernel, admissible only while ``vmem_bytes`` fits);
* ``autotune(u, n, k)`` times each config on synthetic inputs shaped
  like the query, stores the best per ``(backend, bucket(u), bucket(n),
  k)`` and returns the full timing table;
* ``get_config(u, n, k)`` serves the cached winner, falling back to a
  VMEM-safe heuristic when nothing was tuned;
* ``save_cache`` / ``load_cache`` persist winners as JSON (e.g. under
  ``artifacts/autotune/``) so a tuned deployment skips the sweep.

``benchmarks/bench_autotune.py`` drives the sweep; its ``--smoke``
profile (tiny shapes, ``interpret=True``) runs in tier-1 so the whole
path stays exercised without a TPU.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.kernels.geo_topk.kernel import (geo_topk_pallas,
                                           geo_topk_tiled_pallas, vmem_bytes,
                                           vmem_bytes_tiled)

# half a v5e VMEM — the budget the kernel tests pin
VMEM_BUDGET = 64 * 2**20

BLOCK_U_CANDIDATES = (64, 128, 256)
NODE_TILE_CANDIDATES = (512, 1024, 2048, 4096, 8192)

Config = Tuple[int, Optional[int]]          # (block_u, node_tile|None)

_CACHE: Dict[Tuple, Config] = {}


def _bucket(x: int) -> int:
    """Next power of two — tuning transfers across nearby shapes."""
    b = 1
    while b < x:
        b *= 2
    return b


def _backend() -> str:
    return jax.default_backend()


def cache_key(u: int, n: int, k: int) -> Tuple:
    return (_backend(), _bucket(u), _bucket(n), k)


def candidate_configs(u: int, n: int, k: int,
                      *, budget: int = VMEM_BUDGET) -> List[Config]:
    """VMEM-admissible (block_u, node_tile) pairs for a (U, N, k) query."""
    out: List[Config] = []
    for bu in BLOCK_U_CANDIDATES:
        if bu > max(8, _bucket(u)):
            continue
        if vmem_bytes(bu, n, k) < budget:
            out.append((bu, None))
        for nt in NODE_TILE_CANDIDATES:
            if nt >= n or nt < k:
                continue                 # tiling only pays below N
            if vmem_bytes_tiled(bu, nt, k) < budget:
                out.append((bu, nt))
    if not out:                          # degenerate shapes: smallest tile
        out.append((min(BLOCK_U_CANDIDATES), min(NODE_TILE_CANDIDATES)))
    return out


def default_config(u: int, n: int, k: int) -> Config:
    """Heuristic used when nothing was tuned: untiled while it fits the
    VMEM budget, else the largest admissible node tile."""
    if vmem_bytes(128, n, k) < VMEM_BUDGET:
        return (128, None)
    for nt in reversed(NODE_TILE_CANDIDATES):
        if vmem_bytes_tiled(128, nt, k) < VMEM_BUDGET:
            return (128, nt)
    return (64, NODE_TILE_CANDIDATES[0])


def get_config(u: int, n: int, k: int) -> Config:
    """Cached winner for the shape bucket, re-checked against THIS
    query's VMEM budget (a winner tuned at the small end of a bucket may
    not be admissible at the large end), else the heuristic default."""
    cfg = _CACHE.get(cache_key(u, n, k))
    if cfg is not None:
        bu, nt = cfg
        fits = vmem_bytes(bu, n, k) < VMEM_BUDGET if nt is None \
            else vmem_bytes_tiled(bu, nt, k) < VMEM_BUDGET
        if fits:
            return cfg
    return default_config(u, n, k)


def _synthetic_inputs(u: int, n: int, seed: int = 0):
    from repro.core import geohash
    from repro.kernels.geo_topk.ops import pack_inputs
    rng = np.random.default_rng(seed)
    base = (44.97, -93.22)
    ulat = base[0] + rng.uniform(-0.5, 0.5, u)
    ulon = base[1] + rng.uniform(-0.5, 0.5, u)
    nlat = base[0] + rng.uniform(-0.5, 0.5, n)
    nlon = base[1] + rng.uniform(-0.5, 0.5, n)
    return pack_inputs(
        ulat, ulon, rng.integers(0, 3, u),
        geohash.encode_batch(ulat, ulon, 9),
        nlat, nlon, rng.uniform(0, 1, n), rng.integers(0, 3, n),
        geohash.encode_batch(nlat, nlon, 9))


def _run_config(packed, cfg: Config, k: int, need: int, interpret: bool):
    bu, nt = cfg
    if nt is None:
        return geo_topk_pallas(*packed, k=k, need=need, block_u=bu,
                               interpret=interpret)
    return geo_topk_tiled_pallas(*packed, k=k, need=need, block_u=bu,
                                 node_tile=nt, interpret=interpret)


def autotune(u: int, n: int, k: int = 8, *, need: int = 4,
             configs: Optional[List[Config]] = None, repeats: int = 3,
             interpret: bool = False, seed: int = 0) -> Dict:
    """Time every admissible config on a synthetic (U, N, k) query and
    cache the winner for this backend.  Returns ``{"best": config,
    "timings_ms": {config: best-of-repeats}}``.

    ``interpret=True`` runs the kernels through the Pallas interpreter —
    functional end-to-end on CPU (the tier-1 smoke path), with timings
    that only rank Python-level work.
    """
    packed = _synthetic_inputs(u, n, seed=seed)
    configs = candidate_configs(u, n, k) if configs is None else configs
    timings: Dict[Config, float] = {}
    for cfg in configs:
        try:
            s, i = _run_config(packed, cfg, k, need, interpret)
            s.block_until_ready()            # compile outside the clock
        except Exception:                    # config unsupported on backend
            continue
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            s, i = _run_config(packed, cfg, k, need, interpret)
            s.block_until_ready()
            best = min(best, (time.perf_counter() - t0) * 1e3)
        timings[cfg] = best
    if not timings:
        raise RuntimeError(f"no geo_topk config ran for U={u} N={n} k={k}")
    winner = min(timings, key=timings.get)
    _CACHE[cache_key(u, n, k)] = winner
    return {"best": winner, "timings_ms": timings}


# ----------------------------------------------------------- persistence

def save_cache(path) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    rows = [{"key": list(key), "block_u": cfg[0], "node_tile": cfg[1]}
            for key, cfg in _CACHE.items()]
    path.write_text(json.dumps(rows, indent=1))


def load_cache(path) -> int:
    """Merge winners from ``save_cache`` output; returns entries loaded."""
    rows = json.loads(pathlib.Path(path).read_text())
    for r in rows:
        _CACHE[tuple(r["key"])] = (r["block_u"], r["node_tile"])
    return len(rows)


def clear_cache() -> None:
    _CACHE.clear()
