"""Pure-jnp oracle for fused geo-selection top-k (paper Algorithm 1).

Scores every (user, replica) pair in one fused pass:

    score = W_RESOURCE * free + W_AFFINITY * aff + W_PROXIMITY * prox
    prox  = 1 / (1 + haversine_km / 10)

after the paper's adaptive-precision geohash proximity filter: for
p = 4..1, keep replicas whose first ``p`` geohash chars match the user's;
the first ``p`` with >= min(4, N) hits wins, else no filter.  Geohash
prefixes are compared on 20-bit Morton codes (the first 4 base32 chars of
``repro.core.geohash.encode_batch`` codes), which keeps every integer op
inside int32 — TPU-native.

Inputs are packed by ``repro.kernels.geo_topk.ops.pack_inputs``; scores
are fp32 (coordinates at city scale lose < 1 m to fp32, far below the
scoring resolution).  Masked-out pairs score ``NEG``.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# single source of truth for the Algorithm-1 constants lives with the
# engine; the kernel must score exactly what the numpy path scores
from repro.core.selection import (MIN_PROXIMITY_HITS, W_AFFINITY,
                                  W_PROXIMITY, W_RESOURCE)
from repro.core.selection import PROXIMITY_PRECISION as PREFIX_CHARS

EARTH_KM = 6371.0
NEG = -1e30


def haversine_km(ulat, ulon, nlat, nlon):
    """Broadcasted fp32 haversine: (U, 1) x (1, N) -> (U, N)."""
    rad = jnp.float32(jnp.pi / 180.0)
    p1 = ulat * rad
    p2 = nlat * rad
    dp = (nlat - ulat) * rad
    dl = (nlon - ulon) * rad
    a = (jnp.sin(dp / 2) ** 2
         + jnp.cos(p1) * jnp.cos(p2) * jnp.sin(dl / 2) ** 2)
    return 2.0 * EARTH_KM * jnp.arcsin(jnp.sqrt(jnp.clip(a, 0.0, 1.0)))


def _raw_scores(user_lat, user_lon, user_net, node_lat, node_lon,
                node_free, node_aff):
    """Unfiltered (U, N) fp32 Algorithm-1 scores.  Single source for the
    scoring arithmetic — the sharded/unsharded decision-parity proof
    rests on both filters seeing bit-identical scores."""
    d = haversine_km(user_lat[:, None], user_lon[:, None],
                     node_lat[None, :], node_lon[None, :])
    prox = 1.0 / (1.0 + d / 10.0)
    m = node_aff.shape[0]
    onehot = (user_net[:, None]
              == lax.broadcasted_iota(jnp.int32, (user_net.shape[0], m), 1)
              ).astype(jnp.float32)
    aff = onehot @ node_aff                            # (U, N)
    return (W_RESOURCE * node_free[None, :] + W_AFFINITY * aff
            + W_PROXIMITY * prox)


def proximity_mask(user_code20, node_code20, node_valid, need: int):
    """(U, N) bool: the adaptive-precision prefix filter over valid
    nodes — the restricted filter down to p=1, with unsatisfied rows
    falling back to no filter (every valid node)."""
    local, done = proximity_mask_restricted(user_code20, node_code20,
                                            node_valid, need, 1)
    valid = node_valid[None, :] > 0
    return jnp.where(done[:, None], local, valid)


def proximity_mask_restricted(user_code20, node_code20, node_valid,
                              need: int, p_min: int):
    """Shard-local adaptive filter: precisions restricted to
    ``p >= p_min`` (the shard's own prefix length), NO global fallback.
    Returns ``(mask, satisfied)`` — unsatisfied rows stay all-False and
    must escalate to the cross-shard border pass.  Because geohash cells
    nest, a satisfied row's level and mask equal the unrestricted
    ``proximity_mask`` computed over the full node set."""
    valid = node_valid[None, :] > 0
    u = user_code20.shape[0]
    local = jnp.zeros((u, node_code20.shape[0]), bool)
    done = jnp.zeros(u, bool)
    for p in range(PREFIX_CHARS, p_min - 1, -1):
        shift = 5 * (PREFIX_CHARS - p)
        eq = ((user_code20[:, None] >> shift)
              == (node_code20[None, :] >> shift)) & valid
        use = (eq.sum(axis=1) >= need) & ~done
        local = jnp.where(use[:, None], eq, local)
        done = done | use
    return local, done


def score_matrix_restricted(user_lat, user_lon, user_net, user_code20,
                            node_lat, node_lon, node_free, node_aff,
                            node_code20, node_valid, need: int, p_min: int):
    """(U, N) fp32 shard-local scores plus the (U,) satisfied mask.
    Scores are elementwise-identical to ``score_matrix`` over the same
    (user, node) pairs; unsatisfied rows are all ``NEG``."""
    scores = _raw_scores(user_lat, user_lon, user_net, node_lat, node_lon,
                         node_free, node_aff)
    local, sat = proximity_mask_restricted(user_code20, node_code20,
                                           node_valid, need, p_min)
    return jnp.where(local, scores, jnp.float32(NEG)), sat


def score_matrix(user_lat, user_lon, user_net, user_code20,
                 node_lat, node_lon, node_free, node_aff, node_code20,
                 node_valid, need: int):
    """(U, N) fp32 scores with filtered/invalid pairs at ``NEG``."""
    scores = _raw_scores(user_lat, user_lon, user_net, node_lat, node_lon,
                         node_free, node_aff)
    local = proximity_mask(user_code20, node_code20, node_valid, need)
    return jnp.where(local, scores, jnp.float32(NEG))


def geo_topk_reference(user_lat, user_lon, user_net, user_code20,
                       node_lat, node_lon, node_free, node_aff,
                       node_code20, node_valid, *, k: int, need: int):
    """-> (scores (U, k), indices (U, k)): per-user top-k replicas."""
    scores = score_matrix(user_lat, user_lon, user_net, user_code20,
                          node_lat, node_lon, node_free, node_aff,
                          node_code20, node_valid, need)
    return lax.top_k(scores, k)
