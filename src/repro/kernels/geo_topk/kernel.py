"""Fused geo-selection top-k as a Pallas TPU kernel.

One grid step scores a (BU,)-user tile against a node tile:

* haversine + 1/(1+d/10) proximity on the VPU (fp32 elementwise over the
  (BU, N) tile);
* net affinity as a (BU, M) one-hot x (M, N) affinity-column matmul on
  the MXU (M = net types padded to 8, so the K dim is tile-aligned);
* the paper's adaptive-precision geohash filter on 20-bit Morton codes —
  int32 compares + row reductions, no int64 on the TPU;
* iterative max-extract top-k (k is static and small, the loop unrolls);
  ties pick the lowest index, matching ``jax.lax.top_k``.

Two layouts share the scoring math:

* ``geo_topk_pallas`` — 1-D grid over user tiles, ALL nodes broadcast to
  each step.  The (BU, N) working set stays in VMEM (BU=128 x N=4096
  fp32 is 2 MB/matrix — see ``vmem_bytes``), which caps it at N ≲ 16k.
* ``geo_topk_tiled_pallas`` — 2-D grid (user tiles x node tiles): node
  blocks of ``node_tile`` stream HBM→VMEM while a running top-k carry
  (scores + global indices) lives in fp32/int32 scratch across the
  sequential node dimension, merged by the same min-index-tie extraction.
  The adaptive prefix filter needs *global* per-precision hit counts, so
  a first 2-D pass (``_prefix_count_kernel``) accumulates them and the
  per-user precision choice is made between the two ``pallas_call``s.
  VMEM is ``vmem_bytes_tiled(block_u, node_tile)`` — independent of N,
  which lifts the all-nodes-in-VMEM limit to 100k+ nodes.

``repro.kernels.geo_topk.tune`` sweeps (block_u, node_tile) per backend
and caches the winner; ``ops.geo_topk`` consults that cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.geo_topk.ref import (NEG, PREFIX_CHARS, W_AFFINITY,
                                        W_PROXIMITY, W_RESOURCE,
                                        haversine_km)


def _geo_topk_kernel(ulat_ref, ulon_ref, unet_ref, ucode_ref,
                     nlat_ref, nlon_ref, nfree_ref, naff_ref, ncode_ref,
                     nvalid_ref, scores_ref, idx_ref, *, k, need, np_):
    ulat = ulat_ref[:, 0:1]                       # (BU, 1)
    ulon = ulon_ref[:, 0:1]
    unet = unet_ref[:, 0:1]                       # (BU, 1) int32
    ucode = ucode_ref[:, 0:1]                     # (BU, 1) int32
    nlat = nlat_ref[0:1, :]                       # (1, N)
    nlon = nlon_ref[0:1, :]
    nfree = nfree_ref[0:1, :]
    ncode = ncode_ref[0:1, :]                     # (1, N) int32
    valid = nvalid_ref[0:1, :] > 0                # (1, N)

    bu = ulat.shape[0]

    # ---- proximity term (VPU, fp32): shares the oracle's exact formula
    d = haversine_km(ulat, ulon, nlat, nlon)      # (BU,1) x (1,N)
    prox = 1.0 / (1.0 + d / 10.0)                 # (BU, N)

    # ---- affinity term (MXU): one-hot(users) @ per-node affinity columns
    m = naff_ref.shape[0]
    onehot = (unet == jax.lax.broadcasted_iota(jnp.int32, (bu, m), 1)
              ).astype(jnp.float32)
    aff = jax.lax.dot_general(onehot, naff_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    scores = W_RESOURCE * nfree + W_AFFINITY * aff + W_PROXIMITY * prox

    # ---- adaptive-precision geohash filter (int32 prefix compares)
    local = jnp.broadcast_to(valid, (bu, valid.shape[1]))
    done = jnp.zeros((bu, 1), bool)
    for p in range(PREFIX_CHARS, 0, -1):
        shift = 5 * (PREFIX_CHARS - p)
        eq = ((ucode >> shift) == (ncode >> shift)) & valid
        use = (jnp.sum(eq.astype(jnp.int32), axis=1, keepdims=True)
               >= need) & ~done
        local = jnp.where(use, eq, local)
        done = done | use
    scores = jnp.where(local, scores, jnp.float32(NEG))

    # ---- top-k by repeated max extraction (ties -> lowest index)
    iota = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    top_s, top_i = [], []
    for _ in range(k):
        best = jnp.max(scores, axis=1, keepdims=True)           # (BU, 1)
        at = jnp.where(scores >= best, iota, np_)
        ix = jnp.min(at, axis=1, keepdims=True)                 # (BU, 1)
        top_s.append(best)
        top_i.append(ix)
        scores = jnp.where(iota == ix, jnp.float32(NEG * 2), scores)
    scores_ref[...] = jnp.concatenate(top_s, axis=1)
    idx_ref[...] = jnp.concatenate(top_i, axis=1)


def _pad_query(user_lat, user_lon, user_net, user_code20,
               node_lat, node_lon, node_free, node_aff, node_code20,
               node_valid, pu: int, pn: int):
    """Shared pad/reshape prologue: users -> (U+pu, 1) columns, nodes ->
    (1, N+pn) rows, affinity rows padded to an 8-multiple K dim."""
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    ul = jnp.pad(f32(user_lat), (0, pu)).reshape(-1, 1)
    uo = jnp.pad(f32(user_lon), (0, pu)).reshape(-1, 1)
    un = jnp.pad(i32(user_net), (0, pu)).reshape(-1, 1)
    uc = jnp.pad(i32(user_code20), (0, pu)).reshape(-1, 1)
    nl = jnp.pad(f32(node_lat), (0, pn)).reshape(1, -1)
    no = jnp.pad(f32(node_lon), (0, pn)).reshape(1, -1)
    nf = jnp.pad(f32(node_free), (0, pn)).reshape(1, -1)
    nc = jnp.pad(i32(node_code20), (0, pn)).reshape(1, -1)
    nv = jnp.pad(f32(node_valid), (0, pn)).reshape(1, -1)
    m = node_aff.shape[0]
    pm = -m % 8
    na = jnp.pad(f32(node_aff), ((0, pm), (0, pn)))
    return (ul, uo, un, uc), (nl, no, nf, na, nc, nv), m + pm


def geo_topk_pallas(user_lat, user_lon, user_net, user_code20,
                    node_lat, node_lon, node_free, node_aff, node_code20,
                    node_valid, *, k: int, need: int, block_u: int = 128,
                    interpret: bool = False):
    """-> (scores (U, k) fp32, indices (U, k) int32).

    Users: (U,) fp32 lat/lon, int32 net index + 20-bit Morton code.
    Nodes: (N,) fp32 lat/lon/free/valid, int32 codes, (M, N) affinity
    columns.  Pads U to ``block_u`` and N to a lane multiple internally.
    """
    u = user_lat.shape[0]
    n = node_lat.shape[0]
    bu = min(block_u, max(8, u))
    pu = -u % bu
    pn = -n % 128
    (ul, uo, un, uc), (nl, no, nf, na, nc, nv), mp = _pad_query(
        user_lat, user_lon, user_net, user_code20, node_lat, node_lon,
        node_free, node_aff, node_code20, node_valid, pu, pn)

    up, np_ = u + pu, n + pn
    grid = (up // bu,)
    user_spec = pl.BlockSpec((bu, 1), lambda i: (i, 0))
    node_spec = pl.BlockSpec((1, np_), lambda i: (0, 0))

    scores, idx = pl.pallas_call(
        functools.partial(_geo_topk_kernel, k=k, need=need, np_=np_),
        grid=grid,
        in_specs=[user_spec, user_spec, user_spec, user_spec,
                  node_spec, node_spec, node_spec,
                  pl.BlockSpec((mp, np_), lambda i: (0, 0)),
                  node_spec, node_spec],
        out_specs=[pl.BlockSpec((bu, k), lambda i: (i, 0)),
                   pl.BlockSpec((bu, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((up, k), jnp.float32),
                   jax.ShapeDtypeStruct((up, k), jnp.int32)],
        interpret=interpret,
    )(ul, uo, un, uc, nl, no, nf, na, nc, nv)
    return scores[:u], idx[:u]


def vmem_bytes(block_u: int, n: int, k: int = 8, m: int = 8) -> int:
    """Static VMEM budget for one grid step (fp32 everywhere)."""
    user_tiles = 4 * block_u * 4
    node_tiles = (5 + m) * n * 4
    work = 5 * block_u * n * 4            # d/prox/aff/scores/local+iota
    out = 2 * block_u * k * 4
    return 2 * (user_tiles + node_tiles + out) + work


# ---------------------------------------------------------------------------
# node-tiled variant: streams node blocks with a running top-k merge
# ---------------------------------------------------------------------------

# shift amounts of the adaptive filter, finest precision first (p = 4..1)
_SHIFTS = tuple(5 * (PREFIX_CHARS - p) for p in range(PREFIX_CHARS, 0, -1))
_NO_FILTER_SHIFT = 5 * PREFIX_CHARS      # 20-bit codes >> 20 == 0: all pass
_COUNT_LANES = 128                       # count columns padded to one lane
_IDX_SENTINEL = 2**31 - 1


def _prefix_count_kernel(ucode_ref, ncode_ref, nvalid_ref, counts_ref):
    """Accumulate per-user hit counts for every filter precision across
    node tiles: counts[:, i] = #valid nodes matching the user's first
    ``PREFIX_CHARS - i`` geohash chars (columns beyond len(_SHIFTS) stay
    zero — lane padding)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    ucode = ucode_ref[:, 0:1]                     # (BU, 1)
    ncode = ncode_ref[0:1, :]                     # (1, BN)
    valid = nvalid_ref[0:1, :] > 0
    cols = [jnp.sum((((ucode >> s) == (ncode >> s)) & valid)
                    .astype(jnp.int32), axis=1, keepdims=True)
            for s in _SHIFTS]
    bu = ucode.shape[0]
    pad = jnp.zeros((bu, _COUNT_LANES - len(cols)), jnp.int32)
    counts_ref[...] += jnp.concatenate(cols + [pad], axis=1)


def _geo_topk_tiled_kernel(ulat_ref, ulon_ref, unet_ref, ucode_ref,
                           ushift_ref, nlat_ref, nlon_ref, nfree_ref,
                           naff_ref, ncode_ref, nvalid_ref,
                           scores_ref, idx_ref, s_scr, i_scr, *, k, bn, nj):
    """One (user tile, node tile) step: score the tile, merge into the
    running top-k carry held in scratch across the node grid dimension."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.full_like(s_scr, jnp.float32(NEG))
        i_scr[...] = jnp.full_like(i_scr, _IDX_SENTINEL)

    ulat = ulat_ref[:, 0:1]
    ulon = ulon_ref[:, 0:1]
    unet = unet_ref[:, 0:1]
    ucode = ucode_ref[:, 0:1]
    ushift = ushift_ref[:, 0:1]                   # (BU, 1) int32
    nlat = nlat_ref[0:1, :]
    nlon = nlon_ref[0:1, :]
    nfree = nfree_ref[0:1, :]
    ncode = ncode_ref[0:1, :]
    valid = nvalid_ref[0:1, :] > 0
    bu = ulat.shape[0]

    d = haversine_km(ulat, ulon, nlat, nlon)
    prox = 1.0 / (1.0 + d / 10.0)
    m = naff_ref.shape[0]
    onehot = (unet == jax.lax.broadcasted_iota(jnp.int32, (bu, m), 1)
              ).astype(jnp.float32)
    aff = jax.lax.dot_general(onehot, naff_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    scores = W_RESOURCE * nfree + W_AFFINITY * aff + W_PROXIMITY * prox

    # per-user precision chosen from the global count pass; shift == 20
    # (no filter) degenerates to 0 == 0, keeping every valid node
    local = ((ucode >> ushift) == (ncode >> ushift)) & valid
    scores = jnp.where(local, scores, jnp.float32(NEG))

    # running top-k merge: carry columns keep their global indices, tile
    # columns get theirs from the node-grid position; min-index tie rule
    # matches jax.lax.top_k across tile boundaries because earlier tiles
    # always carry smaller global indices
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bu, bn), 1)
    full_s = jnp.concatenate([s_scr[...], scores], axis=1)
    full_i = jnp.concatenate([i_scr[...], gidx], axis=1)
    top_s, top_i = [], []
    for _ in range(k):
        best = jnp.max(full_s, axis=1, keepdims=True)
        at = jnp.where(full_s >= best, full_i, _IDX_SENTINEL)
        ix = jnp.min(at, axis=1, keepdims=True)
        top_s.append(best)
        top_i.append(ix)
        full_s = jnp.where(full_i == ix, jnp.float32(NEG * 2), full_s)
    s_scr[...] = jnp.concatenate(top_s, axis=1)
    i_scr[...] = jnp.concatenate(top_i, axis=1)

    @pl.when(j == nj - 1)
    def _out():
        scores_ref[...] = s_scr[...]
        idx_ref[...] = i_scr[...]


def geo_topk_tiled_pallas(user_lat, user_lon, user_net, user_code20,
                          node_lat, node_lon, node_free, node_aff,
                          node_code20, node_valid, *, k: int, need: int,
                          block_u: int = 128, node_tile: int = 2048,
                          interpret: bool = False):
    """Node-streaming ``geo_topk_pallas``: same results, VMEM independent
    of N (see module docstring).  ``node_tile`` must hold at least ``k``
    entries so every merge sees enough real candidates."""
    from jax.experimental.pallas import tpu as pltpu

    u = user_lat.shape[0]
    n = node_lat.shape[0]
    bu = min(block_u, max(8, u))
    bn = max(128, -(-node_tile // 128) * 128)
    if bn < k:
        raise ValueError(f"node_tile {bn} < k {k}")
    pu = -u % bu
    pn = -n % bn
    (ul, uo, un, uc), (nl, no, nf, na, nc, nv), mp = _pad_query(
        user_lat, user_lon, user_net, user_code20, node_lat, node_lon,
        node_free, node_aff, node_code20, node_valid, pu, pn)

    up, np_ = u + pu, n + pn
    ui, nj = up // bu, np_ // bn
    grid = (ui, nj)
    user_spec = pl.BlockSpec((bu, 1), lambda i, j: (i, 0))
    node_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((bu, k), lambda i, j: (i, 0))
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    # pass 1: global per-precision hit counts (the adaptive filter decides
    # on totals over ALL nodes, which no single tile can see)
    counts = pl.pallas_call(
        _prefix_count_kernel,
        grid=grid,
        in_specs=[user_spec, node_spec, node_spec],
        out_specs=pl.BlockSpec((bu, _COUNT_LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((up, _COUNT_LANES), jnp.int32),
        interpret=interpret,
        **kwargs,
    )(uc, nc, nv)

    # choose the finest precision with enough hits (reference scan order:
    # p = 4..1, first match wins, else no filter)
    shift = jnp.full((up, 1), _NO_FILTER_SHIFT, jnp.int32)
    for i in range(len(_SHIFTS) - 1, -1, -1):
        shift = jnp.where(counts[:, i:i + 1] >= need, _SHIFTS[i], shift)

    # pass 2: scoring + running top-k over streamed node tiles
    scores, idx = pl.pallas_call(
        functools.partial(_geo_topk_tiled_kernel, k=k, bn=bn, nj=nj),
        grid=grid,
        in_specs=[user_spec, user_spec, user_spec, user_spec, user_spec,
                  node_spec, node_spec, node_spec,
                  pl.BlockSpec((mp, bn), lambda i, j: (0, j)),
                  node_spec, node_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((up, k), jnp.float32),
                   jax.ShapeDtypeStruct((up, k), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bu, k), jnp.float32),
                        pltpu.VMEM((bu, k), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(ul, uo, un, uc, shift, nl, no, nf, na, nc, nv)
    return scores[:u], idx[:u]


def vmem_bytes_tiled(block_u: int, node_tile: int, k: int = 8,
                     m: int = 8) -> int:
    """Static VMEM budget for one tiled grid step — independent of N."""
    user_tiles = 5 * block_u * 4
    node_tiles = (5 + m) * node_tile * 4
    work = 5 * block_u * node_tile * 4
    carry = 2 * block_u * k * 4            # running top-k scratch
    out = 2 * block_u * k * 4
    return 2 * (user_tiles + node_tiles + out) + work + carry
