"""Fused geo-selection top-k as a Pallas TPU kernel.

One grid step scores a (BU,)-user tile against the full replica set:

* haversine + 1/(1+d/10) proximity on the VPU (fp32 elementwise over the
  (BU, N) tile);
* net affinity as a (BU, M) one-hot x (M, N) affinity-column matmul on
  the MXU (M = net types padded to 8, so the K dim is tile-aligned);
* the paper's adaptive-precision geohash filter on 20-bit Morton codes —
  int32 compares + row reductions, no int64 on the TPU;
* iterative max-extract top-k (k is static and small, the loop unrolls);
  ties pick the lowest index, matching ``jax.lax.top_k``.

Users are embarrassingly parallel, so the grid is 1-D over user tiles and
every node array is broadcast to each step.  The whole (BU, N) working
set stays in VMEM: BU=128 x N=4096 fp32 is 2 MB/matrix — see
``vmem_bytes``.  N beyond ~16k nodes needs a node-tiled variant with a
running top-k merge (ROADMAP: sharded selection across Beacon replicas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.geo_topk.ref import (NEG, PREFIX_CHARS, W_AFFINITY,
                                        W_PROXIMITY, W_RESOURCE,
                                        haversine_km)


def _geo_topk_kernel(ulat_ref, ulon_ref, unet_ref, ucode_ref,
                     nlat_ref, nlon_ref, nfree_ref, naff_ref, ncode_ref,
                     nvalid_ref, scores_ref, idx_ref, *, k, need, np_):
    ulat = ulat_ref[:, 0:1]                       # (BU, 1)
    ulon = ulon_ref[:, 0:1]
    unet = unet_ref[:, 0:1]                       # (BU, 1) int32
    ucode = ucode_ref[:, 0:1]                     # (BU, 1) int32
    nlat = nlat_ref[0:1, :]                       # (1, N)
    nlon = nlon_ref[0:1, :]
    nfree = nfree_ref[0:1, :]
    ncode = ncode_ref[0:1, :]                     # (1, N) int32
    valid = nvalid_ref[0:1, :] > 0                # (1, N)

    bu = ulat.shape[0]

    # ---- proximity term (VPU, fp32): shares the oracle's exact formula
    d = haversine_km(ulat, ulon, nlat, nlon)      # (BU,1) x (1,N)
    prox = 1.0 / (1.0 + d / 10.0)                 # (BU, N)

    # ---- affinity term (MXU): one-hot(users) @ per-node affinity columns
    m = naff_ref.shape[0]
    onehot = (unet == jax.lax.broadcasted_iota(jnp.int32, (bu, m), 1)
              ).astype(jnp.float32)
    aff = jax.lax.dot_general(onehot, naff_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    scores = W_RESOURCE * nfree + W_AFFINITY * aff + W_PROXIMITY * prox

    # ---- adaptive-precision geohash filter (int32 prefix compares)
    local = jnp.broadcast_to(valid, (bu, valid.shape[1]))
    done = jnp.zeros((bu, 1), bool)
    for p in range(PREFIX_CHARS, 0, -1):
        shift = 5 * (PREFIX_CHARS - p)
        eq = ((ucode >> shift) == (ncode >> shift)) & valid
        use = (jnp.sum(eq.astype(jnp.int32), axis=1, keepdims=True)
               >= need) & ~done
        local = jnp.where(use, eq, local)
        done = done | use
    scores = jnp.where(local, scores, jnp.float32(NEG))

    # ---- top-k by repeated max extraction (ties -> lowest index)
    iota = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    top_s, top_i = [], []
    for _ in range(k):
        best = jnp.max(scores, axis=1, keepdims=True)           # (BU, 1)
        at = jnp.where(scores >= best, iota, np_)
        ix = jnp.min(at, axis=1, keepdims=True)                 # (BU, 1)
        top_s.append(best)
        top_i.append(ix)
        scores = jnp.where(iota == ix, jnp.float32(NEG * 2), scores)
    scores_ref[...] = jnp.concatenate(top_s, axis=1)
    idx_ref[...] = jnp.concatenate(top_i, axis=1)


def geo_topk_pallas(user_lat, user_lon, user_net, user_code20,
                    node_lat, node_lon, node_free, node_aff, node_code20,
                    node_valid, *, k: int, need: int, block_u: int = 128,
                    interpret: bool = False):
    """-> (scores (U, k) fp32, indices (U, k) int32).

    Users: (U,) fp32 lat/lon, int32 net index + 20-bit Morton code.
    Nodes: (N,) fp32 lat/lon/free/valid, int32 codes, (M, N) affinity
    columns.  Pads U to ``block_u`` and N to a lane multiple internally.
    """
    u = user_lat.shape[0]
    n = node_lat.shape[0]
    bu = min(block_u, max(8, u))
    pu = -u % bu
    pn = -n % 128

    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    ul = jnp.pad(f32(user_lat), (0, pu)).reshape(-1, 1)
    uo = jnp.pad(f32(user_lon), (0, pu)).reshape(-1, 1)
    un = jnp.pad(i32(user_net), (0, pu)).reshape(-1, 1)
    uc = jnp.pad(i32(user_code20), (0, pu)).reshape(-1, 1)
    nl = jnp.pad(f32(node_lat), (0, pn)).reshape(1, -1)
    no = jnp.pad(f32(node_lon), (0, pn)).reshape(1, -1)
    nf = jnp.pad(f32(node_free), (0, pn)).reshape(1, -1)
    nc = jnp.pad(i32(node_code20), (0, pn)).reshape(1, -1)
    nv = jnp.pad(f32(node_valid), (0, pn)).reshape(1, -1)
    m = node_aff.shape[0]
    pm = -m % 8
    na = jnp.pad(f32(node_aff), ((0, pm), (0, pn)))

    up, np_ = u + pu, n + pn
    grid = (up // bu,)
    user_spec = pl.BlockSpec((bu, 1), lambda i: (i, 0))
    node_spec = pl.BlockSpec((1, np_), lambda i: (0, 0))

    scores, idx = pl.pallas_call(
        functools.partial(_geo_topk_kernel, k=k, need=need, np_=np_),
        grid=grid,
        in_specs=[user_spec, user_spec, user_spec, user_spec,
                  node_spec, node_spec, node_spec,
                  pl.BlockSpec((m + pm, np_), lambda i: (0, 0)),
                  node_spec, node_spec],
        out_specs=[pl.BlockSpec((bu, k), lambda i: (i, 0)),
                   pl.BlockSpec((bu, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((up, k), jnp.float32),
                   jax.ShapeDtypeStruct((up, k), jnp.int32)],
        interpret=interpret,
    )(ul, uo, un, uc, nl, no, nf, na, nc, nv)
    return scores[:u], idx[:u]


def vmem_bytes(block_u: int, n: int, k: int = 8, m: int = 8) -> int:
    """Static VMEM budget for one grid step (fp32 everywhere)."""
    user_tiles = 4 * block_u * 4
    node_tiles = (5 + m) * n * 4
    work = 5 * block_u * n * 4            # d/prox/aff/scores/local+iota
    out = 2 * block_u * k * 4
    return 2 * (user_tiles + node_tiles + out) + work
