"""Public fused geo-selection op: Pallas on TPU, jnp oracle elsewhere.

``pack_inputs`` flattens a (users, replicas) query into the dtype-correct
arrays both backends consume (``pack_user_inputs`` / ``pack_node_inputs``
split the two halves so callers with a static replica set can cache the
node half — see ``SelectionEngine``'s node-epoch cache); ``geo_topk``
dispatches and returns per-user ``(scores, indices)`` top-k.  On TPU the
kernel layout — untiled vs node-tiled — and its ``(block_u, node_tile)``
come from ``repro.kernels.geo_topk.tune``'s per-backend autotune cache.
``geo_topk_shard`` is the region-sharded entry point: one invocation per
shard over that shard's padded layout, filter restricted to the shard
prefix, with a per-user "satisfied" mask so border users can escalate to
a cross-shard pass.  ``SelectionEngine`` in ``repro.core.selection`` maps
indices back to Task objects.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import numpy as np

from repro.core.selection import CODE_PRECISION
from repro.kernels.geo_topk.kernel import (geo_topk_pallas,
                                           geo_topk_tiled_pallas)
from repro.kernels.geo_topk.ref import MIN_PROXIMITY_HITS, geo_topk_reference

PREFIX_SHIFT = 5 * CODE_PRECISION - 20   # keep the top 4 chars = 20 bits


class GeoTopKInputs(NamedTuple):
    user_lat: np.ndarray      # (U,) fp32
    user_lon: np.ndarray      # (U,) fp32
    user_net: np.ndarray      # (U,) int32 net-type index
    user_code20: np.ndarray   # (U,) int32, top-4-char Morton prefix
    node_lat: np.ndarray      # (N,) fp32
    node_lon: np.ndarray      # (N,) fp32
    node_free: np.ndarray     # (N,) fp32 free-slot fraction
    node_aff: np.ndarray      # (M, N) fp32 affinity columns per node
    node_code20: np.ndarray   # (N,) int32
    node_valid: np.ndarray    # (N,) fp32 1.0 = schedulable


def code20(code45) -> np.ndarray:
    """45-bit engine Morton codes -> kernel 20-bit prefixes (int32)."""
    return (np.asarray(code45, np.int64) >> PREFIX_SHIFT).astype(np.int32)


def pack_user_inputs(user_lat, user_lon, user_net, user_code45):
    """User half of a query as kernel-ready arrays."""
    return (np.asarray(user_lat, np.float32),
            np.asarray(user_lon, np.float32),
            np.asarray(user_net, np.int32),
            code20(user_code45))


def pack_node_inputs(node_lat, node_lon, node_free, node_net,
                     node_code45, node_valid=None):
    """Node half of a query.  ``node_valid`` marks schedulable rows
    (1.0); pass zeros for padding rows added to stabilize jit shapes —
    they score ``NEG`` and fall out of the top-k."""
    from repro.core.selection import AFFINITY_TABLE
    node_net = np.asarray(node_net, np.int64)
    if node_valid is None:
        node_valid = np.ones(len(node_lat), np.float32)
    return (np.asarray(node_lat, np.float32),
            np.asarray(node_lon, np.float32),
            np.asarray(node_free, np.float32),
            AFFINITY_TABLE[node_net, :].T.astype(np.float32),
            code20(node_code45),
            np.asarray(node_valid, np.float32))


def pack_inputs(user_lat, user_lon, user_net, user_code45,
                node_lat, node_lon, node_free, node_net,
                node_code45, node_valid=None) -> GeoTopKInputs:
    """45-bit engine codes + net indices -> kernel-ready arrays."""
    return GeoTopKInputs(
        *pack_user_inputs(user_lat, user_lon, user_net, user_code45),
        *pack_node_inputs(node_lat, node_lon, node_free, node_net,
                          node_code45, node_valid))


@functools.partial(jax.jit, static_argnames=("k", "need", "force_pallas",
                                             "interpret", "block_u",
                                             "node_tile"))
def _dispatch(packed: GeoTopKInputs, k: int, need: int, force_pallas: bool,
              interpret: bool, block_u: Optional[int],
              node_tile: Optional[int]):
    if force_pallas or jax.default_backend() == "tpu":
        kw = dict(k=k, need=need,
                  interpret=interpret or jax.default_backend() != "tpu")
        if block_u is not None:
            kw["block_u"] = block_u
        if node_tile is not None:
            return geo_topk_tiled_pallas(*packed, node_tile=node_tile, **kw)
        return geo_topk_pallas(*packed, **kw)
    return geo_topk_reference(*packed, k=k, need=need)


@functools.partial(jax.jit, static_argnames=("k", "need", "p_min"))
def _dispatch_shard(packed: GeoTopKInputs, k: int, need: int, p_min: int):
    from repro.kernels.geo_topk.ref import score_matrix_restricted
    scores, sat = score_matrix_restricted(*packed, need=need, p_min=p_min)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i, sat


def geo_topk_shard(packed: GeoTopKInputs, *, k: int, need: int,
                   p_min: int, interpret: bool = False):
    """Per-shard top-k over one region's padded node layout: the
    adaptive proximity filter runs restricted to precisions
    ``p >= p_min`` (the shard prefix length) with no global fallback.

    Returns ``(scores, indices, satisfied)`` — ``indices`` are positions
    into THIS shard's padded layout (callers map them to global task
    positions via the shard's ``task_ix_padded``), and rows with
    ``satisfied == False`` carry no result: the in-shard widening could
    not reach ``need`` hits, so the caller must escalate them to a
    cross-shard pass (``geo_topk`` over the adjacent shards' union).
    ``need`` is the caller's *global* hit target — per-shard counts at
    ``p >= p_min`` equal global counts because geohash cells nest.

    jnp oracle on every backend (the per-shard matrices are already a
    1/S slice of the work the Pallas kernels tile; ``interpret`` is
    accepted for call-site symmetry with ``geo_topk``).
    """
    del interpret
    return _dispatch_shard(packed, k, need, p_min)


def geo_topk(packed: GeoTopKInputs, *, k: int, need: int = None,
             force_pallas: bool = False, interpret: bool = False,
             block_u: Optional[int] = None, node_tile: Optional[int] = None):
    """Per-user top-k replica (scores, indices) over the packed query.

    When the Pallas path is taken and no explicit ``block_u``/``node_tile``
    is given, the layout comes from the autotune cache (heuristic default
    until ``tune.autotune`` has run for this shape bucket).
    """
    n = len(packed.node_lat)
    if need is None:
        need = min(MIN_PROXIMITY_HITS, n)
    # consult the autotune cache only when the caller pinned NEITHER
    # knob — an explicit node_tile (or block_u) is a layout request
    if (force_pallas or jax.default_backend() == "tpu") \
            and block_u is None and node_tile is None:
        from repro.kernels.geo_topk import tune
        block_u, node_tile = tune.get_config(len(packed.user_lat), n, k)
    return _dispatch(packed, k, need, force_pallas, interpret, block_u,
                     node_tile)
