"""Pure-jnp oracle for the grouped (per-expert) matmul."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(x, w):
    """x: (E, C, D) dispatched tokens; w: (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
