"""Grouped (per-expert) matmul as a Pallas TPU kernel.

The MoE FFN applies a different weight matrix to each expert's capacity
buffer: y[e] = x[e] @ w[e].  On GPU this is a CUTLASS grouped-GEMM; the TPU
adaptation tiles each expert's GEMM over the MXU with (bc, bd) × (bd, bf)
VMEM tiles and makes the contraction dimension the innermost sequential grid
axis, accumulating partial products in fp32 VMEM scratch.  The expert axis is
an outer parallel grid dimension, so XLA can pipeline experts back-to-back —
no padding of experts to a common token count beyond the capacity buffer the
dispatch already produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nd):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)         # (bc, bd)
    w = w_ref[0].astype(jnp.float32)         # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _out():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm_pallas(x, w, *, block_c=128, block_f=128, block_d=512,
               interpret=False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    pc, pf, pd = -C % bc, -F % bf, -D % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    Cp, Fp, Dp = C + pc, F + pf, D + pd
    nc, nf, nd = Cp // bc, Fp // bf, Dp // bd

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, nd=nd),
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, w)
    return out[:, :C, :F]
