"""Public grouped-matmul op: pallas on TPU, einsum elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.kernel import gmm_pallas
from repro.kernels.moe_gmm.ref import gmm_reference


@functools.partial(jax.jit, static_argnames=("force_pallas", "interpret"))
def gmm(x, w, *, force_pallas=False, interpret=False):
    if force_pallas or jax.default_backend() == "tpu":
        return gmm_pallas(x, w,
                          interpret=interpret or jax.default_backend() != "tpu")
    return gmm_reference(x, w)
