"""Train the MiniCPM-style arch with its WSD schedule + preemption restart.

    PYTHONPATH=src python examples/train_wsd.py

Trains a reduced minicpm-2b for 120 steps, interrupting (preemption) at
step ~60 and restarting from the checkpoint — the loss curve must continue
where it left off, and the WSD decay phase must show the LR drop.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.config import TrainConfig, reduced
from repro.configs import get_config
from repro.models.api import build_model
from repro.train.trainer import Trainer


def main():
    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=3e-3, schedule="wsd", warmup_steps=10,
                     stable_steps=90, decay_steps=120, checkpoint_every=20,
                     remat="none")
    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(model, cfg, tc, batch=8, seq=64, ckpt_dir=d)
        t1.init_or_restore()
        t1.interrupt_at = 60
        # run 60 steps, then simulate preemption
        m1 = t1.train(60)
        print(f"[phase1] steps 1-60: loss "
              f"{m1.steps[0]['loss']:.3f} -> {m1.steps[-1]['loss']:.3f}")
        t1.ckpt.wait()

        t2 = Trainer(model, cfg, tc, batch=8, seq=64, ckpt_dir=d)
        start = t2.init_or_restore()
        print(f"[phase2] restarted from checkpoint step {start} "
              f"(restarts={t2.metrics.restarts})")
        m2 = t2.train(120 - start)
        lrs = [s["lr"] for s in m2.steps]
        print(f"[phase2] steps {start + 1}-120: loss "
              f"{m2.steps[0]['loss']:.3f} -> {m2.steps[-1]['loss']:.3f}; "
              f"WSD lr stable {max(lrs):.1e} -> decayed {lrs[-1]:.1e}")
        assert m2.steps[-1]["loss"] < m1.steps[0]["loss"]
        assert lrs[-1] < 0.5 * max(lrs)


if __name__ == "__main__":
    main()
