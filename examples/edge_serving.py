"""End-to-end driver: Armada edge cloud serving a REAL JAX model.

    PYTHONPATH=src python examples/edge_serving.py

The control plane (selection, auto-scaling, failover) runs in virtual time;
the data plane is real: each edge node's per-frame processing time is the
measured latency of THIS host's jitted detector forward, scaled by the
node's Table-5 speed factor.  Mid-run we kill the busiest node and show
zero-downtime failover; finally a generation request rides the Cargo
session layer across replicas.
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.config import reduced
from repro.configs import get_config
from repro.core.app_manager import ServiceSpec
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import campus_users, real_world
from repro.models.api import build_model, make_batch
from repro.serving.engine import ServeEngine
from repro.serving.session import import_session


def measure_detector_ms() -> float:
    cfg = get_config("armada-detector")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 1, cfg.num_patches + 8)
    fwd = jax.jit(lambda p, b: model.hidden_states(p, b)[0])
    fwd(params, batch).block_until_ready()
    ts = []
    for _ in range(10):
        t0 = time.perf_counter()
        fwd(params, batch).block_until_ready()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def main():
    host_ms = measure_detector_ms()
    print(f"[calibrate] real jitted detector forward: {host_ms:.1f} ms "
          f"on this host")

    topo = real_world()
    # anchor the simulator's node speeds to the measured compute
    anchor = topo.nodes["D6"].proc_ms
    for spec in topo.nodes.values():
        if spec.proc_ms > 0:
            spec.proc_ms = host_ms * (spec.proc_ms / anchor)
    sys_ = ArmadaSystem(topo, seed=0)
    sys_.beacon.deploy_application(ServiceSpec(
        "detect", detection_image(), locations=[topo.nodes["D6"].loc],
        min_replicas=6))
    sys_.ensure_cloud_replica("detect")
    sys_.sim.run(until=15_000)

    users = campus_users(topo, 8, seed=0)
    clients = {u: sys_.make_client(u, "detect", frame_interval_ms=33.0)
               for u in users}
    for i, c in enumerate(clients.values()):
        sys_.sim.at(15_000 + i * 300, c.start)
    sys_.sim.run(until=45_000)
    by_node = {}
    for c in clients.values():
        by_node.setdefault(c.active.captain.node_id, []).append(
            c.mean_latency(since=30_000))
    print("[steady] users per node:",
          {k: f"{len(v)}u @ {sum(v)/len(v):.0f}ms"
           for k, v in sorted(by_node.items())})

    victim = max(by_node, key=lambda k: len(by_node[k]))
    print(f"[churn] killing busiest node {victim} ...")
    sys_.fail_node(victim, 45_000)
    sys_.sim.run(until=60_000)
    lost = [u for u, c in clients.items() if c.active is None]
    print(f"[churn] after failover: 0 users stranded={not lost}; "
          f"mean e2e {np.mean([c.mean_latency(since=50_000) for c in clients.values()]):.0f} ms")

    # ---- real generation w/ session failover across engine replicas
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    e1 = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    e2 = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    e1.submit("gen", [5, 9, 13], max_new_tokens=10)
    for _ in range(4):
        e1.step()
    blob = e1.export_session("gen")            # replica e1 "fails" here
    import_session(e2, blob)
    out = e2.run_until_drained()
    print(f"[session] generation finished on the backup replica: {out}")


if __name__ == "__main__":
    main()
