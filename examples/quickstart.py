"""Quickstart: train a tiny model, serve it, and route through Armada.

    PYTHONPATH=src python examples/quickstart.py

Three acts, ~2 minutes on CPU:
  1. train a reduced qwen3 for 30 steps (loss must drop)
  2. serve it through a jitted continuous-batching engine
  3. stand up an Armada edge cloud and watch 2-step selection pick nodes
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.config import TrainConfig, reduced
from repro.configs import get_config
from repro.core.app_manager import ServiceSpec
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import real_world
from repro.models.api import build_model
from repro.serving.engine import ServeEngine
from repro.train.trainer import Trainer


def main():
    # ---- 1. train -------------------------------------------------------
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=30,
                     checkpoint_every=10, remat="none")
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(model, cfg, tc, batch=8, seq=64, ckpt_dir=d)
        trainer.init_or_restore()
        metrics = trainer.train(30)
        first, last = metrics.steps[0]["loss"], metrics.steps[-1]["loss"]
        print(f"[1/3] trained 30 steps: loss {first:.3f} -> {last:.3f}")
        assert last < first
        params = trainer.params

    # ---- 2. serve -------------------------------------------------------
    engine = ServeEngine(cfg, params, max_batch=4, max_seq=64)
    for i in range(6):
        engine.submit(f"req{i}", [3 + i, 40 + i, 7], max_new_tokens=8)
    done = engine.run_until_drained()
    print(f"[2/3] served {len(done)} requests, "
          f"decode {engine.decode_ms_ema:.1f} ms/step: "
          f"req0 -> {done['req0']}")

    # ---- 3. Armada ------------------------------------------------------
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=0)
    sys_.beacon.deploy_application(ServiceSpec(
        "detect", detection_image(), locations=[topo.nodes["D6"].loc],
        min_replicas=6))
    sys_.sim.run(until=15_000)
    client = sys_.make_client("C1", "detect")
    sys_.sim.at(15_000, client.start)
    sys_.sim.run(until=40_000)
    print(f"[3/3] Armada client C1 selected "
          f"{client.active.captain.node_id} "
          f"(mean e2e {client.mean_latency(since=25_000):.1f} ms; "
          f"paper Table 6a: V1 at 38 ms)")


if __name__ == "__main__":
    main()
