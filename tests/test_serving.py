"""Serving engine: slot-scheduler invariants (hypothesis), continuous
batching correctness, greedy-decode equivalence, session failover, and
the ServingProfile surrogate <-> real parity pins."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st

from repro.config import reduced
from repro.configs import get_config
from repro.models.api import build_model
from repro.serving.batching import GenRequest, SlotScheduler
from repro.serving.engine import ServeEngine
from repro.serving.profile import FAMILIES, ProfileMode, ServingProfile
from repro.serving.session import export_slot, import_session

# ---------------------------------------------------------------------------
# scheduler invariants (property-based)
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(min_value=1, max_value=20), min_size=1,
                max_size=30),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=100, deadline=None)
def test_slot_scheduler_invariants(lengths, max_batch):
    sched = SlotScheduler(max_batch)
    for i, n in enumerate(lengths):
        sched.submit(GenRequest(f"r{i}", [1], max_new_tokens=n))
    served = set()
    for _ in range(10_000):
        sched.admit()
        active = sched.active()
        # invariant: no slot double-booked, occupancy <= max_batch
        slots = [r.slot for r in active]
        assert len(slots) == len(set(slots))
        assert len(active) <= max_batch
        if not active:
            break
        r = active[0]
        r.generated.append(0)
        if len(r.generated) >= r.max_new_tokens:
            sched.complete(r)
            served.add(r.request_id)
        if sched.drain():
            break
    assert served == {f"r{i}" for i in range(len(lengths))}


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen3-1.7b"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _greedy_reference(model, params, cfg, prompt, n):
    """Generate greedily via repeated full forward (the slow oracle)."""
    toks = list(prompt)
    for _ in range(n):
        h, _ = model.hidden_states(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = h[:, -1] @ w
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


@pytest.mark.slow
def test_engine_matches_full_forward_generation(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    prompts = [[5, 9, 13], [7, 3, 200, 41]]
    for i, p in enumerate(prompts):
        engine.submit(f"r{i}", p, max_new_tokens=6)
    out = engine.run_until_drained()
    for i, p in enumerate(prompts):
        ref = _greedy_reference(model, params, cfg, p, 6)
        assert out[f"r{i}"] == ref, (out[f"r{i}"], ref)


def test_continuous_batching_interleaves(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    for i in range(5):                      # 5 requests > 2 slots
        engine.submit(f"r{i}", [3 + i], max_new_tokens=4)
    out = engine.run_until_drained()
    assert len(out) == 5
    # equivalence with serial execution
    solo = ServeEngine(cfg, params, max_batch=1, max_seq=64, eos_id=-1)
    for i in range(5):
        solo.submit(f"r{i}", [3 + i], max_new_tokens=4)
    ref = solo.run_until_drained()
    assert out == ref


@pytest.mark.slow
def test_session_failover_preserves_generation(tiny):
    cfg, model, params = tiny
    e1 = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    e2 = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    prompt = [5, 9, 13]
    n = 8
    e1.submit("mig", prompt, max_new_tokens=n)
    for _ in range(4):
        e1.step()
    blob = e1.export_session("mig")
    import_session(e2, blob)
    out = e2.run_until_drained()
    ref = _greedy_reference(model, params, cfg, prompt, n)
    assert out["mig"] == ref


# ---------------------------------------------------------------------------
# session import under load: queue + re-splice, never drop or corrupt
# ---------------------------------------------------------------------------


class _StubEngine:
    """Session-bookkeeping facade: a real ``SlotScheduler`` plus a small
    device cache, mirroring ``ServeEngine``'s ``_splice``/``_admit``
    resume path without building a model — cheap enough for the property
    test to draw many examples inside tier-1."""

    def __init__(self, max_batch, width=4, name="stub-arch"):
        self.cfg = SimpleNamespace(name=name)
        self.max_batch = max_batch
        self.scheduler = SlotScheduler(max_batch)
        self.cache = {"k": jnp.zeros((max_batch, width), jnp.float32),
                      "len": jnp.zeros((max_batch,), jnp.int32)}
        self.cache_batch_axis = {"k": 0, "len": 0}

    def _splice(self, cache, sub, slot):
        out = {}
        for key, c in cache.items():
            idx = [0] * c.ndim
            idx[self.cache_batch_axis[key]] = slot
            out[key] = jax.lax.dynamic_update_slice(
                c, jnp.asarray(sub[key]).astype(c.dtype), tuple(idx))
        return out

    def _admit(self):
        # ServeEngine._admit's resume branch (the only one imports hit)
        for slot, req in self.scheduler.admit():
            assert req.resume_cache is not None
            self.cache = self._splice(
                self.cache, jax.tree.map(jnp.asarray, req.resume_cache),
                slot)
            req.resume_cache = None


def _donor_blob(j):
    """Export a session whose cache row is distinguishable (100+j)."""
    donor = _StubEngine(1)
    donor.cache = {"k": jnp.full((1, 4), 100.0 + j, jnp.float32),
                   "len": jnp.asarray([40 + j], jnp.int32)}
    req = GenRequest(f"mig{j}", [7, j], 32, generated=[9, j], slot=0)
    donor.scheduler.slots[0] = req
    return export_slot(donor, req)


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=15))
@settings(max_examples=25, deadline=None)
def test_import_session_queues_when_full(max_batch, n_imports, free_mask):
    eng = _StubEngine(max_batch)
    occupants = []
    for s in range(max_batch):
        r = GenRequest(f"occ{s}", [s], 16, generated=[s], slot=s)
        eng.scheduler.slots[s] = r
        occupants.append(r)
    eng.cache = {"k": jnp.arange(max_batch * 4, dtype=jnp.float32)
                 .reshape(max_batch, 4),
                 "len": jnp.arange(max_batch, dtype=jnp.int32)}
    before = jax.tree.map(np.asarray, eng.cache)

    imported = [import_session(eng, _donor_blob(j))
                for j in range(n_imports)]

    # full house: every import queues in FIFO order — nothing dropped,
    # no occupied slot reassigned, no cache row overwritten
    assert [r.request_id for r in eng.scheduler.queue] == \
        [f"mig{j}" for j in range(n_imports)]
    for j, r in enumerate(imported):
        assert r.slot is None and r.resume_cache is not None
        assert r.generated == [9, j]
    for s in range(max_batch):
        assert eng.scheduler.slots[s] is occupants[s]
    after = jax.tree.map(np.asarray, eng.cache)
    np.testing.assert_array_equal(before["k"], after["k"])
    np.testing.assert_array_equal(before["len"], after["len"])

    # free a drawn subset of slots; admission re-splices queued sessions
    # in FIFO order without touching the survivors
    freed = [s for s in range(max_batch) if free_mask >> s & 1]
    for s in freed:
        eng.scheduler.complete(occupants[s])
    eng._admit()
    k = np.asarray(eng.cache["k"])
    ln = np.asarray(eng.cache["len"])
    placed = imported[:min(len(freed), n_imports)]
    taken = [r.slot for r in placed]
    assert len(taken) == len(set(taken))
    for j, r in enumerate(placed):
        assert r.slot in freed and r.resume_cache is None
        np.testing.assert_array_equal(k[r.slot], np.full(4, 100.0 + j))
        assert ln[r.slot] == 40 + j
    for r in imported[len(placed):]:        # overflow stays queued intact
        assert r.slot is None and r.resume_cache is not None
    for s in range(max_batch):              # survivors' rows untouched
        if eng.scheduler.slots[s] in occupants:
            np.testing.assert_array_equal(k[s], before["k"][s])
            assert ln[s] == before["len"][s]


@pytest.mark.slow
def test_import_session_queued_resplices_real(tiny):
    cfg, model, params = tiny
    e1 = ServeEngine(cfg, params, max_batch=1, max_seq=64, eos_id=-1)
    prompt = [5, 9, 13]
    n = 8
    e1.submit("mig", prompt, max_new_tokens=n)
    for _ in range(4):
        e1.step()
    blob = e1.export_session("mig")
    # target replica's only slot is busy -> the import must queue, then
    # re-splice once the occupant finishes; generation stays lossless
    e2 = ServeEngine(cfg, params, max_batch=1, max_seq=64, eos_id=-1)
    e2.submit("busy", [7, 3], max_new_tokens=5)
    e2.step()
    req = import_session(e2, blob)
    assert req.slot is None and req.resume_cache is not None
    out = e2.run_until_drained()
    ref = _greedy_reference(model, params, cfg, prompt, n)
    assert out["mig"] == ref
    assert out["busy"] is not None


def test_session_rejects_cross_arch(tiny):
    cfg, model, params = tiny
    e1 = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    e1.submit("x", [5], max_new_tokens=4)
    e1.step()
    blob = e1.export_session("x")
    cfg2 = reduced(get_config("minicpm-2b"), num_layers=2)
    m2 = build_model(cfg2)
    e2 = ServeEngine(cfg2, m2.init(jax.random.key(1)), max_batch=2,
                     max_seq=64)
    with pytest.raises(AssertionError):
        import_session(e2, blob)


# ---------------------------------------------------------------------------
# ServingProfile: surrogate <-> real parity pins (one per model family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fam", FAMILIES)
def test_profile_surrogate_real_parity(fam):
    """Fast per-family pin: the surrogate exposes the analytic contract
    (linear request_ms, affine monotone step estimate, nothing measured)
    and a reduced real backend produces finite measured timings through
    the same API.  Full-config real profiles live behind the slow marker
    (test_profile_real_full_config / bench_heterogeneity)."""
    sur = ServingProfile(fam, calibration={})
    assert sur.mode is ProfileMode.SURROGATE
    assert sur.measured_ms() is None
    s1, s2 = sur.estimate_step_ms(1), sur.estimate_step_ms(2)
    assert 0.0 < s1 <= s2 <= 2.0 * s1 + 1e-9      # affine, sub-linear
    assert sur.request_ms(2.0) == pytest.approx(2.0 * sur.unit_ms)
    assert sur.step_ms(2) == pytest.approx(s2)    # surrogate dispatch

    real = ServingProfile(fam, calibration={})
    real.attach_real(reduce_layers=1, max_batch=2, max_seq=32)
    assert real.mode is ProfileMode.REAL
    for b in (1, 2):
        m = real.step_ms(b)
        assert np.isfinite(m) and m > 0.0
    assert real.measured_ms() is not None and real.measured_ms() > 0.0
    # surrogate request_ms is unchanged by attaching a real backend: tick
    # paths consume the analytic unit time either way (device linearity)
    assert real.request_ms(1.5) == pytest.approx(sur.request_ms(1.5))


def test_heartbeat_surfaces_profile():
    from repro.core.captain import Captain
    from repro.core.cluster import NodeSpec, Topology
    from repro.core.sim import Simulator

    prof = ServingProfile("armada-detector", calibration={})
    spec = NodeSpec("N", (0.0, 0.0), 30.0, slots=2, profile=prof)
    sim = Simulator(seed=0)
    cap = Captain(sim, Topology({"N": spec}, {}), spec)
    assert cap.request_ms() == pytest.approx(prof.unit_ms)
    hb = cap.heartbeat()
    assert hb["model"] == "armada-detector"
    assert hb["decode_ms"] is None            # surrogate: nothing measured
    assert hb["occupancy"] == 0.0 and hb["queue_ms"] == 0.0
    # 200 frames x 30 ms >> 2 slots x 1000 ms window: node saturates
    cap.arrive_batch(200.0, 1.0, 1000.0, now=0.0)
    hb2 = cap.heartbeat()
    assert hb2["queue_ms"] > 0.0 and hb2["occupancy"] > 0.0

    # synthetic captains keep the legacy contract
    bare = NodeSpec("M", (0.0, 0.0), 24.0, slots=1)
    cap2 = Captain(sim, Topology({"M": bare}, {}), bare)
    hb3 = cap2.heartbeat()
    assert hb3["model"] == "synthetic" and hb3["decode_ms"] is None
    assert cap2.request_ms(2.0) == 48.0


@pytest.mark.slow
def test_profile_real_full_config():
    """Full-config detector real backend: measured step time is positive
    and the measured EMA lands within an order of magnitude of the
    surrogate estimate (calibration proper runs in bench_heterogeneity)."""
    prof = ServingProfile("armada-detector", calibration={})
    prof.attach_real(max_batch=2)
    m = prof.step_ms(2)
    est = prof.estimate_step_ms(2)
    assert np.isfinite(m) and m > 0.0
    assert prof.measured_ms() == pytest.approx(prof._real.ema())
    assert est > 0.0


def test_bench_serving_selection_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1: the
    flash-crowd recovery scenario must show queueing-aware selection
    beating proximity-only on SLO violations (the full 100k profile
    adds the p99 separation)."""
    from benchmarks.bench_serving_selection import derive, run

    rows = run(smoke=True)
    by_name = {r[0]: r for r in rows}
    pre = next(n for n in by_name if n.endswith("/proximity"))[:-len(
        "proximity")]
    base = by_name[pre + "proximity/slo_viol_pct"][1]
    aware = by_name[pre + "queueing/slo_viol_pct"][1]
    assert np.isfinite(base) and np.isfinite(aware)
    # deterministic seeded scenario: the aware run evacuates the dense
    # cluster during recovery, the baseline strands part of it on the
    # drowned nodes
    assert aware < 0.5 * base
    us = {n: (ms * 1e3 if ms is not None else None) for n, ms, _ in rows}
    imp = derive(us)
    assert imp and "slo_viol=" in imp[0][2]
