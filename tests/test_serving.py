"""Serving engine: slot-scheduler invariants (hypothesis), continuous
batching correctness, greedy-decode equivalence, session failover."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st

from repro.config import reduced
from repro.configs import get_config
from repro.models.api import build_model
from repro.serving.batching import GenRequest, SlotScheduler
from repro.serving.engine import ServeEngine
from repro.serving.session import export_slot, import_session

# ---------------------------------------------------------------------------
# scheduler invariants (property-based)
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(min_value=1, max_value=20), min_size=1,
                max_size=30),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=100, deadline=None)
def test_slot_scheduler_invariants(lengths, max_batch):
    sched = SlotScheduler(max_batch)
    for i, n in enumerate(lengths):
        sched.submit(GenRequest(f"r{i}", [1], max_new_tokens=n))
    served = set()
    for _ in range(10_000):
        sched.admit()
        active = sched.active()
        # invariant: no slot double-booked, occupancy <= max_batch
        slots = [r.slot for r in active]
        assert len(slots) == len(set(slots))
        assert len(active) <= max_batch
        if not active:
            break
        r = active[0]
        r.generated.append(0)
        if len(r.generated) >= r.max_new_tokens:
            sched.complete(r)
            served.add(r.request_id)
        if sched.drain():
            break
    assert served == {f"r{i}" for i in range(len(lengths))}


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen3-1.7b"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _greedy_reference(model, params, cfg, prompt, n):
    """Generate greedily via repeated full forward (the slow oracle)."""
    toks = list(prompt)
    for _ in range(n):
        h, _ = model.hidden_states(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = h[:, -1] @ w
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


@pytest.mark.slow
def test_engine_matches_full_forward_generation(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    prompts = [[5, 9, 13], [7, 3, 200, 41]]
    for i, p in enumerate(prompts):
        engine.submit(f"r{i}", p, max_new_tokens=6)
    out = engine.run_until_drained()
    for i, p in enumerate(prompts):
        ref = _greedy_reference(model, params, cfg, p, 6)
        assert out[f"r{i}"] == ref, (out[f"r{i}"], ref)


def test_continuous_batching_interleaves(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    for i in range(5):                      # 5 requests > 2 slots
        engine.submit(f"r{i}", [3 + i], max_new_tokens=4)
    out = engine.run_until_drained()
    assert len(out) == 5
    # equivalence with serial execution
    solo = ServeEngine(cfg, params, max_batch=1, max_seq=64, eos_id=-1)
    for i in range(5):
        solo.submit(f"r{i}", [3 + i], max_new_tokens=4)
    ref = solo.run_until_drained()
    assert out == ref


@pytest.mark.slow
def test_session_failover_preserves_generation(tiny):
    cfg, model, params = tiny
    e1 = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    e2 = ServeEngine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    prompt = [5, 9, 13]
    n = 8
    e1.submit("mig", prompt, max_new_tokens=n)
    for _ in range(4):
        e1.step()
    blob = e1.export_session("mig")
    import_session(e2, blob)
    out = e2.run_until_drained()
    ref = _greedy_reference(model, params, cfg, prompt, n)
    assert out["mig"] == ref


def test_session_rejects_cross_arch(tiny):
    cfg, model, params = tiny
    e1 = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    e1.submit("x", [5], max_new_tokens=4)
    e1.step()
    blob = e1.export_session("x")
    cfg2 = reduced(get_config("minicpm-2b"), num_layers=2)
    m2 = build_model(cfg2)
    e2 = ServeEngine(cfg2, m2.init(jax.random.key(1)), max_batch=2,
                     max_seq=64)
    with pytest.raises(AssertionError):
        import_session(e2, blob)
