"""Beyond-paper churn analysis: stability estimation converges, and the
stability-aware scheduling policy reduces client failovers under churn."""
import pytest

from repro.core.app_manager import ServiceSpec
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.churn import ChurnModel, StabilityTracker, stability_policy
from repro.core.cluster import real_world


def test_stability_tracker_separates_stable_from_flaky():
    sys_ = ArmadaSystem(real_world(), seed=0)
    tr = StabilityTracker(sys_.sim)
    churn = ChurnModel(sys_.sim, sys_.captains, tr,
                       volunteer_mttf_ms=30_000.0, mttr_ms=15_000.0,
                       unstable=("V4", "V5"))
    churn.start()
    sys_.sim.run(until=600_000.0)
    flaky = min(tr.availability("V4"), tr.availability("V5"))
    stable = tr.availability("D6")
    assert stable > flaky + 0.1, (stable, flaky)
    assert tr.mttf_ms("V4") is not None


def _failovers(use_stability: bool, seed: int = 21) -> float:
    sys_ = ArmadaSystem(real_world(), seed=seed)
    tracker = StabilityTracker(sys_.sim)
    if use_stability:
        sys_.spinner.new_policy(stability_policy(tracker, weight=0.6))
    churn = ChurnModel(sys_.sim, sys_.captains, tracker,
                       volunteer_mttf_ms=45_000.0, mttr_ms=20_000.0,
                       unstable=("V4", "V5"))
    # warm the tracker so the policy has signal before placement
    churn.start()
    sys_.sim.run(until=300_000.0)
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=4)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=320_000.0)
    clients = []
    for cid in ("C1", "C2", "C3"):
        c = sys_.make_client(cid, "detect", frame_interval_ms=33.0)
        clients.append(c)
        sys_.sim.at(320_000.0, c.start)
    sys_.sim.run(until=500_000.0)
    return sum(len(c.switches) for c in clients) / len(clients)


def test_stability_policy_reduces_failovers():
    naive = sum(_failovers(False, s) for s in (21, 22, 23))
    aware = sum(_failovers(True, s) for s in (21, 22, 23))
    assert aware <= naive, (aware, naive)


def test_data_locality_policy_prefers_near_cargo():
    """Data-dependent placement: with the policy on, new tasks land nearer
    the service's data replicas (paper §3.3.1 custom-policy slot)."""
    from repro.core.app_manager import Task
    from repro.core.beacon import facerec_image
    from repro.core.churn import data_locality_policy
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=5,
                        compute_nodes=["V1", "V2", "V3", "V4", "V5", "D6"],
                        cargo_nodes=["V5", "D6", "Cloud"])
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       locations=[topo.nodes["V5"].loc])
    sys_.cargo_manager.store_register(spec)
    sys_.spinner.new_policy(data_locality_policy(
        sys_.cargo_manager, "face", topo, weight=1.5))
    t = Task("face/t0", "face")
    sys_.spinner.deploy_task(t, spec.image, topo.nodes["C1"].loc)
    # cargo replicas sit on V5/D6: the data-locality score must pull the
    # task onto (or right next to) a cargo node
    best_rtt = min(topo.rtt(t.captain.node_id, c)
                   for c in ("V5", "D6"))
    assert best_rtt <= 20.0, (t.captain.node_id, best_rtt)
