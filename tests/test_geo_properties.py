"""Property-based tests for the batched geo primitives the shard router
silently relies on (hypothesis, or the deterministic fallback shim).

The Beacon fault-domain router assumes three invariants of
``repro.core.geohash``:

* ``encode_batch`` produces exactly the bit stream the string ``encode``
  packs (so region prefix strings, Morton prefix codes and decoded cell
  centers all name the same cell), and decoding the code's cell contains
  the encoded point;
* Morton prefix **nesting** — the precision-p cell contains all its
  precision-(p+1) children (``code(p) == code(p+1) >> 5``), the property
  that makes in-shard proximity-hit counts equal global counts;
* ``distance_km_batch`` is a metric in the ways routing needs: symmetric,
  zero at identity, consistent with the scalar haversine, and triangle-
  sane (the nearest-live-Beacon pick is order-independent).
"""
import numpy as np

try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st

from repro.core import geohash

lat_st = st.floats(min_value=-89.9, max_value=89.9, allow_nan=False)
lon_st = st.floats(min_value=-179.9, max_value=179.9, allow_nan=False)


@given(lat=lat_st, lon=lon_st, p=st.integers(min_value=1, max_value=9))
@settings(max_examples=100, deadline=None)
def test_encode_batch_matches_string_encode_and_roundtrips(lat, lon, p):
    """Batch Morton code == string-encoded code, and the decoded cell
    contains the point (within the cell half-sizes)."""
    code = int(geohash.encode_batch(np.asarray([lat]), np.asarray([lon]),
                                    p)[0])
    gh = geohash.encode(lat, lon, precision=p)
    assert code == geohash.str_to_code(gh)
    assert geohash.code_to_str(code, p) == gh
    dlat, dlon, elat, elon = geohash.decode(geohash.code_to_str(code, p))
    assert abs(dlat - lat) <= elat * 1.0001
    assert abs(dlon - lon) <= elon * 1.0001


@given(lat=lat_st, lon=lon_st, p=st.integers(min_value=1, max_value=8))
@settings(max_examples=100, deadline=None)
def test_morton_prefix_nesting(lat, lon, p):
    """The precision-p cell contains its precision-(p+1) child: dropping
    the child's last base32 char (5 bits) recovers the parent code.  This
    is the invariant behind in-shard-hits == global-hits."""
    child = int(geohash.encode_batch(np.asarray([lat]), np.asarray([lon]),
                                     p + 1)[0])
    parent = int(geohash.encode_batch(np.asarray([lat]), np.asarray([lon]),
                                      p)[0])
    assert parent == child >> 5


@given(lat=lat_st, lon=lon_st, p=st.integers(min_value=2, max_value=9))
@settings(max_examples=50, deadline=None)
def test_shared_prefix_chars_matches_string_common_prefix(lat, lon, p):
    """The vectorized prefix-length primitive agrees with the string one
    for a point and a perturbed neighbour."""
    lat2 = min(89.9, lat + 0.3)
    lon2 = min(179.9, lon + 0.3)
    a = geohash.encode_batch(np.asarray([lat]), np.asarray([lon]), p)
    b = geohash.encode_batch(np.asarray([lat2]), np.asarray([lon2]), p)
    want = geohash.common_prefix(geohash.encode(lat, lon, p),
                                 geohash.encode(lat2, lon2, p))
    assert int(geohash.shared_prefix_chars(a, b, p)[0]) == want


@given(lat1=lat_st, lon1=lon_st, lat2=lat_st, lon2=lon_st)
@settings(max_examples=100, deadline=None)
def test_distance_batch_symmetry_and_scalar_parity(lat1, lon1, lat2, lon2):
    d_ab = float(geohash.distance_km_batch(lat1, lon1, lat2, lon2))
    d_ba = float(geohash.distance_km_batch(lat2, lon2, lat1, lon1))
    np.testing.assert_allclose(d_ab, d_ba, rtol=1e-12)
    np.testing.assert_allclose(
        d_ab, geohash.distance_km(lat1, lon1, lat2, lon2),
        rtol=1e-9, atol=1e-9)
    assert d_ab >= 0.0
    assert float(geohash.distance_km_batch(lat1, lon1, lat1, lon1)) == 0.0


@given(lat1=lat_st, lon1=lon_st, lat2=lat_st, lon2=lon_st,
       lat3=lat_st, lon3=lon_st)
@settings(max_examples=100, deadline=None)
def test_distance_batch_triangle_inequality(lat1, lon1, lat2, lon2,
                                            lat3, lon3):
    """Great-circle distance is a metric: d(a,c) <= d(a,b) + d(b,c).
    The nearest-live-Beacon handoff relies on this staying sane."""
    d_ac = float(geohash.distance_km_batch(lat1, lon1, lat3, lon3))
    d_ab = float(geohash.distance_km_batch(lat1, lon1, lat2, lon2))
    d_bc = float(geohash.distance_km_batch(lat2, lon2, lat3, lon3))
    assert d_ac <= d_ab + d_bc + 1e-6
