"""Telemetry: the while-aware HLO cost walker (trip counts, dot flops,
slice-aware traffic, collective accounting) and roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import V5E
from repro.telemetry.hlo_cost import analyze
from repro.telemetry.roofline import Roofline

ONE_MM = 2 * 64 * 512 * 512          # flops of one (64,512)x(512,512)


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_walker_counts_scan_trip_count():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((64, 512), jnp.float32)
    ws = jnp.zeros((8, 512, 512))
    c = analyze(_compiled_text(scanned, x, ws))
    assert c.flops == pytest.approx(8 * ONE_MM, rel=0.01)
    # XLA's own cost_analysis counts the body once — the bug we fix
    ca = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
    if isinstance(ca, list):          # older jax wraps it in a list
        ca = ca[0]
    assert ca["flops"] == pytest.approx(ONE_MM, rel=0.01)


def test_walker_nested_scan():
    def nested(x, ws):
        def outer(c, wpair):
            def inner(ci, w):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, wpair)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws.reshape(2, 4, 512, 512))
        return y

    x = jnp.zeros((64, 512), jnp.float32)
    ws = jnp.zeros((8, 512, 512))
    c = analyze(_compiled_text(nested, x, ws))
    assert c.flops == pytest.approx(8 * ONE_MM, rel=0.01)


def test_walker_unrolled_equals_scanned():
    x = jnp.zeros((64, 512), jnp.float32)
    ws = jnp.zeros((8, 512, 512))

    def unrolled(x, ws):
        for i in range(8):
            x = x @ ws[i]
        return x

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    cu = analyze(_compiled_text(unrolled, x, ws))
    cs = analyze(_compiled_text(scanned, x, ws))
    assert cu.flops == pytest.approx(cs.flops, rel=0.01)


def test_walker_slice_traffic_not_full_buffer():
    """A dynamic-slice of a huge buffer must cost ~slice bytes."""
    big = jnp.zeros((1024, 1024), jnp.float32)          # 4 MB

    def f(big, i):
        return jax.lax.dynamic_slice(big, (i, 0), (8, 1024)) * 2.0

    c = analyze(_compiled_text(f, big, jnp.int32(3)))
    assert c.bytes < 1e6                                 # << 4 MB


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, chips=1,
                 hw=V5E)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")
    r2 = Roofline(flops=1e12, hbm_bytes=1e9, coll_bytes=200e9 * 4, chips=1)
    assert r2.dominant == "collective"
    assert 0.0 <= r2.compute_fraction() <= 1.0


def test_collective_accounting_via_psum():
    mesh = jax.make_mesh((1,), ("d",))

    @jax.jit
    def f(x):
        return jax.lax.with_sharding_constraint(
            x, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))

    # single-device: no collectives expected
    c = analyze(jax.jit(lambda x: x * 2).lower(
        jnp.zeros((128,))).compile().as_text())
    assert c.coll_bytes == 0.0


def test_dryrun_artifacts_complete_and_wellformed():
    """All 40 cells × 2 meshes exist: 64 ok + 16 documented skips."""
    import json
    import pathlib
    art = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated")
    recs = [json.loads(p.read_text()) for p in art.glob("*.json")
            if "__" in p.name and p.name.count("__") == 2]
    base = [r for r in recs if r.get("variant", "baseline") == "baseline"]
    ok = [r for r in base if r["status"] == "ok"]
    skip = [r for r in base if r["status"] == "skip"]
    assert len(ok) == 64, len(ok)
    assert len(skip) == 16
    for r in ok:
        assert r["roofline"]["flops"] > 0
        assert r["roofline"]["dominant"] in ("compute", "memory",
                                             "collective")
        assert r["chips"] in (256, 512)
    for r in skip:
        assert "long_500k" in r["shape"]
