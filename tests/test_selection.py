"""Parity tests for the vectorized selection path.

Three layers, each pinned to its predecessor:

1. ``geohash.encode_batch`` (int64 Morton codes) vs the scalar base32
   ``encode`` across random coordinates and every precision;
2. ``SelectionEngine`` (numpy batched) vs the pre-refactor scalar scorer
   ``candidate_list_scalar`` on the paper topologies;
3. the ``geo_topk`` fused op vs the engine's ranking (kernel-vs-oracle
   parity itself lives in tests/test_kernels.py).
"""
import numpy as np
import pytest

from repro.core import geohash
from repro.core.app_manager import ServiceSpec
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import campus_users, emulation, real_world
from repro.core.selection import SelectionEngine, candidate_list_scalar

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# encode_batch / distance_km_batch vs the scalar primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [1, 2, 3, 4, 6, 9])
def test_encode_batch_matches_scalar_encode(precision):
    lats = RNG.uniform(-89.9, 89.9, 500)
    lons = RNG.uniform(-179.9, 179.9, 500)
    codes = geohash.encode_batch(lats, lons, precision)
    for i in range(0, 500, 7):
        s = geohash.encode(lats[i], lons[i], precision)
        assert geohash.str_to_code(s) == int(codes[i])
        assert geohash.code_to_str(int(codes[i]), precision) == s


def test_shared_prefix_chars_matches_common_prefix():
    lats = RNG.uniform(-60, 60, 200)
    lons = RNG.uniform(-170, 170, 200)
    # mix global pairs with near-identical pairs (long shared prefixes)
    lats[100:] = lats[:100] + RNG.uniform(-1e-4, 1e-4, 100)
    lons[100:] = lons[:100] + RNG.uniform(-1e-4, 1e-4, 100)
    codes = geohash.encode_batch(lats, lons, 9)
    pairs = np.stack([np.arange(100), np.arange(100, 200)])
    got = geohash.shared_prefix_chars(codes[pairs[0]], codes[pairs[1]])
    for n in range(100):
        a = geohash.encode(lats[n], lons[n], 9)
        b = geohash.encode(lats[100 + n], lons[100 + n], 9)
        assert got[n] == geohash.common_prefix(a, b)


def test_distance_km_batch_matches_scalar():
    lats = RNG.uniform(-89, 89, 60)
    lons = RNG.uniform(-179, 179, 60)
    d = geohash.distance_km_batch(lats[:30, None], lons[:30, None],
                                  lats[None, 30:], lons[None, 30:])
    assert d.shape == (30, 30)
    for i in range(0, 30, 5):
        for j in range(0, 30, 5):
            ref = geohash.distance_km(lats[i], lons[i],
                                      lats[30 + j], lons[30 + j])
            assert abs(d[i, j] - ref) < 1e-9


# ---------------------------------------------------------------------------
# SelectionEngine vs the pre-refactor scalar scorer
# ---------------------------------------------------------------------------

def _deployed_system(make_topo, seed=3, replicas=6):
    topo = make_topo()
    sys_ = ArmadaSystem(topo, seed=seed)
    first = next(iter(topo.nodes.values()))
    spec = ServiceSpec("svc", detection_image(), locations=[first.loc],
                       min_replicas=replicas)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=20_000)
    return sys_


@pytest.mark.parametrize("make_topo,users", [
    (real_world, ["C1", "C2", "C3"]),
    (emulation, ["User_A", "User_B", "User_C"]),
])
def test_engine_matches_scalar_on_paper_topologies(make_topo, users):
    sys_ = _deployed_system(make_topo)
    tasks = sys_.am.tasks["svc"]
    for uid in users:
        loc = sys_.topo.nodes[uid].loc
        net = sys_.topo.nodes[uid].net_type
        for top_n in (1, 3, 64):
            want = [t.task_id for t in
                    candidate_list_scalar(tasks, loc, net, top_n)]
            got = [t.task_id for t in
                   sys_.am.candidate_list("svc", loc, net, top_n=top_n)]
            assert got == want


def test_engine_matches_scalar_on_random_fleet():
    sys_ = _deployed_system(real_world)
    users = campus_users(sys_.topo, 25, seed=5)
    tasks = sys_.am.tasks["svc"]
    eng = SelectionEngine(top_n=3)
    for uid in users:
        loc = sys_.topo.nodes[uid].loc
        net = sys_.topo.nodes[uid].net_type
        want = [t.task_id for t in candidate_list_scalar(tasks, loc, net, 3)]
        got = [t.task_id for t in eng.candidate_list("svc", tasks, loc, net)]
        assert got == want


def test_batched_equals_per_user():
    sys_ = _deployed_system(real_world)
    users = campus_users(sys_.topo, 40, seed=9)
    locs = [sys_.topo.nodes[u].loc for u in users]
    nets = [sys_.topo.nodes[u].net_type for u in users]
    batched = sys_.beacon.query_service_batch("svc", locs, nets)
    assert len(batched) == len(users)
    for loc, net, row in zip(locs, nets, batched):
        want = sys_.am.candidate_list("svc", loc, net)
        assert [t.task_id for t in row] == [t.task_id for t in want]


def test_engine_tracks_replica_and_liveness_changes():
    sys_ = _deployed_system(real_world)
    loc = sys_.topo.nodes["C1"].loc
    before = sys_.am.candidate_list("svc", loc, "wifi", top_n=64)
    assert before
    # kill the top node: the mask must drop it with no explicit invalidate
    top = before[0].captain
    top.fail()
    after = sys_.am.candidate_list("svc", loc, "wifi", top_n=64)
    assert all(t.captain is not top for t in after)
    assert [t.task_id for t in after] == \
        [t.task_id for t in candidate_list_scalar(
            sys_.am.tasks["svc"], loc, "wifi", 64)]


def test_engine_cache_reuse_and_invalidate():
    sys_ = _deployed_system(real_world)
    eng = sys_.am.engine
    loc = sys_.topo.nodes["C1"].loc
    sys_.am.candidate_list("svc", loc, "wifi")
    arrays = eng._cache.get("svc")
    assert arrays is not None
    sys_.am.candidate_list("svc", loc, "wifi")
    assert eng._cache.get("svc") is arrays          # cache hit, same arrays
    eng.invalidate("svc")
    assert "svc" not in eng._cache


def test_kernel_path_matches_numpy_engine():
    sys_ = _deployed_system(real_world)
    users = campus_users(sys_.topo, 20, seed=17)
    locs = [sys_.topo.nodes[u].loc for u in users]
    nets = [sys_.topo.nodes[u].net_type for u in users]
    eng = sys_.am.engine
    tasks = sys_.am.tasks["svc"]
    want = eng.candidate_lists("svc", tasks, locs, nets)
    got = eng.candidate_lists_kernel("svc", tasks, locs, nets)
    for w, g in zip(want, got):
        assert [t.task_id for t in g] == [t.task_id for t in w]


def test_kernel_index_path_matches_numpy_indices():
    """`candidate_indices_kernel` (padded, fp32, index-space) ranks like
    the numpy index path — the ClientPool fluid-refresh contract."""
    sys_ = _deployed_system(real_world)
    users = campus_users(sys_.topo, 20, seed=18)
    locs = [sys_.topo.nodes[u].loc for u in users]
    nets = [sys_.topo.nodes[u].net_type for u in users]
    eng = sys_.am.engine
    tasks = sys_.am.tasks["svc"]
    want = eng.candidate_indices("svc", tasks, locs, nets)
    got = eng.candidate_indices_kernel("svc", tasks, locs, nets,
                                       node_pad=8)
    assert got.shape == want.shape          # both honor the (U, k) contract
    np.testing.assert_array_equal(got, want)


def test_empty_and_all_dead_services():
    sys_ = _deployed_system(real_world)
    eng = SelectionEngine()
    assert eng.candidate_list("nope", [], (45.0, -93.0), "wifi") == []
    tasks = sys_.am.tasks["svc"]
    for t in tasks:
        if t.captain is not None:
            t.captain.alive = False
    assert eng.candidate_lists("svc", tasks,
                               [(45.0, -93.0)], "wifi") == [[]]


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------

def test_parse_nets_accepts_integer_sequences():
    """A plain Python list of int net indices used to fall through the
    string branch and silently map every entry to "other"."""
    from repro.core.selection import NET_INDEX, parse_nets
    np.testing.assert_array_equal(parse_nets([0, 1, 2], 3), [0, 1, 2])
    np.testing.assert_array_equal(parse_nets((2, 0), 2), [2, 0])
    np.testing.assert_array_equal(
        parse_nets(np.array([0, 1, 2]), 3), [0, 1, 2])
    np.testing.assert_array_equal(
        parse_nets(["wifi", "lte"], 2),
        [NET_INDEX["wifi"], NET_INDEX["lte"]])
    np.testing.assert_array_equal(parse_nets("lte", 2),
                                  [NET_INDEX["lte"]] * 2)


def test_parse_nets_rejects_out_of_range_indices():
    from repro.core.selection import parse_nets
    with pytest.raises(ValueError, match="out of range"):
        parse_nets([0, 7], 2)
    with pytest.raises(ValueError, match="out of range"):
        parse_nets(np.array([-1, 0]), 2)
    with pytest.raises(ValueError, match="entries for"):
        parse_nets([0, 1], 3)


def test_cloud_replica_visible_to_device_path_immediately():
    """``ensure_cloud_replica`` is an out-of-band task insertion; it must
    route through engine invalidation so the device-resident
    ``packed_static`` cache cannot serve pre-insertion node arrays on the
    very next query."""
    sys_ = _deployed_system(real_world)
    loc = sys_.topo.nodes["C1"].loc
    # warm the device-resident padded cache
    warm = sys_.am.engine.candidate_indices_kernel(
        "svc", sys_.am.tasks["svc"], [loc], "wifi", top_n=64, node_pad=8)
    assert (warm >= 0).any()
    for t in sys_.am.tasks["svc"]:          # only the cloud will remain
        if t.captain is not None:
            t.captain.fail()
    task = sys_.ensure_cloud_replica("svc")
    assert task is not None
    cloud_pos = sys_.am.tasks["svc"].index(task)
    got = sys_.am.engine.candidate_indices_kernel(
        "svc", sys_.am.tasks["svc"], [loc], "wifi", top_n=64, node_pad=8)
    assert got[0, 0] == cloud_pos, \
        "device path served a stale pre-insertion replica set"
    # numpy path agrees
    got_np = sys_.am.candidate_indices("svc", [loc], "wifi", top_n=64)
    assert got_np[0, 0] == cloud_pos


def test_scale_down_survives_dead_captains():
    sys_ = _deployed_system(real_world, replicas=6)
    tasks = [t for t in sys_.am.tasks["svc"] if t.status == "running"]
    assert len(tasks) > 3
    tasks[0].captain.fail()             # dead captain in the running list
    sys_.am.scale_down("svc")           # must not probe the dead captain
    cancelled = [t for t in sys_.am.tasks["svc"] if t.status == "cancelled"]
    assert all(t.captain.alive for t in cancelled)


@pytest.mark.slow
def test_engine_matches_scalar_at_scale():
    """2k-user x 200-node parity sweep (excluded from tier-1 by marker)."""
    from benchmarks.bench_selection_scale import _fleet, _users
    tasks = _fleet(200, seed=2)
    locs, nets = _users(2000, seed=2)
    eng = SelectionEngine(top_n=3)
    batched = eng.candidate_lists("bench", tasks, locs, nets)
    for i in range(0, 2000, 41):
        want = candidate_list_scalar(tasks, tuple(locs[i]), nets[i], 3)
        assert [t.task_id for t in batched[i]] == \
            [t.task_id for t in want]


def test_trace_can_be_disabled_for_scale_runs():
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=0, trace_enabled=False)
    spec = ServiceSpec("svc", detection_image(),
                       locations=[topo.nodes["D6"].loc])
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=20_000)
    assert sys_.sim.trace == []
    assert [t for t in sys_.am.tasks["svc"] if t.status == "running"]
