"""Distribution layer: rules engine, divisibility sanitization (hypothesis),
param-spec validity for every arch × mesh, and a reduced-device dry-run
(8 host devices in a subprocess) proving the full pipeline lowers."""
import json
import os
import pathlib
import subprocess
import sys

import pytest
try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st
from jax.sharding import PartitionSpec as PS

from repro.config import MULTI_POD, SHAPES, SINGLE_POD
from repro.configs import assigned_archs, get_config
from repro.distributed.sharding import (make_rules, mesh_axis_size,
                                        param_specs, sanitize_spec)
from repro.models.api import build_model
from repro.models.modules import tree_map_params

ROOT = pathlib.Path(__file__).resolve().parents[1]

MESH_SIZES = {"single": {"data": 16, "model": 16},
              "multi": {"pod": 2, "data": 16, "model": 16}}


@given(dim=st.integers(min_value=1, max_value=10_000),
       k=st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=200, deadline=None)
def test_sanitize_spec_divisibility(dim, k):
    sizes = {"model": k}
    out = sanitize_spec((dim,), PS("model"), sizes)
    if dim % k == 0 and k > 1:
        assert out == PS("model")
    elif k > 1:
        assert out == PS(None)


@pytest.mark.parametrize("arch", assigned_archs())
@pytest.mark.parametrize("mesh_cfg", [SINGLE_POD, MULTI_POD],
                         ids=["single", "multi"])
def test_param_specs_always_divisible(arch, mesh_cfg):
    """Every param leaf's sharding must divide its shape exactly — the
    invariant that made whisper/minicpm/xlstm cells compile."""
    cfg = get_config(arch)
    model = build_model(cfg)
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    for shape_name in ("train_4k", "decode_32k"):
        rules = make_rules(cfg, mesh_cfg, SHAPES[shape_name])
        specs = param_specs(model, rules, sizes)
        decls = model.param_tree()

        def check(path, p):
            spec = _lookup(specs, path)
            for dim, entry in zip(p.shape, list(spec)):
                assert dim % mesh_axis_size(entry, sizes) == 0, \
                    (arch, path, p.shape, spec)
            return None

        tree_map_params(check, decls)


def _lookup(tree, path):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def test_rules_batch_replicated_for_long_500k():
    cfg = get_config("zamba2-7b")
    rules = make_rules(cfg, SINGLE_POD, SHAPES["long_500k"])
    assert rules["batch"] is None                 # batch=1 can't shard
    rules2 = make_rules(cfg, SINGLE_POD, SHAPES["train_4k"])
    assert rules2["batch"] == "data"


def test_rules_moe_expert_placement():
    ds = get_config("deepseek-moe-16b")           # 64 experts % 16 == 0
    r = make_rules(ds, SINGLE_POD, SHAPES["train_4k"])
    assert r["experts"] == "model"
    gk = get_config("grok-1-314b")                # 8 experts % 16 != 0
    r = make_rules(gk, SINGLE_POD, SHAPES["train_4k"])
    assert r["experts"] is None and r["expert_ff"] == "model"


def test_rules_decode_split_kv():
    llama = get_config("llama3-405b")             # kv_heads=8 < 16
    r = make_rules(llama, SINGLE_POD, SHAPES["decode_32k"])
    assert r["kv_heads_act"] is None
    assert r["kv_seq"] == "model"                 # split-KV decode


def test_variants_differ_from_baseline():
    cfg = get_config("qwen3-14b")
    base = make_rules(cfg, SINGLE_POD, SHAPES["train_4k"])
    seqp = make_rules(cfg, SINGLE_POD, SHAPES["train_4k"],
                      variant="seqpar")
    assert seqp["act_seq"] == "model" and base["act_seq"] is None
    z = make_rules(cfg, SINGLE_POD, SHAPES["train_4k"], variant="zero_off")
    assert z["embed"] is None and base["embed"] == "data"


@pytest.mark.slow
def test_dryrun_lite_subprocess():
    """Full dry-run pipeline on 8 fake host devices: lower+compile+roofline
    for a dense train cell and an SSM long-context decode cell."""
    env = dict(os.environ)
    env.update(REPRO_HOST_DEVICES="8", REPRO_MESH_OVERRIDE="4x2;2x2x2",
               PYTHONPATH=str(ROOT / "src"))
    for arch, shape in (("qwen3-1.7b", "decode_32k"),
                        ("xlstm-1.3b", "long_500k")):
        out = ROOT / "artifacts" / "dryrun" / f"{arch}__{shape}__single.json"
        backup = out.read_text() if out.exists() else None
        try:
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                 arch, "--shape", shape, "--mesh", "single", "--force"],
                capture_output=True, text=True, timeout=600, env=env,
                cwd=str(ROOT))
            assert r.returncode == 0, r.stderr[-2000:]
            rec = json.loads(out.read_text())
            assert rec["status"] == "ok"
            assert rec["roofline"]["flops"] > 0
        finally:
            if backup is not None:
                out.write_text(backup)


def test_serve_fast_profile():
    """§Perf cell C: serving profile drops ZeRO only when weights fit."""
    small = get_config("qwen3-14b")        # 0.9 GB/chip TP shard
    r = make_rules(small, SINGLE_POD, SHAPES["decode_32k"],
                   variant="serve_fast")
    assert r["embed"] is None
    big = get_config("llama3-405b")        # 50 GB/chip TP shard
    r = make_rules(big, SINGLE_POD, SHAPES["decode_32k"],
                   variant="serve_fast")
    assert r["embed"] == "data"            # keeps ZeRO
