"""Region-sharded selection: decision-identity with the unsharded engine.

The sharded control plane (paper §3.1's per-region Beacon replicas) must
be a pure execution-strategy change: same (U, k) candidate indices as
the global engine — including users in the border band between regions
and exact score ties across a shard boundary — on the numpy path, the
fused-kernel path, and the device-resident fused tick, across the
Fig. 8/10 scenarios and synthetic boundary-straddling topologies.  Also
pins the per-shard cache adoption (invalidation routed to the changed
region) and the fused tick's border-capacity guard rail.
"""
import numpy as np
import pytest

from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import NodeSpec, Topology, campus_users, real_world
from repro.core.selection import SelectionEngine

SERVICE = "detect"


# ---------------------------------------------------------------------------
# engine-level parity (numpy + kernel paths)
# ---------------------------------------------------------------------------

def _metro_fleet(n_nodes=60, seed=2, spread=0.5):
    from benchmarks.bench_selection_scale import _fleet
    del n_nodes, spread
    return _fleet(60, seed=seed)


def _metro_users(n=300, seed=2):
    from benchmarks.bench_selection_scale import _users
    return _users(n, seed=seed)


@pytest.mark.parametrize("precision", [1, 2, 3, 4])
def test_sharded_numpy_matches_global(precision):
    tasks = _metro_fleet()
    locs, nets = _metro_users()
    want = SelectionEngine(top_n=3).candidate_indices(
        "bench", tasks, locs, nets)
    eng = SelectionEngine(top_n=3, shard_precision=precision)
    got = eng.candidate_indices("bench", tasks, locs, nets)
    np.testing.assert_array_equal(got, want)
    assert len(eng._shard_cache["bench"].shards) >= 1


def test_sharded_kernel_path_matches_global_kernel():
    tasks = _metro_fleet()
    locs, nets = _metro_users(n=80)
    want = SelectionEngine(top_n=3).candidate_indices_kernel(
        "bench", tasks, locs, nets, node_pad=32)
    eng = SelectionEngine(top_n=3, shard_precision=3)
    got = eng.candidate_indices_kernel("bench", tasks, locs, nets,
                                       node_pad=32)
    np.testing.assert_array_equal(got, want)
    assert len(eng._shard_cache["bench"].shards) >= 2


def test_sharded_on_paper_topology_under_liveness_churn():
    """real_world deployment: sharded candidate lists equal global ones,
    and a captain death routes through the dynamic mask identically."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=3, shard_precision=3)
    first = next(iter(topo.nodes.values()))
    sys_.beacon.deploy_application(ServiceSpec(
        "svc", detection_image(), locations=[first.loc], min_replicas=6))
    sys_.sim.run(until=20_000)
    users = campus_users(sys_.topo, 20, seed=5)
    locs = [sys_.topo.nodes[u].loc for u in users]
    nets = [sys_.topo.nodes[u].net_type for u in users]
    tasks = sys_.am.tasks["svc"]
    ref = SelectionEngine(top_n=3)
    for _ in range(2):
        want = ref.candidate_indices("svc", tasks, locs, nets)
        got = sys_.am.candidate_indices("svc", locs, nets)
        np.testing.assert_array_equal(np.asarray(got), want)
        running = [t for t in tasks if t.status == "running"
                   and t.captain is not None and t.captain.alive]
        running[0].captain.fail()           # second lap: one region lost


# ---------------------------------------------------------------------------
# border band + cross-shard ties (satellite: tie parity)
# ---------------------------------------------------------------------------

class _TieTask:
    __slots__ = ("task_id", "service_id", "captain", "status")

    def __init__(self, task_id, captain):
        self.task_id = task_id
        self.service_id = "tie"
        self.captain = captain
        self.status = "running"


def _tie_tasks(specs, seed=0):
    from repro.core.captain import Captain
    from repro.core.sim import Simulator
    sim = Simulator(seed=seed, trace_enabled=False)
    topo = Topology({s.node_id: s for s in specs}, {})
    return [_TieTask(f"tie/t{i}", Captain(sim, topo, s))
            for i, s in enumerate(specs)]


def test_cross_shard_equidistant_tie_resolves_like_global_argsort():
    """Two replicas exactly equidistant from the user, identical free
    slots and net type, in DIFFERENT shards (opposite sides of the 45°
    precision-1 latitude boundary): the sharded engine must return them
    in global task order — the unsharded stable argsort's tie-break."""
    specs = [NodeSpec("hi", (45.7, -93.0), proc_ms=20.0, slots=2),
             NodeSpec("lo", (44.3, -93.0), proc_ms=20.0, slots=2)]
    tasks = _tie_tasks(specs)
    users = [(45.0, -93.0), (45.0, -93.1)]
    want = SelectionEngine(top_n=2).candidate_indices(
        "tie", tasks, users, "wifi")
    np.testing.assert_array_equal(want, [[0, 1], [0, 1]])
    for precision in (1, 2, 3, 4):
        eng = SelectionEngine(top_n=2, shard_precision=precision)
        got = eng.candidate_indices("tie", tasks, users, "wifi")
        np.testing.assert_array_equal(got, want)
        # same tie through the fp32 kernel path (lax.top_k min-index)
        gk = eng.candidate_indices_kernel("tie", tasks, users, "wifi",
                                          node_pad=8)
        np.testing.assert_array_equal(gk, want)


def test_straddling_boundary_widening_crosses_shards():
    """A cluster straddling a precision-3 cell edge (inside one
    precision-2 cell): users just west of the boundary cannot reach the
    hit target in-shard, so the widening must pull candidates from the
    adjacent shard — identically to the global engine."""
    edge = -92.8125            # p3 lon boundary, NOT a p2 boundary
    specs = [NodeSpec(f"W{i}", (44.9 + 0.01 * i, edge - 0.02),
                      proc_ms=20.0, slots=2) for i in range(3)] + \
            [NodeSpec(f"E{i}", (44.9 + 0.01 * i, edge + 0.02),
                      proc_ms=20.0, slots=2) for i in range(3)]
    tasks = _tie_tasks(specs)
    users = [(44.9, edge - 0.01), (44.91, edge - 0.05),
             (44.9, edge + 0.01)]
    want = SelectionEngine(top_n=6).candidate_indices(
        "tie", tasks, users, "wifi")
    # the global filter widened past the shard prefix: east+west mix
    assert {int(i) for i in want[0] if i >= 0} == {0, 1, 2, 3, 4, 5}
    eng = SelectionEngine(top_n=6, shard_precision=3)
    got = eng.candidate_indices("tie", tasks, users, "wifi")
    np.testing.assert_array_equal(got, want)
    gk = eng.candidate_indices_kernel("tie", tasks, users, "wifi",
                                      node_pad=8)
    wk = SelectionEngine(top_n=6).candidate_indices_kernel(
        "tie", tasks, users, "wifi", node_pad=8)
    np.testing.assert_array_equal(gk, wk)


# ---------------------------------------------------------------------------
# shard cache adoption (invalidation routed to the changed region)
# ---------------------------------------------------------------------------

def test_unchanged_shards_adopt_device_caches_across_invalidate():
    from repro.core.captain import Captain
    from repro.core.sim import Simulator
    specs = [NodeSpec(f"A{i}", (44.9 + 0.05 * i, -93.2), proc_ms=20.0,
                      slots=2) for i in range(3)] + \
            [NodeSpec(f"B{i}", (32.8 + 0.05 * i, -96.8), proc_ms=20.0,
                      slots=2) for i in range(3)]
    sim = Simulator(seed=0, trace_enabled=False)
    topo = Topology({s.node_id: s for s in specs}, {})
    caps = {s.node_id: Captain(sim, topo, s) for s in specs}
    tasks = [_TieTask(f"tie/t{i}", caps[s.node_id])
             for i, s in enumerate(specs)]
    eng = SelectionEngine(top_n=3, shard_precision=3)
    eng.candidate_indices_kernel("tie", tasks, [(44.9, -93.2)], "wifi",
                                 node_pad=8)
    before = {sh.code: sh.arrays.packed_static(8)
              for sh in eng._shard_cache["tie"].shards}
    assert len(before) >= 2
    # new replica joins region A only; region B's device cache must survive
    tasks = tasks + [_TieTask("tie/t_new", caps["A0"])]
    eng.invalidate("tie")
    eng.candidate_indices_kernel("tie", tasks, [(44.9, -93.2)], "wifi",
                                 node_pad=8)
    after = {sh.code: sh.arrays.packed_static(8)
             for sh in eng._shard_cache["tie"].shards}
    changed = {c for c in before if after[c] is not before[c]}
    kept = {c for c in before if after[c] is before[c]}
    assert len(changed) == 1 and kept, \
        "invalidation was not routed to the one changed region"


# ---------------------------------------------------------------------------
# pool-level parity (Fig 8/10 scenarios, host + device ticks)
# ---------------------------------------------------------------------------

def _fluid_system(n_nodes=24, seed=0, spread=0.5, shard=None):
    rng = np.random.default_rng(seed)
    nodes = {f"N{i}": NodeSpec(
        f"N{i}", (44.97 + float(rng.uniform(-spread, spread)),
                  -93.22 + float(rng.uniform(-spread, spread))),
        proc_ms=float(rng.uniform(10, 30)),
        slots=int(rng.integers(2, 9)),
        dedicated=bool(rng.random() < 0.2))
        for i in range(n_nodes)}
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False, shard_precision=shard)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def _run_pool(tick, shard, *, n_users=50, seed=0, until=12_000.0, fail=(),
              border_cap=None):
    sys_ = _fluid_system(seed=seed, shard=shard)
    rng = np.random.default_rng(seed + 1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick=tick,
        shard_border_cap=border_cap if border_cap is not None else n_users)
    sys_.sim.at(0.0, pool.start)
    for node, t in fail:
        sys_.fail_node(node, t)
    sys_.sim.run(until=until)
    return pool, sys_


def _assert_decisions_equal(a, b):
    assert a.ticks_run == b.ticks_run
    assert a.requests_sent == b.requests_sent
    assert a.failovers == b.failovers
    np.testing.assert_array_equal(a.cand_task, b.cand_task)
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.pending, b.pending)
    assert list(zip(a.switch_t, a.switch_user, a.switch_from,
                    a.switch_to)) == \
        list(zip(b.switch_t, b.switch_user, b.switch_from, b.switch_to))


def test_sharded_pool_ticks_match_unsharded_fig10_failover():
    """Fig 10 regime with mid-window node deaths: the sharded host tick
    reproduces the unsharded host tick, and the sharded fused device
    tick reproduces the sharded host tick — full decision streams."""
    fail = [("N1", 4_200.0), ("N5", 4_300.0)]
    host_u, _ = _run_pool("host", None, fail=fail)
    host_s, _ = _run_pool("host", 3, fail=fail)
    dev_s, _ = _run_pool("device", 3, fail=fail)
    _assert_decisions_equal(host_s, host_u)
    _assert_decisions_equal(dev_s, host_s)
    assert dev_s.failovers > 0
    assert len(dev_s.switch_t) > 0


def test_sharded_device_tick_compiles_once_under_churn():
    """Churn inside existing regions (fail/recover + a replica join on a
    node whose region already has a shard) must not retrace any fused
    program — per-shard paddings absorb membership changes.  Same
    seed/topology as the parity test above, so the sharded programs are
    already compiled and only retraces would show up."""
    from repro.core import fused_tick
    pool_sys = _fluid_system(seed=0, shard=3)
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 50),
                     -93.22 + rng.uniform(-.5, .5, 50)], axis=1)
    pool = pool_sys.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device", shard_border_cap=50)
    pool_sys.sim.at(0.0, pool.start)
    pool_sys.sim.run(until=2_100.0)
    counts0 = dict(fused_tick.COMPILE_COUNTS)
    pool_sys.fail_node("N2", 2_200.0)
    pool_sys.sim.run(until=4_300.0)
    pool_sys.captains["N2"].recover()
    cap = pool_sys.captains["N4"]
    t = Task(f"{SERVICE}/t_join", SERVICE, captain=cap, status="running",
             ready_at=pool_sys.sim.now)
    cap.tasks[t.task_id] = t
    pool_sys.am.register_task(t)
    pool_sys.sim.run(until=8_100.0)
    assert pool.ticks_run >= 3
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts0.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"sharded fused programs re-traced under churn: {delta}"


def test_sharded_device_tick_border_capacity_guard():
    """Users homed far outside every node region land in the border
    band; a band larger than shard_border_cap must raise with the
    remedy, not silently drop users."""
    sys_ = _fluid_system(n_nodes=8, seed=1, shard=3)
    locs = np.concatenate([
        np.tile((44.97, -93.22), (4, 1)),
        np.tile((10.0, 10.0), (6, 1))])     # no shard anywhere near
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device", shard_border_cap=2)
    sys_.sim.at(0.0, pool.start)
    with pytest.raises(RuntimeError, match="shard_border_cap"):
        sys_.sim.run(until=4_100.0)


# ---------------------------------------------------------------------------
# adversarial topologies (satellite: degenerate shard layouts)
# ---------------------------------------------------------------------------

def _paths_match_unsharded(tasks, users, top_n, precisions=(1, 2, 3, 4)):
    """Numpy + kernel sharded paths against the unsharded engine."""
    want = SelectionEngine(top_n=top_n).candidate_indices(
        "tie", tasks, users, "wifi")
    want_k = SelectionEngine(top_n=top_n).candidate_indices_kernel(
        "tie", tasks, users, "wifi", node_pad=8)
    for precision in precisions:
        eng = SelectionEngine(top_n=top_n, shard_precision=precision)
        got = eng.candidate_indices("tie", tasks, users, "wifi")
        np.testing.assert_array_equal(got, want, err_msg=f"p={precision}")
        gk = eng.candidate_indices_kernel("tie", tasks, users, "wifi",
                                          node_pad=8)
        np.testing.assert_array_equal(gk, want_k, err_msg=f"p={precision}")
    return want


def test_all_invalid_shard_escalates_to_border():
    """A shard whose nodes are ALL dead (every captain failed) must not
    strand its users: they escalate to the cross-shard pass and land on
    the other region, exactly like the unsharded engine."""
    specs = [NodeSpec(f"A{i}", (44.9 + 0.02 * i, -93.2), proc_ms=20.0,
                      slots=2) for i in range(3)] + \
            [NodeSpec(f"B{i}", (32.8 + 0.02 * i, -96.8), proc_ms=20.0,
                      slots=2) for i in range(3)]
    tasks = _tie_tasks(specs)
    for t in tasks[:3]:
        t.captain.fail()                    # region A: all invalid
    users = [(44.9, -93.2), (44.91, -93.21), (32.8, -96.8)]
    want = _paths_match_unsharded(tasks, users, top_n=3)
    # the dead region's users really did cross shards
    assert {int(i) for i in want[0] if i >= 0} <= {3, 4, 5}
    assert (want[0] >= 0).any()


def test_service_with_no_nodes_in_home_region():
    """Users homed in a region with zero replicas anywhere near: their
    home shard does not exist, so every path must agree with the global
    fallback (no filter) of the unsharded engine."""
    specs = [NodeSpec(f"B{i}", (32.8 + 0.02 * i, -96.8), proc_ms=20.0,
                      slots=2) for i in range(4)]
    tasks = _tie_tasks(specs)
    users = [(60.0, 10.0), (44.9, -93.2), (32.8, -96.8)]
    want = _paths_match_unsharded(tasks, users, top_n=3)
    assert (want >= 0).all()                # everyone is served


def test_single_node_global_topology():
    """One replica on Earth: k_eff collapses to 1, every user shares the
    single shard or the border pass — all paths agree."""
    tasks = _tie_tasks([NodeSpec("only", (44.9, -93.2), proc_ms=20.0,
                                 slots=2)])
    users = [(44.9, -93.2), (-33.9, 151.2)]
    want = _paths_match_unsharded(tasks, users, top_n=3)
    np.testing.assert_array_equal(want, [[0, -1, -1], [0, -1, -1]])


def test_device_tick_all_border_matches_unsharded_device():
    """A population homed entirely outside every node region (the whole
    pool rides the fixed-capacity border pass every tick) must decide
    exactly like the unsharded fused tick."""
    def run(shard):
        sys_ = _fluid_system(seed=0, shard=shard)
        rng = np.random.default_rng(9)
        locs = np.stack([10.0 + rng.uniform(-.2, .2, 50),
                         10.0 + rng.uniform(-.2, .2, 50)], axis=1)
        pool = sys_.make_client_pool(
            SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
            selection_backend="geo_topk", tick="device",
            shard_border_cap=50)
        sys_.sim.at(0.0, pool.start)
        sys_.sim.run(until=6_100.0)
        return pool
    _assert_decisions_equal(run(3), run(None))


def test_bench_sharded_selection_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1 (it
    asserts sharded == global internally before timing)."""
    from benchmarks.bench_sharded_selection import run
    rows = run(smoke=True)
    assert rows and rows[0][1] > 0
    assert "work_frac=" in rows[1][2] and "shards=" in rows[1][2]
