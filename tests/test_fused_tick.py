"""Device-resident fused tick vs the host numpy tick: decision-stream
parity on the paper's Fig. 8/10 scenarios, jit-shape stability under
churn, and the device-mode guard rails.

The fused program must reproduce the host fluid tick EXACTLY in every
decision — candidate matrices, active/pending assignments, switch
records (time, user, from, to), failover counts, request counts — and
match EMAs/latency aggregates to fp32 rounding (the host folds in
float64).  Scoring parity is by construction (both paths consume
bit-identical fp32 inputs through the geo_topk math); this file pins the
whole tick, including the sequential break replay and two-round switch.
"""
import numpy as np
import pytest

from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import NodeSpec, Topology

SERVICE = "detect"


def _fluid_system(n_nodes=24, seed=0, spread=0.5):
    """Metro fleet with one running replica per node (Fig 8-style node
    sets; failures injected per test recreate the Fig 10 trajectories)."""
    rng = np.random.default_rng(seed)
    nodes = {f"N{i}": NodeSpec(
        f"N{i}", (44.97 + float(rng.uniform(-spread, spread)),
                  -93.22 + float(rng.uniform(-spread, spread))),
        proc_ms=float(rng.uniform(10, 30)),
        slots=int(rng.integers(2, 9)),
        dedicated=bool(rng.random() < 0.2))
        for i in range(n_nodes)}
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def _run_pool(tick, *, n_users=50, n_nodes=24, seed=0, until=12_000.0,
              fail=(), frame_interval=500.0, profiled=False,
              queueing=False, slots=None, workload_scale=1.0):
    sys_ = _fluid_system(n_nodes, seed)
    if slots is not None:                 # force capacity (saturation tests)
        for cap in sys_.captains.values():
            cap.spec.slots = slots
    if profiled:
        # heterogeneous serving profiles (detector / facerec / llm-decode
        # round-robin, speed scaled off each node's proc_ms); calibration={}
        # pins the deterministic fallback unit times
        from repro.serving.profile import attach_profiles
        attach_profiles(sys_.captains.values(), calibration={})
    if queueing:
        sys_.am.engine.set_queueing_awareness(SERVICE)
    rng = np.random.default_rng(seed + 1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid",
        frame_interval_ms=frame_interval, selection_backend="geo_topk",
        tick=tick, workload_scale=workload_scale)
    sys_.sim.at(0.0, pool.start)
    for node, t in fail:
        sys_.fail_node(node, t)
    sys_.sim.run(until=until)
    return pool, sys_


def _assert_tick_parity(host, dev, n_users):
    assert host.ticks_run == dev.ticks_run
    assert host.requests_sent == dev.requests_sent
    assert host.failovers == dev.failovers
    np.testing.assert_array_equal(host.cand_task, dev.cand_task)
    np.testing.assert_array_equal(host.active, dev.active)
    np.testing.assert_array_equal(host.pending, dev.pending)
    want = list(zip(host.switch_t, host.switch_user, host.switch_from,
                    host.switch_to))
    got = list(zip(dev.switch_t, dev.switch_user, dev.switch_from,
                   dev.switch_to))
    assert want == got, "switch records diverge"
    # fold the open window on BOTH sides before comparing EMA tables
    # (mean_latency flushes the host fluid buffer / the device stash)
    np.testing.assert_allclose(host.mean_latency(), dev.mean_latency(),
                               rtol=1e-4)
    for u in range(n_users):
        a, b = host.ema_of(u), dev.ema_of(u)
        assert set(a) == set(b), f"user {u}: EMA key set diverges"
        for node in a:
            np.testing.assert_allclose(a[node], b[node], rtol=1e-4)


def test_device_tick_matches_host_fig8_steady_state():
    """Fig 8 regime: steady metro fleet, probes + frames + two-round
    switches — decision stream identical, EMAs to fp32 rounding."""
    host, _ = _run_pool("host", until=14_000.0)
    dev, _ = _run_pool("device", until=14_000.0)
    _assert_tick_parity(host, dev, 50)
    assert len(dev.switch_t) > 0          # the scenario actually switches
    assert dev.ticks_run >= 6


def test_device_tick_matches_host_fig10_failover():
    """Fig 10 regime: nodes die mid-run (some within one window) — the
    queued break replay must reproduce the host's instant failovers."""
    fail = [("N1", 4_200.0), ("N5", 4_300.0), ("N9", 6_500.0),
            ("N2", 6_600.0)]
    host, _ = _run_pool("host", until=14_000.0, fail=fail)
    dev, _ = _run_pool("device", until=14_000.0, fail=fail)
    _assert_tick_parity(host, dev, 50)
    assert dev.failovers > 0


def test_device_tick_matches_host_under_volunteer_churn():
    """Fail/recover cycles: recovered nodes re-enter selection, EMAs are
    popped per break — both ticks stay locked step for the whole run."""
    host, hs = _run_pool("host", until=10_000.0,
                         fail=[("N3", 3_100.0), ("N7", 5_100.0)])
    dev, ds = _run_pool("device", until=10_000.0,
                        fail=[("N3", 3_100.0), ("N7", 5_100.0)])
    for s in (hs, ds):
        s.captains["N3"].recover()
        s.sim.run(until=18_000.0)
    _assert_tick_parity(host, dev, 50)


def test_device_tick_matches_host_with_profiles_and_queueing():
    """Serving-aware regime: heterogeneous ServingProfiles set per-node
    unit times, the fleet is driven into saturation (6 single-slot nodes,
    4x workload) so the queueing-aware load fold is numerically active —
    and the
    fused device tick must still reproduce the host decision stream
    exactly (the fold happens in ``dynamic_state``, upstream of both)."""
    # slots=1 + 4x workload on 6 nodes saturates; workload_scale is a
    # runtime scalar and U/nf/node_pad stay at the suite defaults, so the
    # device run reuses the already-compiled fused programs
    hot = dict(until=14_000.0, n_nodes=6, slots=1, workload_scale=4.0,
               profiled=True, queueing=True)
    host, hs = _run_pool("host", **hot)
    dev, _ = _run_pool("device", **hot)
    _assert_tick_parity(host, dev, 50)
    assert dev.ticks_run >= 6
    # the term was genuinely active: backlog built up...
    assert max(c.queueing_delay_ms() for c in hs.captains.values()) > 0.0
    # ...and queueing awareness changed at least one decision vs baseline
    base, _ = _run_pool("host", **{**hot, "queueing": False})
    assert not np.array_equal(base.active, host.active) or \
        list(base.switch_t) != list(host.switch_t) or \
        (base.cand_task != host.cand_task).any()


def test_numpy_kernel_parity_with_queueing_backlog():
    """numpy vs geo_topk index path with the occupancy term active and a
    real injected backlog: a third of the fleet is saturated, so the
    queueing fold moves scores — both paths must still rank identically."""
    sys_ = _fluid_system(24, seed=6)
    from repro.serving.profile import attach_profiles
    attach_profiles(sys_.captains.values(), calibration={})
    sys_.am.engine.set_queueing_awareness(SERVICE)
    for i, cap in enumerate(sys_.captains.values()):
        if i % 3 == 0:                    # drown every third node
            cap.arrive_batch(400.0, 1.0, 1_000.0, 0.0)
    rng = np.random.default_rng(7)
    locs = [(44.97 + float(rng.uniform(-.5, .5)),
             -93.22 + float(rng.uniform(-.5, .5))) for _ in range(20)]
    eng = sys_.am.engine
    tasks = sys_.am.tasks[SERVICE]
    want = eng.candidate_indices(SERVICE, tasks, locs, "wifi")
    got = eng.candidate_indices_kernel(SERVICE, tasks, locs, "wifi",
                                       node_pad=32)
    np.testing.assert_array_equal(got, want)
    # the saturated nodes actually carry a queueing signal
    qs = [cap.queueing_delay_ms() for cap in sys_.captains.values()]
    assert max(qs) > 0.0


def test_device_tick_compiles_once_under_churn():
    """Shape stability: node failures, recoveries AND a replica join
    (within the node_pad) must not retrigger tracing of any fused
    program — a recompiling tick would silently serialize the loop."""
    from repro.core import fused_tick
    sys_ = _fluid_system(16, seed=2)
    rng = np.random.default_rng(3)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 50),
                     -93.22 + rng.uniform(-.5, .5, 50)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device")
    sys_.sim.at(0.0, pool.start)
    sys_.sim.run(until=2_100.0)           # start + first full tick traced
    counts0 = dict(fused_tick.COMPILE_COUNTS)

    sys_.fail_node("N2", 2_200.0)
    sys_.fail_node("N6", 4_300.0)
    sys_.sim.run(until=6_000.0)
    sys_.captains["N2"].recover()
    # volunteer join: a fresh replica appears on a live node (new task,
    # new node-epoch — static arrays rebuild, shapes must not change)
    cap = sys_.captains["N4"]
    t = Task(f"{SERVICE}/t_join", SERVICE, captain=cap, status="running",
             ready_at=sys_.sim.now)
    cap.tasks[t.task_id] = t
    sys_.am.tasks[SERVICE].append(t)
    sys_.am.engine.invalidate(SERVICE)
    sys_.sim.run(until=14_000.0)
    assert pool.ticks_run >= 6
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts0.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"fused programs re-traced under churn: {delta}"


def test_device_tick_phase_breakdown_recorded():
    # default shapes on purpose: reuses the parity tests' compiled
    # programs (every fused-tick test shares U=50 / node_pad=256 / nf=4)
    dev, _ = _run_pool("device", until=4_100.0)
    assert "fused_tick" in dev.phase_ms and "transport" in dev.phase_ms
    host, _ = _run_pool("host", until=4_100.0)
    assert {"selection", "policy", "transport"} <= set(host.phase_ms)


def test_device_tick_guard_rails():
    sys_ = _fluid_system(8, seed=1)
    locs = np.zeros((4, 2)) + (44.97, -93.22)
    for kw, msg in [
            (dict(transport="events", selection_backend="numpy"),
             "tick='device'"),
            (dict(transport="fluid", selection_backend="numpy"),
             "geo_topk"),
            (dict(transport="fluid", selection_backend="geo_topk",
                  mode="cloud"), "armada")]:
        with pytest.raises(ValueError, match=msg):
            sys_.make_client_pool(SERVICE, locs=locs, tick="device",
                                  frame_interval_ms=500.0, **kw)


@pytest.mark.slow
def test_device_tick_survives_total_candidate_loss_and_recovery():
    """Kill the whole fleet, then bring one node back: users re-enter
    initial selection at the next tick and traffic resumes."""
    sys_ = _fluid_system(6, seed=4, spread=0.05)
    rng = np.random.default_rng(5)
    locs = np.stack([44.97 + rng.uniform(-.05, .05, 50),
                     -93.22 + rng.uniform(-.05, .05, 50)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device")
    sys_.sim.at(0.0, pool.start)
    for i in range(6):
        sys_.fail_node(f"N{i}", 3_000.0 + 10 * i)
    sys_.sim.run(until=5_000.0)
    assert (pool.active == -1).all()
    sys_.captains["N0"].recover()
    sys_.sim.run(until=12_000.0)
    assert (pool.active >= 0).all()
    assert np.isfinite(pool.mean_latency())


# ---------------------------------------------------------------------------
# switch-confirmation starvation (ROADMAP regression, filed from PR 9)
# ---------------------------------------------------------------------------

def _starved_system(n_thin=23, seed=2):
    """One desirable-looking node whose single slot drowns under load,
    ringed by near-tied thin alternatives: every user wants out of HOT,
    but the thin nodes' EMAs stay within jitter of each other so the
    instantaneous per-tick argmin rotates — the exact regime where the
    old confirm-against-fresh-argmin rule starved every switch."""
    nodes = {"HOT": NodeSpec("HOT", (44.97, -93.22), proc_ms=12.0,
                             slots=1)}
    for i in range(n_thin):
        ang = 2 * np.pi * i / n_thin
        nodes[f"T{i}"] = NodeSpec(
            f"T{i}", (44.97 + 0.3 * float(np.cos(ang)),
                      -93.22 + 0.3 * float(np.sin(ang))),
            proc_ms=20.0, slots=2)
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


# 50 users x 24 nodes x 500 ms frames matches _run_pool's shapes AND
# static config, so the device program compiled by earlier tests in
# this session is reused here (a fresh shape would recompile ~5 s)
def _run_starved(tick, backend, *, n_users=50, until=20_000.0):
    sys_ = _starved_system()
    rng = np.random.default_rng(3)
    locs = np.stack([44.97 + rng.uniform(-.02, .02, n_users),
                     -93.22 + rng.uniform(-.02, .02, n_users)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend=backend, tick=tick, workload_scale=12.0)
    sys_.sim.at(0.0, pool.start)
    sys_.sim.run(until=until)
    pool.mean_latency()         # flush: sync device actives to the host
    hot_task = next(i for i, t in enumerate(sys_.am.tasks[SERVICE])
                    if t.captain.node_id == "HOT")
    return pool, hot_task


def test_switch_starvation_near_tie_evacuates_all_paths():
    """The drowned node empties on every tick path, and the decision
    streams stay locked: host numpy == geo_topk kernel == fused device.
    (The mesh driver consumes the same device decision code;
    tests/_mesh_child.py pins its stream against the device's.)"""
    runs = {
        "host-numpy": _run_starved("host", "numpy"),
        "host-kernel": _run_starved("host", "geo_topk"),
        "device": _run_starved("device", "geo_topk"),
    }
    base_pool, hot_task = runs["host-numpy"]
    # the crowd initially lands on the fast nearby node...
    first_active = np.asarray(
        [base_pool.switch_from[base_pool.switch_user.index(u)]
         for u in set(base_pool.switch_user)])
    assert (first_active == "HOT").mean() > 0.5
    for name, (pool, hot) in runs.items():
        stranded = int((pool.active == hot).sum())
        assert stranded <= 6, \
            f"{name}: {stranded} users starved on the drowned node"
        assert len(pool.switch_t) >= 32, f"{name}: too few switches"
    _assert_tick_parity(runs["host-kernel"][0], runs["device"][0], 50)
    a, b = runs["host-numpy"][0], runs["host-kernel"][0]
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.pending, b.pending)
    assert list(a.switch_t) == list(b.switch_t)


# ---------------------------------------------------------------------------
# in-situ data plane (data_profile): identity + effect
# ---------------------------------------------------------------------------

def _data_system(n_nodes=24, seed=0):
    from repro.core.storage.cargo import Cargo
    sys_ = _fluid_system(n_nodes, seed)
    for nid in ("N0", "N3", "N7"):
        cg = Cargo(sys_.sim, sys_.topo, sys_.topo.nodes[nid])
        sys_.cargos[nid] = cg
        sys_.beacon.register_cargo(cg)
    spec = ServiceSpec(SERVICE, detection_image(), need_storage=True,
                       locations=[sys_.topo.nodes["N0"].loc])
    sys_.cargo_manager.store_register(spec, initial={"k": bytes(1024)})
    return sys_


def _run_data_pool(tick, *, profile, n_users=50, until=14_000.0,
                   backend="geo_topk"):
    from repro.core.storage.cargo_manager import DataProfile
    sys_ = _data_system()
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)
    kw = {}
    if profile:
        kw["data_profile"] = DataProfile(2.0, 0.5, "strong")
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend=backend, tick=tick, **kw)
    sys_.sim.at(0.0, pool.start)
    sys_.sim.run(until=until)
    return pool, sys_


def test_data_term_decision_identity_host_kernel_device():
    """With the in-situ data term active the decision streams stay
    locked across host numpy, the geo_topk kernel, and the fused device
    tick: the (U,) data_ms is computed host-side once per window and
    injected into every backend identically."""
    host, hs = _run_data_pool("host", profile=True)
    kern, _ = _run_data_pool("host", profile=True, backend="geo_topk")
    dev, ds = _run_data_pool("device", profile=True)
    _assert_tick_parity(kern, dev, 50)
    np.testing.assert_array_equal(host.active, kern.active)
    np.testing.assert_array_equal(host.cand_task, kern.cand_task)
    assert list(host.switch_t) == list(kern.switch_t)
    # the charge-back side is identical too: same read totals, same
    # measured rates on every replica
    for nid in hs.cargos:
        assert hs.cargos[nid].reads_total == ds.cargos[nid].reads_total
        np.testing.assert_allclose(hs.cargos[nid].read_rate,
                                   ds.cargos[nid].read_rate)
    assert sum(c.reads_total for c in hs.cargos.values()) > 0, \
        "scenario never charged a read"


def test_data_term_changes_latency_and_decisions():
    """The fold is genuinely active: with a data profile the frame
    latencies include the Cargo hop (mean strictly above the data-less
    run) and at least one selection decision moves toward data."""
    on, _ = _run_data_pool("host", profile=True)
    off, _ = _run_data_pool("host", profile=False)
    assert on.requests_sent == off.requests_sent
    assert on.mean_latency() > off.mean_latency() + 1.0
    assert (not np.array_equal(on.active, off.active)
            or list(on.switch_t) != list(off.switch_t)
            or (on.cand_task != off.cand_task).any())
