"""Subprocess body for tests/test_mesh_scale.py.

The parent sets ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
in the environment BEFORE this interpreter starts (the flag is read at
jax initialisation, which is why the comparison cannot run in-process
with the tier-1 suite).  Two identical scenarios are driven end-to-end
— node churn (fail + recover, replayed through the fused break queue)
followed by a Beacon fault-domain failover and recovery — once on the
single-device fused tick and once on the 4-device mesh-sharded tick,
and the decision streams must match exactly: candidate matrices,
actives, pending, switch records, failover counts, EMA tables (fp32
rounding).  The in-situ storage data plane is active throughout
(``data_profile`` + two regional Cargos), so the parity pin also covers
the host-computed per-user data term and its read charge-back.  A band of users placed midway between two metros sits
outside every home shard: on the mesh they straddle a device boundary
and are served through the fixed-capacity border pass.

Usage: ``python tests/_mesh_child.py [n_users] [nodes_per_region]
[refresh_period_ms]`` — a non-zero third argument runs BOTH sides with
incremental candidate refresh (``refresh_period_ms``) and additionally
pins the host-side dirty-count stream single == mesh.  Prints one
``##OUT##{json}`` line on success; any parity violation raises and
fails the parent test with this traceback.
"""
import json
import sys

import numpy as np

REGIONS = ((44.97, -93.22), (41.88, -87.63), (39.74, -104.99),
           (32.78, -96.80))
SHARD_PRECISION = 3
SERVICE = "detect"
PROBE_MS = 2000.0
N_BORDER = 8


def _system(n_per_region: int, seed: int):
    from repro.core.app_manager import ServiceSpec, Task
    from repro.core.beacon import ArmadaSystem, detection_image
    from repro.core.cluster import NodeSpec, Topology

    rng = np.random.default_rng(seed)
    nodes = {}
    for r, base in enumerate(REGIONS):
        for i in range(n_per_region):
            nid = f"R{r}N{i}"
            nodes[nid] = NodeSpec(
                nid, (base[0] + float(rng.uniform(-0.5, 0.5)),
                      base[1] + float(rng.uniform(-0.5, 0.5))),
                proc_ms=float(rng.uniform(10, 30)),
                slots=int(rng.integers(2, 9)),
                dedicated=bool(rng.random() < 0.2))
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False,
                        shard_precision=SHARD_PRECISION,
                        beacon_heartbeat_ms=1.5 * PROBE_MS)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    # in-situ storage: Cargos in two of the four regions, so the
    # per-user data term varies across shards (users in R2/R3 pay a
    # longer replica hop than R0/R1) and the mesh parity pin covers the
    # host-computed data_ms injection end-to-end
    from repro.core.storage.cargo import Cargo
    for nid in ("R0N0", "R1N0"):
        cg = Cargo(sys_.sim, sys_.topo, sys_.topo.nodes[nid])
        sys_.cargos[nid] = cg
        sys_.beacon.register_cargo(cg)
    spec = ServiceSpec(SERVICE, detection_image(), need_storage=True,
                       locations=[sys_.topo.nodes["R0N0"].loc])
    sys_.cargo_manager.store_register(spec, initial={"k": bytes(1024)})
    return sys_


def _locs(n_users: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    region = rng.integers(0, len(REGIONS), n_users - N_BORDER)
    base = np.asarray(REGIONS)[region]
    clustered = base + rng.uniform(-0.5, 0.5, (n_users - N_BORDER, 2))
    # border band: midway between two metros — outside every home shard
    # at shard precision, so these escalate to the full border pass (and
    # straddle a device boundary on the mesh)
    a, b = np.asarray(REGIONS[0]), np.asarray(REGIONS[1])
    mid = a + (b - a) * np.linspace(0.45, 0.55, N_BORDER)[:, None]
    return np.concatenate([clustered, mid], axis=0)


def _run(mesh, n_users: int, n_per: int, refresh_ms: float = 0.0):
    import repro.core.fused_tick as fused_tick
    from repro.core.storage.cargo_manager import DataProfile

    sys_ = _system(n_per, seed=0)
    # serving-aware scoring active on BOTH sides: mesh parity covers the
    # queueing-delay fold in dynamic_state (single == mesh by construction)
    sys_.am.engine.set_queueing_awareness(SERVICE)
    kw = {"refresh_period_ms": refresh_ms} if refresh_ms else {}
    # the Beacon failover floods the border band with the dead domain's
    # users — size the cap for the whole affected region
    pool = sys_.make_client_pool(
        SERVICE, locs=_locs(n_users, seed=0), transport="fluid",
        frame_interval_ms=500.0, selection_backend="geo_topk",
        tick="device", mesh=mesh,
        data_profile=DataProfile(1.0, 0.2, "eventual"),
        shard_border_cap=max(256, n_users // 2), **kw)
    sys_.sim.at(0.0, pool.start)
    sys_.fail_node("R0N1", 4_200.0)
    sys_.fail_node("R1N2", 4_300.0)

    sys_.sim.run(until=2_100.0)          # start + first full tick traced
    counts0 = dict(fused_tick.COMPILE_COUNTS)
    sys_.sim.run(until=6_000.0)          # both failures replayed
    sys_.captains["R0N1"].recover()
    sys_.sim.run(until=7_000.0)
    churn_delta = {k: fused_tick.COMPILE_COUNTS[k] - counts0.get(k, 0)
                   for k in fused_tick.COMPILE_COUNTS
                   if fused_tick.COMPILE_COUNTS[k] != counts0.get(k, 0)}

    # Beacon fault-domain failover + recovery: ownership merges, users
    # hand off (mesh: re-home across device boundaries), then re-home
    # back when the domain returns
    region = sys_.beacons.busiest_region()
    sys_.fail_beacon(region, 7_900.0)
    sys_.recover_beacon(region, 13_900.0)
    sys_.sim.run(until=14_000.0)
    # a node coming back near its old users beats their failover target
    # by the switch margin -> two-round switches on the final ticks
    sys_.captains["R1N2"].recover()
    sys_.sim.run(until=20_100.0)
    assert not sys_.sim.truncated
    return pool, churn_delta, sys_


def _assert_parity(host, dev, n_users: int) -> None:
    assert host.ticks_run == dev.ticks_run
    assert host.requests_sent == dev.requests_sent
    assert host.failovers == dev.failovers
    np.testing.assert_array_equal(host.cand_task, dev.cand_task)
    np.testing.assert_array_equal(host.active, dev.active)
    np.testing.assert_array_equal(host.pending, dev.pending)
    want = list(zip(host.switch_t, host.switch_user, host.switch_from,
                    host.switch_to))
    got = list(zip(dev.switch_t, dev.switch_user, dev.switch_from,
                   dev.switch_to))
    assert want == got, "switch records diverge"
    np.testing.assert_allclose(host.mean_latency(), dev.mean_latency(),
                               rtol=1e-4)
    sample = sorted(set(range(0, n_users, max(1, n_users // 96))) |
                    set(range(n_users - N_BORDER, n_users)))
    for u in sample:
        a, b = host.ema_of(u), dev.ema_of(u)
        assert set(a) == set(b), f"user {u}: EMA key set diverges"
        for node in a:
            np.testing.assert_allclose(a[node], b[node], rtol=1e-4)


def main() -> None:
    n_users = int(sys.argv[1]) if len(sys.argv) > 1 else 2_000
    n_per = int(sys.argv[2]) if len(sys.argv) > 2 else 16
    refresh_ms = float(sys.argv[3]) if len(sys.argv) > 3 else 0.0
    import jax
    assert len(jax.devices()) >= 4, jax.devices()

    single, _, sys_s = _run(None, n_users, n_per, refresh_ms)
    mesh, churn_delta, sys_m = _run(4, n_users, n_per, refresh_ms)
    assert mesh._dev._sharded, "mesh driver should be region-sharded"
    _assert_parity(single, mesh, n_users)

    # the in-situ data plane charged identically on both paths: same
    # read totals and measured rates on every Cargo replica
    reads = 0
    for nid in sys_s.cargos:
        assert (sys_s.cargos[nid].reads_total
                == sys_m.cargos[nid].reads_total), f"{nid} reads diverge"
        np.testing.assert_allclose(sys_s.cargos[nid].read_rate,
                                   sys_m.cargos[nid].read_rate)
        reads += sys_s.cargos[nid].reads_total
    assert reads > 0, "data term never charged a read"

    # the border band is outside every home shard yet fully served —
    # identically on both paths (covered by the parity assert above)
    border = np.arange(n_users - N_BORDER, n_users)
    assert (mesh.active[border] >= 0).all(), "border users unserved"

    # one SPMD trace per mesh program: node churn is content, not shape
    mesh_delta = {k: v for k, v in churn_delta.items()
                  if k.startswith("mesh_")}
    assert not mesh_delta, f"mesh programs re-traced under churn: " \
                           f"{mesh_delta}"

    out = {
        "ok": True,
        "ticks": single.ticks_run,
        "switches": len(single.switch_t),
        "failovers": single.failovers,
        "border_users": int(border.size),
        "cargo_reads": int(reads),
    }
    if refresh_ms:
        # the host-side dirty tracker is shared logic: the mesh driver
        # must refresh exactly the users the single-device driver does
        assert single._rt.dirty_counts == mesh._rt.dirty_counts, \
            "dirty-count streams diverge single vs mesh"
        out["dirty_total"] = int(sum(mesh._rt.dirty_counts))
        out["dirty_frac"] = float(sum(mesh._rt.dirty_counts) /
                                  (n_users * max(1, mesh.ticks_run)))
        out["fallbacks"] = int(mesh._rt.fallbacks)
    print("##OUT##" + json.dumps(out))


if __name__ == "__main__":
    main()
