"""Cargo storage layer: replication count, consistency semantics,
data-access-point selection, failover, and storage auto-scaling."""
import numpy as np
import pytest

from repro.core.app_manager import ServiceSpec
from repro.core.beacon import ArmadaSystem, facerec_image
from repro.core.cluster import real_world


def _system(cargo_nodes=("V1", "V2", "D6", "Cloud")):
    topo = real_world()
    return ArmadaSystem(topo, seed=9, compute_nodes=["V3", "V4", "V5"],
                        cargo_nodes=list(cargo_nodes))


def _register(sys_, consistency="eventual"):
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       consistency=consistency,
                       locations=[sys_.topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(
        spec, initial={"k0": b"v0"})
    return spec, chosen


def test_store_register_allocates_three_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_)
    assert len(chosen) == 3
    for c in chosen:
        assert c.stores["face"]["k0"] == b"v0"
        assert len(c.peers["face"]) == 2


def test_eventual_write_acks_fast_then_converges():
    sys_ = _system()
    spec, chosen = _register(sys_)
    lat = []
    chosen[0].write("face", "k1", b"v1", "V3", "eventual", lat.append)
    sys_.sim.run(until=60.0)                 # local ack: ~rtt + write
    assert lat and lat[0] < 60.0
    sys_.sim.run(until=2_000.0)              # cascade completes
    for c in chosen:
        assert c.stores["face"]["k1"] == b"v1"


def test_strong_write_waits_for_all_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    strong, eventual = [], []
    chosen[0].write("face", "ks", b"v", "V3", "strong", strong.append)
    sys_.sim.run(until=5_000.0)
    # all replicas have it at ack time recorded; latency >= slowest hop
    assert strong
    chosen[0].write("face", "ke", b"v", "V3", "eventual", eventual.append)
    sys_.sim.run(until=10_000.0)
    assert eventual[0] < strong[0]


def test_cargo_discover_ranks_by_proximity():
    sys_ = _system()
    spec, chosen = _register(sys_)
    cands = sys_.cargo_manager.cargo_discover("face",
                                              sys_.topo.nodes["V5"].loc)
    assert 1 <= len(cands) <= 3
    assert all(c.alive for c in cands)


def test_dead_replica_skipped_not_blocking():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    chosen[1].fail()
    lat = []
    chosen[0].write("face", "k2", b"v2", "V3", "strong", lat.append)
    sys_.sim.run(until=5_000.0)
    assert lat, "strong write must still ack when a replica is dead"
    alive = [c for c in chosen if c.alive]
    for c in alive:
        assert c.stores["face"].get("k2") == b"v2"


def test_storage_autoscaling_follows_compute():
    """A service replica placed far from all data replicas triggers a new
    data replica nearby (paper §3.4 storage auto-scaling)."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9,
                        compute_nodes=["V3", "V4", "V5", "Cloud"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       locations=[topo.nodes["V3"].loc])
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=30_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert len(placements) >= 3
