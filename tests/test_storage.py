"""Cargo storage layer: replication count, consistency semantics,
data-access-point selection, failover, storage auto-scaling, capacity
accounting, and the vectorized data plane (``data_ms_for_nodes``)."""
import numpy as np
import pytest

from repro.core import geohash
from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, facerec_image
from repro.core.cluster import real_world
from repro.core.storage.cargo import TIMEOUT_MS, CargoUnavailableError
from repro.core.storage.cargo_manager import HOT_READ_RATE, DataProfile


def _system(cargo_nodes=("V1", "V2", "D6", "Cloud")):
    topo = real_world()
    return ArmadaSystem(topo, seed=9, compute_nodes=["V3", "V4", "V5"],
                        cargo_nodes=list(cargo_nodes))


def _register(sys_, consistency="eventual"):
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       consistency=consistency,
                       locations=[sys_.topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(
        spec, initial={"k0": b"v0"})
    return spec, chosen


def test_store_register_allocates_three_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_)
    assert len(chosen) == 3
    for c in chosen:
        assert c.stores["face"]["k0"] == b"v0"
        assert len(c.peers["face"]) == 2


def test_eventual_write_acks_fast_then_converges():
    sys_ = _system()
    spec, chosen = _register(sys_)
    lat = []
    chosen[0].write("face", "k1", b"v1", "V3", "eventual", lat.append)
    sys_.sim.run(until=60.0)                 # local ack: ~rtt + write
    assert lat and lat[0] < 60.0
    sys_.sim.run(until=2_000.0)              # cascade completes
    for c in chosen:
        assert c.stores["face"]["k1"] == b"v1"


def test_strong_write_waits_for_all_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    strong, eventual = [], []
    chosen[0].write("face", "ks", b"v", "V3", "strong", strong.append)
    sys_.sim.run(until=5_000.0)
    # all replicas have it at ack time recorded; latency >= slowest hop
    assert strong
    chosen[0].write("face", "ke", b"v", "V3", "eventual", eventual.append)
    sys_.sim.run(until=10_000.0)
    assert eventual[0] < strong[0]


def test_cargo_discover_ranks_by_proximity():
    sys_ = _system()
    spec, chosen = _register(sys_)
    cands = sys_.cargo_manager.cargo_discover("face",
                                              sys_.topo.nodes["V5"].loc)
    assert 1 <= len(cands) <= 3
    assert all(c.alive for c in cands)


def test_dead_replica_skipped_not_blocking():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    chosen[1].fail()
    lat = []
    chosen[0].write("face", "k2", b"v2", "V3", "strong", lat.append)
    sys_.sim.run(until=5_000.0)
    assert lat, "strong write must still ack when a replica is dead"
    alive = [c for c in chosen if c.alive]
    for c in alive:
        assert c.stores["face"].get("k2") == b"v2"


def test_dead_cargo_read_write_deliver_errors_not_silence():
    """I/O against a dead Cargo must never hang the caller: with an
    ``on_error`` the timeout delivers ``CargoUnavailableError``; without
    one the sentinel rides ``on_done`` (None value / nan latency)."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    dead = chosen[0]
    dead.fail()
    errs, reads, writes = [], [], []
    dead.read("face", "k0", "V3", lambda v, ms: reads.append((v, ms)),
              on_error=errs.append)
    dead.write("face", "kx", b"v", "V3", "eventual",
               lambda ms: writes.append(ms), on_error=errs.append)
    sys_.sim.run(until=TIMEOUT_MS + 50.0)
    assert len(errs) == 2 and not reads and not writes
    assert all(isinstance(e, CargoUnavailableError) for e in errs)
    # fallback sentinels when no on_error was given
    dead.read("face", "k0", "V3", lambda v, ms: reads.append((v, ms)))
    dead.write("face", "ky", b"v", "V3", "eventual",
               lambda ms: writes.append(ms))
    sys_.sim.run(until=sys_.sim.now + TIMEOUT_MS + 50.0)
    assert reads == [(None, pytest.approx(TIMEOUT_MS))]
    assert len(writes) == 1 and np.isnan(writes[0])


def test_cargo_dying_mid_read_times_out():
    """Death between request and lookup (in-flight) hits the same
    timeout path as death at request time."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    errs, reads = [], []
    chosen[0].read("face", "k0", "V3",
                   lambda v, ms: reads.append(v), on_error=errs.append)
    sys_.sim.at(1.0, chosen[0].fail)        # dies before the lookup lands
    sys_.sim.run(until=TIMEOUT_MS + 50.0)
    assert len(errs) == 1 and not reads


def test_dead_peer_mid_cascade_does_not_orphan_downstream():
    """Eventual-consistency cascade with the middle replica dying while
    the update is in flight to it: the chain must skip the corpse and
    still reach every replica downstream of it."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    acked = []
    chosen[0].write("face", "kc", b"vc", "V3", "eventual", acked.append)
    # the local apply lands ~rtt/2 + write (<13 ms); the first hop needs
    # >=16 ms more — kill the middle replica inside that window
    sys_.sim.at(14.0, chosen[1].fail)
    sys_.sim.run(until=2_000.0)
    assert acked, "eventual write never acked"
    assert chosen[0].stores["face"].get("kc") == b"vc"
    assert chosen[1].stores["face"].get("kc") is None, \
        "test setup: the middle replica was meant to die pre-arrival"
    assert chosen[2].stores["face"].get("kc") == b"vc", \
        "cascade died with the middle replica instead of skipping it"


def test_fail_cargo_guard_rails():
    """``fail_cargo`` has ``fail_node`` parity: unknown names raise at
    schedule time, an already-dead Cargo raises when the event fires."""
    sys_ = _system()
    with pytest.raises(ValueError, match="unknown cargo"):
        sys_.fail_cargo("nope", 100.0)
    sys_.fail_cargo("V1", 100.0)
    sys_.fail_cargo("V1", 200.0)            # fires against a corpse
    with pytest.raises(RuntimeError, match="already failed"):
        sys_.sim.run(until=300.0)
    assert not sys_.cargos["V1"].alive


def test_cargo_discover_orders_strictly_by_distance():
    sys_ = _system()
    spec, chosen = _register(sys_)
    loc = sys_.topo.nodes["V5"].loc
    cands = sys_.cargo_manager.cargo_discover("face", loc)
    dists = [geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                 loc[0], loc[1]) for c in cands]
    assert dists == sorted(dists)
    assert len(cands) == 3
    # a dead access point drops out of the candidate list
    cands[0].fail()
    cands2 = sys_.cargo_manager.cargo_discover("face", loc)
    assert cands[0] not in cands2 and len(cands2) == 2


def test_store_register_respects_capacity():
    """Placement ranks by distance among cargos WITH room: a store too
    big for the 2 GB volunteers lands on the only node that fits it."""
    sys_ = _system()
    spec = ServiceSpec("big", facerec_image(), need_storage=True,
                       storage_capacity_mb=10_000.0,
                       locations=[sys_.topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(spec)
    assert [c.node_id for c in chosen] == ["Cloud"]


def test_on_new_task_replaces_only_when_far():
    """Storage auto-scaling reacts to a far compute spawn with one new
    data replica (and republishes locality); a nearby spawn is a no-op."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9,
                        compute_nodes=["V3", "V4", "V5", "Cloud"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec, chosen = _register(sys_)
    near = Task("face/near", "face", captain=sys_.captains["V4"],
                status="running")
    sys_.cargo_manager.on_new_task(spec, near)
    sys_.sim.run(until=5_000.0)
    assert len(sys_.cargo_manager.placements["face"]) == 3   # no-op
    far = Task("face/far", "face", captain=sys_.captains["Cloud"],
               status="running")
    sys_.cargo_manager.on_new_task(spec, far)
    sys_.sim.run(until=10_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert len(placements) == 4
    new = placements[-1]
    assert new.node_id == "Cloud"
    assert new.stores["face"]["k0"] == b"v0"    # data actually copied
    assert all(new in c.peers["face"] for c in placements[:-1])
    locs, _ = sys_.am.engine.data_locality["face"]
    assert len(locs) == 4


def test_storage_autoscaling_follows_compute():
    """A service replica placed far from all data replicas triggers a new
    data replica nearby (paper §3.4 storage auto-scaling)."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9,
                        compute_nodes=["V3", "V4", "V5", "Cloud"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       locations=[topo.nodes["V3"].loc])
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=30_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert len(placements) >= 3

# ------------------------------------------------------ capacity accounting


def test_used_mb_tracks_live_store_size():
    """Property: under a mixed provision / write / propagate sequence,
    the incremental ``used_mb`` accounting on EVERY Cargo equals the
    recomputed live record size — the invariant the Cargo Manager's
    capacity filter ranks on.  (The seed-era bug: only ``provision``
    bumped ``used_mb``, so grown stores ranked at provision-time size.)"""
    sys_ = _system()
    spec, chosen = _register(sys_)
    rng = np.random.default_rng(4)
    for i in range(40):
        writer = chosen[int(rng.integers(len(chosen)))]
        key = f"k{int(rng.integers(12))}"          # overwrites included
        val = bytes(int(rng.integers(1, 2048)))
        mode = "strong" if i % 3 == 0 else "eventual"
        writer.write("face", key, val, "V3", mode, lambda ms: None)
        sys_.sim.run(until=sys_.sim.now + float(rng.integers(1, 400)))
    # a mid-life re-provision replaces the store, it must not stack
    chosen[0].provision("face", chosen, {"k0": b"v0", "kr": bytes(512)})
    sys_.sim.run(until=sys_.sim.now + 10_000.0)    # drain every cascade
    for c in sys_.cargos.values():
        c.check_capacity_invariant()
        assert c.used_mb == pytest.approx(c.stored_mb())
        assert c.used_mb >= 0.0


def test_propagated_records_are_accounted():
    """Replica propagation grows ``used_mb`` on the receiving side: after
    an eventual write converges, every replica accounts the new record —
    not just the one that took the client write."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    before = [c.used_mb for c in chosen]
    chosen[0].write("face", "k9", bytes(4096), "V3", "eventual",
                    lambda ms: None)
    sys_.sim.run(until=5_000.0)
    grow = (8 + 4096) / 1e6
    for c, b in zip(chosen, before):
        assert c.used_mb == pytest.approx(b + grow)
        c.check_capacity_invariant()


def test_capacity_overflow_migrates_largest_store():
    """A propagated record that pushes a Cargo past its volume triggers
    eviction: the store migrates to a Cargo with room, the group
    re-links, and the accounting invariant holds everywhere."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    full = chosen[0]
    full.spec.storage_gb = 2e-6          # ~2 KB volume: next write spills
    big = bytes(4096)
    chosen[1].write("face", "big", big, "V3", "eventual", lambda ms: None)
    sys_.sim.run(until=30_000.0)
    group = sys_.cargo_manager.placements["face"]
    assert all(c is not full for c in group), "full Cargo still placed"
    assert "face" not in full.stores
    added = [c for c in group if c not in chosen]
    assert len(added) == 1, "migration target missing from the group"
    assert added[0].stores["face"]["big"] == big
    assert all(added[0] in c.peers["face"] for c in group
               if c is not added[0])
    for c in sys_.cargos.values():
        c.check_capacity_invariant()
    kinds = [e["kind"] for e in sys_.sim.trace]
    assert "storage_evict" in kinds


def test_sole_replica_never_evicted():
    """A Cargo holding the only alive copy of a store tolerates the
    overflow (logged) — dropping it would lose data."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    chosen[1].fail()
    chosen[2].fail()
    sole = chosen[0]
    sole.spec.storage_gb = 2e-6
    sole.write("face", "big", bytes(4096), "V3", "eventual",
               lambda ms: None)
    sys_.sim.run(until=30_000.0)
    assert sole.stores["face"]["big"] == bytes(4096)   # data kept
    evs = [e for e in sys_.sim.trace
           if e["kind"] == "storage_evict_failed"]
    assert evs and evs[-1]["reason"] == "sole-replica"


# ------------------------------------------------- auto-scaling edge cases


def test_dead_source_copy_refused():
    """Storage auto-scaling with every replica dead must refuse the bulk
    copy (``storage_scale_failed``) instead of fabricating recovered
    data out of a dead Cargo's in-memory store."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    for c in chosen:
        c.fail()
    started = sys_.cargo_manager._ensure_replica_near(
        spec, sys_.topo.nodes["Cloud"].loc, "handoff")
    sys_.sim.run(until=5_000.0)
    assert started is False
    assert len(sys_.cargo_manager.placements["face"]) == 3   # unchanged
    assert all("face" not in c.stores
               for c in sys_.cargos.values() if c not in chosen)
    evs = [e for e in sys_.sim.trace
           if e["kind"] == "storage_scale_failed"]
    assert evs and evs[-1]["reason"] == "no-alive-source"


def test_concurrent_handoffs_do_not_double_place():
    """Two Beacon handoffs re-homing users to the same region before the
    first bulk copy lands must place ONE replica: the in-flight copy is
    visible to the second call's nearby check."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    assert "Cloud" not in [c.node_id for c in chosen]
    loc = sys_.topo.nodes["Cloud"].loc
    n1 = sys_.cargo_manager.on_domain_handoff(loc)
    n2 = sys_.cargo_manager.on_domain_handoff(loc)     # racing duplicate
    assert (n1, n2) == (1, 0)
    sys_.sim.run(until=30_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert [c.node_id for c in placements].count("Cloud") == 1
    assert len(placements) == 4
    assert not sys_.cargo_manager._inflight.get("face")
    for c in placements:
        c.check_capacity_invariant()


def test_hot_read_load_triggers_storage_scaling():
    """A replica whose charged read throughput crosses ``HOT_READ_RATE``
    gains a second access point (hot-store split), the way hot Captains
    trigger compute auto-scaling."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    cm = sys_.cargo_manager
    chosen[1].fail()
    chosen[2].fail()
    reps = [c for c in cm.placements["face"] if c.alive]
    assert reps == [chosen[0]]
    before = len(cm.placements["face"])
    cm.note_read_load("face", reps, np.asarray([500.0]), 1_000.0)
    assert chosen[0].read_rate > HOT_READ_RATE
    sys_.sim.run(until=30_000.0)
    after = cm.placements["face"]
    assert len(after) == before + 1
    assert after[-1].stores["face"]["k0"] == b"v0"     # data copied


# ----------------------------------------------------------- data plane


def test_effective_read_ms_inflates_with_load():
    """The load-inflated read time grows with charged throughput and is
    clamped at 10x the measured EMA (never a divide-by-zero)."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    c = chosen[0]
    base = c.effective_read_ms()
    assert base == pytest.approx(c.read_ema)
    c.note_reads(50.0, 1_000.0)
    mid = c.effective_read_ms()
    assert mid > base
    c.note_reads(1e6, 1_000.0)           # drive utilization to the cap
    assert c.effective_read_ms() == pytest.approx(c.read_ema * 10.0)


def test_data_ms_for_nodes_consistency_cost():
    """Vectorized per-node access cost: read-only < +writes(eventual) <
    +writes(strong) — the strong ack waits for the slowest peer
    (Table 7 / Fig 12b ordering); no alive placement returns None."""
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    cm = sys_.cargo_manager
    lats = np.asarray([sys_.topo.nodes[n].loc[0] for n in ("V3", "Cloud")])
    lons = np.asarray([sys_.topo.nodes[n].loc[1] for n in ("V3", "Cloud")])
    ro = DataProfile(reads_per_request=1.0)
    rw_e = DataProfile(1.0, 1.0, "eventual")
    rw_s = DataProfile(1.0, 1.0, "strong")
    ms_ro, nearest, reps = cm.data_ms_for_nodes("face", ro, lats, lons)
    ms_e, _, _ = cm.data_ms_for_nodes("face", rw_e, lats, lons)
    ms_s, _, _ = cm.data_ms_for_nodes("face", rw_s, lats, lons)
    assert ms_ro.shape == (2,) and nearest.shape == (2,)
    assert all(reps[i].alive for i in nearest)
    assert (ms_e > ms_ro).all()          # writes cost extra
    assert (ms_s > ms_e).all()           # strong waits on the fan-out
    # a loaded nearest replica makes the SAME node's access slower
    reps[int(nearest[0])].note_reads(400.0, 1_000.0)
    ms_hot, _, _ = cm.data_ms_for_nodes("face", ro, lats, lons)
    assert ms_hot[0] > ms_ro[0]
    for c in chosen:
        c.fail()
    assert cm.data_ms_for_nodes("face", ro, lats, lons) is None


def test_data_profile_validates_consistency():
    with pytest.raises(ValueError, match="unknown consistency"):
        DataProfile(consistency="quorum")


def test_bench_storage_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1,
    driving the vectorized-pool data plane end-to-end: the data term
    must raise end-to-end frame latency over the term-off twin, reads
    must be charged back to the Cargo replicas, and the mid-run Cargo
    failure must re-home reads onto the surviving replicas at a longer
    hop (the full 100k x 1k profile rides the slow tier)."""
    from benchmarks.bench_storage import _SMOKE, _fleet_rows, derive

    rows = _fleet_rows(_SMOKE)
    pre = rows[0][0].rsplit("/", 1)[0] + "/"
    by_name = {n: (ms, d) for n, ms, d in rows}
    on, on_d = by_name[pre + "data_on"]
    off, off_d = by_name[pre + "data_off"]
    assert np.isfinite(on) and np.isfinite(off)
    assert on > 1.5 * off                # the Cargo hop is in the frames
    assert "cargo_reads=0;" in off_d     # term off -> no charge-back
    assert "cargo_reads=0;" not in on_d
    ev, _ = by_name[pre + "write_eventual"]
    st, _ = by_name[pre + "write_strong"]
    assert st > ev                       # strong pays the replica fan-out
    chp, _ = by_name[pre + "churn_pre"]
    chq, chq_d = by_name[pre + "churn_post"]
    assert np.isfinite(chp) and chq > chp        # longer replica hop
    assert "replicas_alive=2" in chq_d           # the nearest replica died
    us = {n: ms * 1e3 for n, ms, _ in rows if ms == ms}
    imp = derive(us)
    assert imp and "data_term_frame=" in imp[0][2]
    assert "churn_frame_ms=" in imp[0][2]


@pytest.mark.slow
def test_bench_storage_full_profile():
    """Full fleet profile (102_400 users x 1_000 nodes, 12 Cargos) —
    same invariants as the smoke profile at paper scale."""
    from benchmarks.bench_storage import _FULL, _fleet_rows

    rows = _fleet_rows(_FULL)
    by_name = {n: (ms, d) for n, ms, d in rows}
    pre = rows[0][0].rsplit("/", 1)[0] + "/"
    assert by_name[pre + "data_on"][0] > by_name[pre + "data_off"][0]
    assert by_name[pre + "churn_post"][0] > by_name[pre + "churn_pre"][0]
