"""Cargo storage layer: replication count, consistency semantics,
data-access-point selection, failover, and storage auto-scaling."""
import numpy as np
import pytest

from repro.core import geohash
from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, facerec_image
from repro.core.cluster import real_world
from repro.core.storage.cargo import TIMEOUT_MS, CargoUnavailableError


def _system(cargo_nodes=("V1", "V2", "D6", "Cloud")):
    topo = real_world()
    return ArmadaSystem(topo, seed=9, compute_nodes=["V3", "V4", "V5"],
                        cargo_nodes=list(cargo_nodes))


def _register(sys_, consistency="eventual"):
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       consistency=consistency,
                       locations=[sys_.topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(
        spec, initial={"k0": b"v0"})
    return spec, chosen


def test_store_register_allocates_three_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_)
    assert len(chosen) == 3
    for c in chosen:
        assert c.stores["face"]["k0"] == b"v0"
        assert len(c.peers["face"]) == 2


def test_eventual_write_acks_fast_then_converges():
    sys_ = _system()
    spec, chosen = _register(sys_)
    lat = []
    chosen[0].write("face", "k1", b"v1", "V3", "eventual", lat.append)
    sys_.sim.run(until=60.0)                 # local ack: ~rtt + write
    assert lat and lat[0] < 60.0
    sys_.sim.run(until=2_000.0)              # cascade completes
    for c in chosen:
        assert c.stores["face"]["k1"] == b"v1"


def test_strong_write_waits_for_all_replicas():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    strong, eventual = [], []
    chosen[0].write("face", "ks", b"v", "V3", "strong", strong.append)
    sys_.sim.run(until=5_000.0)
    # all replicas have it at ack time recorded; latency >= slowest hop
    assert strong
    chosen[0].write("face", "ke", b"v", "V3", "eventual", eventual.append)
    sys_.sim.run(until=10_000.0)
    assert eventual[0] < strong[0]


def test_cargo_discover_ranks_by_proximity():
    sys_ = _system()
    spec, chosen = _register(sys_)
    cands = sys_.cargo_manager.cargo_discover("face",
                                              sys_.topo.nodes["V5"].loc)
    assert 1 <= len(cands) <= 3
    assert all(c.alive for c in cands)


def test_dead_replica_skipped_not_blocking():
    sys_ = _system()
    spec, chosen = _register(sys_, "strong")
    chosen[1].fail()
    lat = []
    chosen[0].write("face", "k2", b"v2", "V3", "strong", lat.append)
    sys_.sim.run(until=5_000.0)
    assert lat, "strong write must still ack when a replica is dead"
    alive = [c for c in chosen if c.alive]
    for c in alive:
        assert c.stores["face"].get("k2") == b"v2"


def test_dead_cargo_read_write_deliver_errors_not_silence():
    """I/O against a dead Cargo must never hang the caller: with an
    ``on_error`` the timeout delivers ``CargoUnavailableError``; without
    one the sentinel rides ``on_done`` (None value / nan latency)."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    dead = chosen[0]
    dead.fail()
    errs, reads, writes = [], [], []
    dead.read("face", "k0", "V3", lambda v, ms: reads.append((v, ms)),
              on_error=errs.append)
    dead.write("face", "kx", b"v", "V3", "eventual",
               lambda ms: writes.append(ms), on_error=errs.append)
    sys_.sim.run(until=TIMEOUT_MS + 50.0)
    assert len(errs) == 2 and not reads and not writes
    assert all(isinstance(e, CargoUnavailableError) for e in errs)
    # fallback sentinels when no on_error was given
    dead.read("face", "k0", "V3", lambda v, ms: reads.append((v, ms)))
    dead.write("face", "ky", b"v", "V3", "eventual",
               lambda ms: writes.append(ms))
    sys_.sim.run(until=sys_.sim.now + TIMEOUT_MS + 50.0)
    assert reads == [(None, pytest.approx(TIMEOUT_MS))]
    assert len(writes) == 1 and np.isnan(writes[0])


def test_cargo_dying_mid_read_times_out():
    """Death between request and lookup (in-flight) hits the same
    timeout path as death at request time."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    errs, reads = [], []
    chosen[0].read("face", "k0", "V3",
                   lambda v, ms: reads.append(v), on_error=errs.append)
    sys_.sim.at(1.0, chosen[0].fail)        # dies before the lookup lands
    sys_.sim.run(until=TIMEOUT_MS + 50.0)
    assert len(errs) == 1 and not reads


def test_dead_peer_mid_cascade_does_not_orphan_downstream():
    """Eventual-consistency cascade with the middle replica dying while
    the update is in flight to it: the chain must skip the corpse and
    still reach every replica downstream of it."""
    sys_ = _system()
    spec, chosen = _register(sys_)
    acked = []
    chosen[0].write("face", "kc", b"vc", "V3", "eventual", acked.append)
    # the local apply lands ~rtt/2 + write (<13 ms); the first hop needs
    # >=16 ms more — kill the middle replica inside that window
    sys_.sim.at(14.0, chosen[1].fail)
    sys_.sim.run(until=2_000.0)
    assert acked, "eventual write never acked"
    assert chosen[0].stores["face"].get("kc") == b"vc"
    assert chosen[1].stores["face"].get("kc") is None, \
        "test setup: the middle replica was meant to die pre-arrival"
    assert chosen[2].stores["face"].get("kc") == b"vc", \
        "cascade died with the middle replica instead of skipping it"


def test_fail_cargo_guard_rails():
    """``fail_cargo`` has ``fail_node`` parity: unknown names raise at
    schedule time, an already-dead Cargo raises when the event fires."""
    sys_ = _system()
    with pytest.raises(ValueError, match="unknown cargo"):
        sys_.fail_cargo("nope", 100.0)
    sys_.fail_cargo("V1", 100.0)
    sys_.fail_cargo("V1", 200.0)            # fires against a corpse
    with pytest.raises(RuntimeError, match="already failed"):
        sys_.sim.run(until=300.0)
    assert not sys_.cargos["V1"].alive


def test_cargo_discover_orders_strictly_by_distance():
    sys_ = _system()
    spec, chosen = _register(sys_)
    loc = sys_.topo.nodes["V5"].loc
    cands = sys_.cargo_manager.cargo_discover("face", loc)
    dists = [geohash.distance_km(c.spec.loc[0], c.spec.loc[1],
                                 loc[0], loc[1]) for c in cands]
    assert dists == sorted(dists)
    assert len(cands) == 3
    # a dead access point drops out of the candidate list
    cands[0].fail()
    cands2 = sys_.cargo_manager.cargo_discover("face", loc)
    assert cands[0] not in cands2 and len(cands2) == 2


def test_store_register_respects_capacity():
    """Placement ranks by distance among cargos WITH room: a store too
    big for the 2 GB volunteers lands on the only node that fits it."""
    sys_ = _system()
    spec = ServiceSpec("big", facerec_image(), need_storage=True,
                       storage_capacity_mb=10_000.0,
                       locations=[sys_.topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(spec)
    assert [c.node_id for c in chosen] == ["Cloud"]


def test_on_new_task_replaces_only_when_far():
    """Storage auto-scaling reacts to a far compute spawn with one new
    data replica (and republishes locality); a nearby spawn is a no-op."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9,
                        compute_nodes=["V3", "V4", "V5", "Cloud"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec, chosen = _register(sys_)
    near = Task("face/near", "face", captain=sys_.captains["V4"],
                status="running")
    sys_.cargo_manager.on_new_task(spec, near)
    sys_.sim.run(until=5_000.0)
    assert len(sys_.cargo_manager.placements["face"]) == 3   # no-op
    far = Task("face/far", "face", captain=sys_.captains["Cloud"],
               status="running")
    sys_.cargo_manager.on_new_task(spec, far)
    sys_.sim.run(until=10_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert len(placements) == 4
    new = placements[-1]
    assert new.node_id == "Cloud"
    assert new.stores["face"]["k0"] == b"v0"    # data actually copied
    assert all(new in c.peers["face"] for c in placements[:-1])
    locs, _ = sys_.am.engine.data_locality["face"]
    assert len(locs) == 4


def test_storage_autoscaling_follows_compute():
    """A service replica placed far from all data replicas triggers a new
    data replica nearby (paper §3.4 storage auto-scaling)."""
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9,
                        compute_nodes=["V3", "V4", "V5", "Cloud"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       locations=[topo.nodes["V3"].loc])
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=30_000.0)
    placements = sys_.cargo_manager.placements["face"]
    assert len(placements) >= 3
