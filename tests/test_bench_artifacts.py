"""Benchmark artifact hygiene: results.json must be strict JSON.

The runner used to serialize annotation-only rows (derive hooks that
carry their result in ``derived``, e.g. the mesh weak-scaling ratio)
with ``us_per_call: NaN`` — a Python-ism that is not JSON: strict
parsers (``jq``, browsers, ``json.loads(..., parse_constant=...)``)
reject the whole file.  These tests drive ``benchmarks.run``'s real
serialization path end-to-end with a stub benchmark module and pin:

* timing-less rows are written as ``null`` (JSON) / an empty field
  (CSV), never ``NaN``;
* a ``--only`` merge against a pre-fix artifact containing a literal
  ``NaN`` heals it in place;
* the checked-in ``artifacts/bench/results.json`` itself strict-parses;
* the derive hooks that produce annotation rows return ``None``, not
  ``float("nan")``.
"""
import json
import sys
import types

import pytest

from benchmarks import run as bench_run


def _strict(text: str):
    def boom(s):
        raise ValueError(f"non-strict JSON constant: {s}")
    return json.loads(text, parse_constant=boom)


def _stub_module(name: str):
    mod = types.ModuleType(name)
    mod.run = lambda: [("stub/measured", 2.5, "ticks=3")]
    mod.derive = lambda us_by_name: [
        ("stub/ratio", None, "speedup=2.00x")]
    sys.modules[name] = mod
    return mod


def _run_main(tmp_path, monkeypatch, argv):
    name = "benchmarks._stub_bench"
    _stub_module(name)
    monkeypatch.setattr(bench_run, "MODULES", [name])
    monkeypatch.setattr(bench_run, "_artifacts_dir", lambda: tmp_path)
    monkeypatch.setattr(sys, "argv", ["run.py"] + argv)
    try:
        bench_run.main()
    finally:
        sys.modules.pop(name, None)
    return tmp_path / "results.json", tmp_path / "results.csv"


def test_runner_writes_strict_json_and_csv(tmp_path, monkeypatch, capsys):
    results, csv = _run_main(tmp_path, monkeypatch, [])
    rows = _strict(results.read_text())          # raises on NaN/Infinity
    by_name = {r["name"]: r for r in rows}
    assert by_name["stub/measured"]["us_per_call"] == pytest.approx(2500.0)
    assert by_name["stub/ratio"]["us_per_call"] is None
    assert by_name["stub/ratio"]["derived_row"] is True
    lines = csv.read_text().splitlines()
    assert "stub/ratio,,speedup=2.00x" in lines
    assert "NaN" not in results.read_text()
    # the stdout CSV mirrors the file: empty field, not "nan"
    out = capsys.readouterr().out
    assert "stub/ratio,,speedup=2.00x" in out.splitlines()


def test_only_merge_heals_pre_fix_nan_rows(tmp_path, monkeypatch):
    """A partial --only run merging into an artifact written before the
    fix (literal NaN) must emit a file that strict-parses."""
    stale = ('[\n {\n  "name": "old/row",\n  "us_per_call": NaN,\n'
             '  "derived": "x=1"\n }\n]')
    (tmp_path / "results.json").write_text(stale)
    results, csv = _run_main(tmp_path, monkeypatch, ["--only", "_stub"])
    rows = _strict(results.read_text())
    by_name = {r["name"]: r for r in rows}
    assert by_name["old/row"]["us_per_call"] is None      # healed
    assert by_name["stub/measured"]["us_per_call"] > 0
    assert "old/row,," in csv.read_text()


def test_checked_in_results_json_is_strict():
    path = bench_run._artifacts_dir() / "results.json"
    if not path.exists():
        pytest.skip("no recorded bench artifact")
    rows = _strict(path.read_text())
    assert isinstance(rows, list) and rows


def test_derive_hooks_return_none_not_nan():
    from benchmarks.bench_client_scale import derive as client_derive
    from benchmarks.bench_mesh_scale import derive as mesh_derive
    pre = "client_scale/u100000_n1000/"
    rows = client_derive({pre + "numpy": 100.0, pre + "geo_topk": 50.0,
                          pre + "device": 10.0, pre + "device_inc": 2.0,
                          pre + "device_full": 10.0})
    rows += mesh_derive({"mesh_scale/u250000_n10000/single_d1": 40.0,
                         "mesh_scale/u1000000_n10000/mesh_d4": 80.0})
    assert len(rows) == 4
    for _, ms, _ in rows:
        assert ms is None
    by_name = dict((n, d) for n, _, d in rows)
    assert by_name[pre + "speedup_incremental"] == "speedup=5.00x"
    # None-valued entries in the merged map must never produce a row
    assert client_derive({pre + "numpy": None, pre + "device": 10.0}) == []
