"""Minimal ``hypothesis`` stand-in so property tests run on clean envs.

When the real ``hypothesis`` is installed (see requirements-dev.txt) the
tests import it and this module is unused.  The fallback draws a fixed
number of deterministic pseudo-random samples per test — weaker than real
property-based shrinking, but it keeps the geohash property suite
executing (instead of skipped) on environments without the dependency.
"""
from __future__ import annotations


from types import SimpleNamespace

import numpy as np

_SEED = 0xA47A11
_DEFAULT_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _floats(min_value=-1e9, max_value=1e9, allow_nan=False, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _integers(min_value=0, max_value=100, **_):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(seq, **_):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _lists(elements, min_size=0, max_size=10, **_):
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]
    return _Strategy(draw)


st = SimpleNamespace(floats=_floats, integers=_integers,
                     sampled_from=_sampled_from, lists=_lists)


def settings(max_examples=_DEFAULT_EXAMPLES, **_):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        n = min(getattr(fn, "_fallback_max_examples", _DEFAULT_EXAMPLES),
                _DEFAULT_EXAMPLES)

        def wrapper():
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                fn(*[s.draw(rng) for s in pos_strategies],
                   **{k: s.draw(rng) for k, s in kw_strategies.items()})
        # no functools.wraps: pytest would follow __wrapped__ and mistake
        # the drawn parameters for fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
