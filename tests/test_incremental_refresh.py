"""Incremental candidate refresh: dirty-set sparse rescoring.

``ClientPool(refresh_period_ms=...)`` turns the every-tick O(U·N)
candidate refresh into a dirty-set refresh: a user is rescored only
when its home region's node set changed (engine epochs), it had a pool
event (connection break, Beacon handoff), or its per-user staleness
deadline fired.  These tests pin the mode across the tick paths:

* **identity matrix** — host-numpy == host-kernel == fused device tick
  make identical decisions under ``refresh_period_ms``, through node
  churn + recovery and a Beacon fault-domain kill/recover cycle, with
  identical per-tick refreshed-user streams (the mesh leg lives in
  ``tests/test_mesh_scale.py::test_mesh_identity_incremental_refresh``);
* **overflow fallback** — a ``refresh_cap`` smaller than the dirty set
  latches the in-program overflow flag and falls back to the dense
  full-scan branch for that tick, bit-for-bit identical to the host,
  with no retrace (the fallback is a ``lax.cond``, not a new shape);
* **sparse == restricted dense** (property) — for random dirty subsets
  the sparse gather → score → top-k → scatter-back equals a full
  recompute restricted to those rows (rank order and index tie-breaking
  included), and untouched rows keep their previous candidates;
* **discovery × refresh** — a staleness deadline that fires inside a
  Beacon re-discovery window defers exactly once (the gates compose by
  AND: the user stays due and refreshes on the first open tick, which
  re-arms the deadline), identically on host and device;
* the constructor guard rails.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ImportError:                                 # pragma: no cover
    from tests._hypothesis_fallback import given, settings, st

import repro.core.fused_tick as fused_tick
from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import NodeSpec, Topology
from tests.test_sharded_selection import (SERVICE, _assert_decisions_equal,
                                          _fluid_system)

PROBE = 2000.0
N_USERS = 50


def _locs(n_users=N_USERS, seed=0):
    rng = np.random.default_rng(seed + 1)
    return np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)


def _run(tick, *, backend="geo_topk", period=None, cap=None, shard=3,
         beacon=False, churn=True, until=16_000.0, seed=0, system=None,
         after_start=None):
    """One Fig 8/10-style fluid run (N1/N5 die, N1 recovers; optional
    Beacon kill/recover on the busiest fault domain)."""
    sys_ = system() if system is not None else _fluid_system(
        seed=seed, shard=shard)
    kw = {}
    if period is not None:
        kw["refresh_period_ms"] = period
    if cap is not None:
        kw["refresh_cap"] = cap
    pool = sys_.make_client_pool(
        SERVICE, locs=_locs(seed=seed), transport="fluid",
        frame_interval_ms=500.0, selection_backend=backend, tick=tick,
        shard_border_cap=N_USERS, **kw)
    sys_.sim.at(0.0, pool.start)
    if churn:
        sys_.fail_node("N1", 4_200.0)
        sys_.fail_node("N5", 4_300.0)
        sys_.sim.at(8_000.0, sys_.captains["N1"].recover)
    if beacon:
        region = sys_.beacons.busiest_region()
        sys_.fail_beacon(region, 5_900.0)
        sys_.recover_beacon(region, 10_100.0)
    if after_start is not None:
        after_start(sys_, pool)
    sys_.sim.run(until=until)
    return pool


def _dirty_streams_equal(host, dev):
    """The device tick runs one extra leading tick at t=0 (which
    refreshes nobody under incremental mode); past that, the per-tick
    refreshed-user streams must match exactly."""
    assert dev.dirty_counts[0] == 0
    assert dev.dirty_counts[1:] == host.dirty_counts


# ---------------------------------------------------------- identity matrix


def test_refresh_identity_host_kernel_device():
    """Under ``refresh_period_ms`` the three in-process tick paths make
    identical decisions through churn + Beacon kill/recover — and the
    refresh really is sparse (well under one rescore per user-tick)."""
    period = 3 * PROBE
    host_np = _run("host", backend="numpy", period=period, beacon=True)
    host_k = _run("host", period=period, beacon=True)
    dev = _run("device", period=period, beacon=True)
    _assert_decisions_equal(host_k, host_np)
    _assert_decisions_equal(dev, host_k)
    _dirty_streams_equal(host_k, dev)
    assert host_np.dirty_counts == host_k.dirty_counts
    total = sum(host_k.dirty_counts)
    assert 0 < total < 0.7 * N_USERS * len(host_k.dirty_counts)
    assert dev._rt.fallbacks == 0


def test_default_mode_reports_no_dirty_stream():
    """Without ``refresh_period_ms`` nothing changes: no tracker, no
    dirty accounting — the historical every-tick semantics (whose
    bit-for-bit stability the rest of the suite pins)."""
    pool = _run("host", until=2_100.0, churn=False)
    assert pool.dirty_counts is None and pool._rt is None


@pytest.mark.slow
@pytest.mark.parametrize("period", [PROBE, 2 * PROBE, 5 * PROBE])
def test_refresh_identity_period_sweep(period):
    host_np = _run("host", backend="numpy", period=period, beacon=True)
    host_k = _run("host", period=period, beacon=True)
    dev = _run("device", period=period, beacon=True)
    _assert_decisions_equal(host_k, host_np)
    _assert_decisions_equal(dev, host_k)
    _dirty_streams_equal(host_k, dev)


# ------------------------------------------------- overflow -> dense branch


@pytest.mark.slow       # ~7 s edge pin; the main identity pin stays fast
def test_overflow_cap_falls_back_to_full_scan_identically():
    """A refresh_cap smaller than the dirty set must not drop users: the
    program latches overflow and takes the dense branch for that tick,
    still refreshing exactly the dirty rows — decisions stay identical
    to the host, and the cond flip retraces nothing."""
    deltas = {}

    def pin(sys_, pool):
        def snap():
            deltas["base"] = dict(fused_tick.COMPILE_COUNTS)
        sys_.sim.at(2_100.0, snap)

    host = _run("host", period=3 * PROBE)
    dev = _run("device", period=3 * PROBE, cap=4, after_start=pin)
    _assert_decisions_equal(dev, host)
    _dirty_streams_equal(host, dev)
    assert dev._rt.fallbacks > 0, "cap=4 never overflowed"
    assert {k: v for k, v in fused_tick.COMPILE_COUNTS.items()
            if v != deltas["base"].get(k, 0)} == {}, \
        "dirty-size changes / overflow fallback retraced the program"


def test_guard_rails():
    sys_ = _fluid_system(seed=0, shard=3)
    with pytest.raises(ValueError, match="refresh_period_ms"):
        sys_.make_client_pool(SERVICE, locs=_locs(), transport="events",
                              refresh_period_ms=1000.0)
    with pytest.raises(ValueError, match="must be > 0"):
        sys_.make_client_pool(SERVICE, locs=_locs(), transport="fluid",
                              frame_interval_ms=500.0,
                              refresh_period_ms=0.0)
    with pytest.raises(ValueError, match="refresh_cap"):
        sys_.make_client_pool(SERVICE, locs=_locs(), transport="fluid",
                              frame_interval_ms=500.0, refresh_cap=16)


# ------------------------------------- property: sparse == restricted dense


_IDLE_PERIOD = 1e9          # staleness never fires inside the horizon
_CAND_CACHE = {}


def _cand_after_marks(marks, shard, tie=False):
    """Device run with an idle tracker; ``marks`` users are dirtied just
    before the tick at t=6000 and the candidate matrix is snapped right
    after it."""
    key = (tuple(sorted(marks)), shard, tie)
    if key in _CAND_CACHE:
        return _CAND_CACHE[key]
    snaps = {}

    def hook(sys_, pool):
        ix = np.asarray(sorted(marks), dtype=int)
        if ix.size:
            sys_.sim.at(4_900.0, lambda: pool._rt.mark(ix))
        sys_.sim.at(6_100.0,
                    lambda: snaps.__setitem__("cand",
                                              pool.cand_task.copy()))

    pool = _run("device", period=_IDLE_PERIOD, cap=N_USERS, shard=shard,
                churn=False, until=6_200.0, after_start=hook,
                system=_tie_system if tie else None)
    assert pool._rt.fallbacks == 0
    _CAND_CACHE[key] = snaps["cand"]
    return snaps["cand"]


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=N_USERS - 1),
                min_size=0, max_size=N_USERS, unique=True),
       st.sampled_from([None, 3]))
def test_sparse_scatter_equals_restricted_recompute(subset, shard):
    """For a random dirty subset D, the sparse path's scatter-back
    equals the full recompute restricted to D's rows — same per-row rank
    order, same tie-breaking — and rows outside D are untouched."""
    full = _cand_after_marks(range(N_USERS), shard)
    base = _cand_after_marks((), shard)
    got = _cand_after_marks(subset, shard)
    sub = np.asarray(sorted(subset), dtype=int)
    rest = np.setdiff1d(np.arange(N_USERS), sub)
    np.testing.assert_array_equal(got[sub], full[sub],
                                  err_msg="dirty rows != restricted dense")
    np.testing.assert_array_equal(got[rest], base[rest],
                                  err_msg="clean rows were clobbered")


def _tie_system():
    """Every node identical (location, speed, capacity): all scores tie
    and the candidate order is pure index tie-breaking."""
    nodes = {f"N{i}": NodeSpec(f"N{i}", (44.97, -93.22), proc_ms=20.0,
                               slots=4) for i in range(24)}
    sys_ = ArmadaSystem(Topology(nodes, {}), seed=0, trace_enabled=False,
                        include_cloud_compute=False, shard_precision=3)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def test_sparse_preserves_global_tie_breaking():
    """All-tie topology: the sparse gather/top-k/scatter must reproduce
    the dense path's index tie-breaking exactly."""
    subset = (0, 3, 7, 21, 48)
    full = _cand_after_marks(range(N_USERS), 3, tie=True)
    got = _cand_after_marks(subset, 3, tie=True)
    sub = np.asarray(subset)
    np.testing.assert_array_equal(got[sub], full[sub])


# ----------------------------------------- discovery window x refresh period


def _defer_run(tick):
    """Refresh deadlines (period 2·PROBE) with a discovery window pinned
    over users 0..9 covering the tick at t=6000.  Records every
    (user, refresh time) the tracker re-arms."""
    times = {}

    def hook(sys_, pool):
        def arm():
            pool.am.engine.discovery_ms = 1_500.0
            rec_orig = pool._rt.note_refreshed

            def rec(refreshed, now):
                ix = np.asarray(refreshed)
                if ix.dtype == bool:
                    ix = np.nonzero(ix)[0]
                for u in ix:
                    times.setdefault(int(u), []).append(now)
                return rec_orig(refreshed, now)
            pool._rt.note_refreshed = rec

        def window():
            pool._disc_until = np.zeros(pool.n_users)
            pool._disc_until[:10] = 7_500.0
        sys_.sim.at(100.0, arm)
        sys_.sim.at(4_950.0, window)

    pool = _run(tick, period=2 * PROBE, shard=None, churn=False,
                until=13_000.0, after_start=hook)
    return pool, times


def test_deadline_inside_discovery_window_defers_exactly_once():
    """Masks compose by AND: a user whose staleness deadline fires while
    its re-discovery window is closed stays due, refreshes on the FIRST
    open tick (t=8000, not t=6000), and that refresh re-arms the
    deadline (next at t=12000 — no catch-up double fire at t=10000).
    Host and device agree on every (user, time) pair."""
    host, h_times = _defer_run("host")
    dev, d_times = _defer_run("device")
    _assert_decisions_equal(dev, host)
    _dirty_streams_equal(host, dev)
    assert h_times == d_times
    # stagger: users 0..31 first refresh at t=2000, 32..49 at t=4000
    for u in range(10):                       # gated: deferred once
        assert h_times[u] == [2_000.0, 8_000.0, 12_000.0], (u, h_times[u])
    for u in range(10, 32):                   # ungated control group
        assert h_times[u] == [2_000.0, 6_000.0, 10_000.0], (u, h_times[u])
    for u in range(32, 50):
        assert h_times[u] == [4_000.0, 8_000.0, 12_000.0], (u, h_times[u])
