import os
import sys
import pathlib

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
ROOT = pathlib.Path(__file__).resolve().parents[1]
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)
