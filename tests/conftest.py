import os
import sys
import pathlib
import time

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
ROOT = pathlib.Path(__file__).resolve().parents[1]
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

# ---------------------------------------------------------------------------
# tier-1 wall-clock budget
# ---------------------------------------------------------------------------
# The fast suite was deliberately trimmed to ~2 minutes (heavy sweeps live
# behind `-m slow`); this guard fails a FULL green tier-1 run that exceeds
# the budget, so slow tests can't silently creep back in.  Partial runs
# (-k / file args / -x aborts / failing runs) are exempt — the budget is a
# property of the whole suite, not of a debugging subset.  Override with
# TIER1_BUDGET_S (0 disables).
TIER1_BUDGET_S = float(os.environ.get("TIER1_BUDGET_S", "150"))
_SESSION_T0 = time.monotonic()
_FULL_SUITE_MIN_TESTS = 150         # below this it was a subset run


def pytest_sessionfinish(session, exitstatus):
    elapsed = time.monotonic() - _SESSION_T0
    if (TIER1_BUDGET_S <= 0 or exitstatus != 0
            or session.config.option.keyword
            or session.config.option.markexpr != "not slow"
            or getattr(session, "shouldstop", False)
            or session.testscollected < _FULL_SUITE_MIN_TESTS):
        return                  # not a full tier-1 run (see pytest.ini)
    if elapsed > TIER1_BUDGET_S:
        session.exitstatus = 1
        print(f"\nERROR: tier-1 suite took {elapsed:.1f}s — over its "
              f"{TIER1_BUDGET_S:.0f}s wall-clock budget. Move heavyweight "
              "tests behind `-m slow` (see pytest.ini) or, if the budget "
              "itself is wrong for this machine, set TIER1_BUDGET_S.",
              file=sys.stderr)
