"""Training substrate: AdamW, schedules (incl. WSD), microbatch-grad
equivalence, int8 compression, data-pipeline determinism/sharding,
checkpoint atomicity + restart equality, straggler/NaN guards."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st

from repro.checkpoint import Checkpointer
from repro.config import TrainConfig, reduced
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models.api import build_model, make_batch
from repro.optim import AdamW, make_schedule
from repro.train.train_step import _int8_roundtrip, make_train_step
from repro.train.trainer import Trainer


def test_adamw_optimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0.0,
                     schedule="const", warmup_steps=1)
    opt = AdamW(tc)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params, 0.05)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_wsd_schedule_phases():
    tc = TrainConfig(learning_rate=1e-3, schedule="wsd", warmup_steps=10,
                     stable_steps=80, decay_steps=100)
    s = make_schedule(tc)
    assert float(s(5)) < 1e-3                       # warmup
    np.testing.assert_allclose(float(s(50)), 1e-3)  # stable plateau
    assert float(s(99)) < 0.2e-3                    # sharp decay
    assert float(s(200)) <= float(s(100)) + 1e-12


@pytest.mark.slow
def test_microbatch_grads_match_full_batch():
    cfg = reduced(get_config("qwen3-1.7b"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 4, 32, seed=0)
    tc1 = TrainConfig(microbatches=1, remat="none", grad_clip=0.0)
    tc4 = TrainConfig(microbatches=4, remat="none", grad_clip=0.0)
    opt = AdamW(tc1)
    s1 = opt.init(params)
    p1, _, m1 = make_train_step(model, tc1)(params, s1, batch)
    s2 = AdamW(tc4).init(params)
    p2, _, m2 = make_train_step(model, tc4)(params, s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 1e-4


@given(st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=6, deadline=None)    # tier-1 profile; each example
def test_int8_compression_error_bound(xs):  # pays a fresh jit trace
    g = jnp.asarray(xs, jnp.float32)
    out = _int8_roundtrip(g)
    scale = max(abs(float(jnp.max(g))), abs(float(jnp.min(g)))) / 127.0
    assert float(jnp.max(jnp.abs(out - g))) <= scale * 0.5 + 1e-6


def test_pipeline_deterministic_and_sharded():
    cfg = reduced(get_config("qwen3-1.7b"))
    p1 = TokenPipeline(cfg, batch=8, seq=32, seed=5)
    p2 = TokenPipeline(cfg, batch=8, seq=32, seed=5)
    np.testing.assert_array_equal(p1.batch_at(3)["tokens"],
                                  p2.batch_at(3)["tokens"])
    assert not np.array_equal(p1.batch_at(3)["tokens"],
                              p1.batch_at(4)["tokens"])
    # host sharding: different hosts get different data, same shapes
    h0 = TokenPipeline(cfg, batch=8, seq=32, seed=5, host_index=0,
                       host_count=2)
    h1 = TokenPipeline(cfg, batch=8, seq=32, seed=5, host_index=1,
                       host_count=2)
    a, b = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert a.shape == (4, 32) == b.shape
    assert not np.array_equal(a, b)
    # labels are next-token shifted
    full = p1.batch_at(0)
    assert full["tokens"].shape == full["labels"].shape


def test_checkpoint_atomic_and_checksummed(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    state = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    ck.save(1, state)
    ck.save(2, jax.tree.map(lambda x: x * 2, state))
    # a torn write must be invisible to restore
    (tmp_path / "step_00000099.tmp").mkdir()
    restored, step = ck.restore(None, state)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(10.0) * 2)
    # corruption detection
    import glob
    victim = sorted(glob.glob(str(tmp_path / "step_00000002" / "*.npy")))[0]
    with open(victim, "r+b") as f:
        f.seek(200)
        f.write(b"\xff\xff\xff")
    with pytest.raises(IOError):
        ck.restore(2, state)


@pytest.mark.slow
def test_trainer_restart_continues_identically(tmp_path):
    cfg = reduced(get_config("qwen3-1.7b"), num_layers=2)
    model = build_model(cfg)
    tc = TrainConfig(checkpoint_every=4, remat="none", learning_rate=1e-3,
                     warmup_steps=2, async_checkpoint=False)

    # uninterrupted 8-step run
    t_ref = Trainer(model, cfg, tc, batch=4, seq=32,
                    ckpt_dir=str(tmp_path / "ref"))
    t_ref.init_or_restore()
    m_ref = t_ref.train(8)

    # run 4 steps, "crash", restart, run 4 more
    d = str(tmp_path / "restart")
    t1 = Trainer(model, cfg, tc, batch=4, seq=32, ckpt_dir=d)
    t1.init_or_restore()
    t1.train(4)
    t2 = Trainer(model, cfg, tc, batch=4, seq=32, ckpt_dir=d)
    assert t2.init_or_restore() == 4
    m2 = t2.train(4)
    # data pipeline replays -> losses at steps 5..8 match exactly
    ref_tail = [s["loss"] for s in m_ref.steps[4:]]
    got_tail = [s["loss"] for s in m2.steps]
    np.testing.assert_allclose(got_tail, ref_tail, rtol=2e-4)


def test_nan_guard_skips_update(tmp_path):
    cfg = reduced(get_config("qwen3-1.7b"), num_layers=2)
    model = build_model(cfg)

    class Exploding:
        def __getattr__(self, k):
            return getattr(model, k)

        def loss(self, params, batch, **kw):
            return model.loss(params, batch, **kw) * jnp.nan

    tc = TrainConfig(checkpoint_every=100, remat="none")
    tr = Trainer(Exploding(), cfg, tc, batch=2, seq=16,
                 ckpt_dir=str(tmp_path))
    tr.init_or_restore()
    before = jax.tree.leaves(tr.params)[0]
    m = tr.train(2)
    assert m.skipped_steps == 2
