"""Mesh-sharded ClientPool vs the single-device fused tick.

The mesh path (``ClientPool(tick="device", mesh=4)`` →
``fused_tick.MeshTickDriver``) must be decision-identical to the
single-device fused tick — which PR 6 pinned against the host tick — so
the chain host == device == mesh holds through churn and Beacon
failover.  The comparison needs 4 XLA devices, and
``--xla_force_host_platform_device_count`` is only read at jax
initialisation: each scenario therefore runs in a subprocess
(``tests/_mesh_child.py``) with the flag injected, while this module
stays importable under the tier-1 suite's single-device jax.

``tests/_mesh_child.py`` asserts, in-process:

* candidate matrices / actives / pending / switch records / failover
  counts identical, EMA tables to fp32 rounding — through node churn
  (fail + recover), a Beacon fault-domain failover + recovery, and
  two-round switches;
* border-band users (homed to no region shard — straddling a device
  boundary on the mesh) are served via the fixed-capacity border pass;
* compile-count pin: node churn re-traces no mesh SPMD program.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parents[1]
_OUT = "##OUT##"


def _run_child(n_users: int, n_per_region: int, timeout: float = 600.0,
               refresh_ms: float = 0.0):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_ROOT / "src")] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, str(_ROOT / "tests" / "_mesh_child.py"),
         str(n_users), str(n_per_region)] +
        ([str(refresh_ms)] if refresh_ms else []),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"mesh identity child failed:\n{proc.stdout}\n{proc.stderr}"
    lines = [ln for ln in proc.stdout.splitlines() if ln.startswith(_OUT)]
    assert lines, proc.stdout
    return json.loads(lines[-1][len(_OUT):])


def test_mesh_identity_churn_beacon_failover():
    """4-device mesh == single device, decision for decision, through a
    full churn + Beacon-failover cycle (includes the compile-count pin
    and the border-band straddlers — see tests/_mesh_child.py)."""
    out = _run_child(2_000, 16)
    assert out["ok"]
    assert out["ticks"] >= 8
    assert out["switches"] > 0, "scenario never exercised two-round switch"
    assert out["failovers"] > 0, "scenario never exercised failover"
    assert out["border_users"] > 0


@pytest.mark.slow
def test_mesh_identity_incremental_refresh():
    """Incremental candidate refresh on the mesh: with
    ``refresh_period_ms`` set, the 4-device mesh still reproduces the
    single-device decision stream through churn + Beacon failover, the
    host-side dirty-count streams match exactly, and the steady-state
    dirty fraction is genuinely sparse (the whole point of the mode)."""
    out = _run_child(2_000, 16, refresh_ms=6 * 2_000.0)
    assert out["ok"]
    assert out["switches"] > 0 and out["failovers"] > 0
    assert out["dirty_total"] > 0
    assert out["dirty_frac"] < 0.6, \
        f"incremental refresh not sparse: {out['dirty_frac']:.2f}"


@pytest.mark.slow
def test_mesh_identity_10k_users():
    """ISSUE acceptance shape at reduced scale: 10k users, 4 regions."""
    out = _run_child(10_000, 32, timeout=1200.0)
    assert out["ok"]
    assert out["switches"] > 0 and out["failovers"] > 0


@pytest.mark.slow       # ~20 s: registration smoke, not an identity pin
def test_bench_mesh_scale_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1: the
    multi-device subprocess harness, mesh driver, churn and per-phase
    breakdown are exercised on every test run."""
    from benchmarks.bench_mesh_scale import derive, run
    rows = run(smoke=True)
    assert len(rows) == 2
    by_kind = {name.rsplit("/", 1)[1]: (ms, d) for name, ms, d in rows}
    assert {"single_d1", "mesh_d4"} <= set(by_kind)
    for kind, (ms, d) in by_kind.items():
        assert ms == ms and ms > 0
        assert "host_devices=4" in d and "phase_fused_tick_ms=" in d
    # identical populations -> identical aggregate data-plane behavior
    def strip(d):
        return [kv for kv in d.split(";")
                if kv.split("=")[0] in ("ticks", "reqs", "mean_frame_ms")]
    assert strip(by_kind["single_d1"][1]) == strip(by_kind["mesh_d4"][1])
    # the weak-scaling hook needs the full-profile rows; on smoke-only
    # input it must produce nothing (never a stale or partial ratio)
    assert derive({name: ms * 1e3 for name, ms, _ in rows}) == []
