"""ClientPool correctness: bit-for-bit scalar parity + policy units + the
fluid scale path.

The events-transport pool must reproduce U scalar ``Client`` objects
EXACTLY — same latency samples, same EMA trajectories, same switch
decisions, same active nodes — on the paper's Fig. 8/10 scenarios, because
its batched RNG draws and replay orders are constructed to match the
scalar event sequence.  Any drift here means the vectorized control plane
changed semantics.
"""
import numpy as np
import pytest

from benchmarks.common import WARM, emulation_system, realworld_system
from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.client_pool import (MODE_ARMADA, MODE_CLOUD, MODE_DEDICATED,
                                    ClientPool, ema_fold, failover_pick,
                                    mode_filter, switch_decide)
from repro.core.cluster import NodeSpec, Topology, campus_users

# ---------------------------------------------------------------------------
# scalar-parity harness
# ---------------------------------------------------------------------------


def _run_pair(make_system, client_ids, mode, *, until, fail=(),
              frame_interval=33.0, autoscale=False, **kw):
    """Run the same seeded scenario twice — U scalar Clients vs one
    events-transport ClientPool — and return both."""
    sys_s = make_system()
    sys_s.am.autoscale_enabled = autoscale
    clients = [sys_s.make_client(c, "detect", mode=mode,
                                 frame_interval_ms=frame_interval, **kw)
               for c in client_ids]
    for c in clients:
        sys_s.sim.at(WARM, c.start)
    for node, t in fail:
        sys_s.fail_node(node, t)
    sys_s.sim.run(until=until)

    sys_p = make_system()
    sys_p.am.autoscale_enabled = autoscale
    pool = sys_p.make_client_pool("detect", client_ids=list(client_ids),
                                  mode=mode, frame_interval_ms=frame_interval,
                                  **kw)
    sys_p.sim.at(WARM, pool.start)
    for node, t in fail:
        sys_p.fail_node(node, t)
    sys_p.sim.run(until=until)
    return clients, pool, sys_s, sys_p


def _assert_parity(clients, pool):
    for i, c in enumerate(clients):
        want = [(s.t, s.ms, s.node, s.is_probe) for s in c.samples]
        got = [(s.t, s.ms, s.node, s.is_probe)
               for s in pool.samples_of(i)]
        assert want == got, f"user {i}: samples diverge"
        assert c.ema == pool.ema_of(i), f"user {i}: EMA diverges"
        assert c.switches == pool.switches_of(i), \
            f"user {i}: switches diverge"
        want_active = c.active.captain.node_id if c.active else None
        assert want_active == pool.active_node(i), f"user {i}: active"


@pytest.mark.parametrize("mode", ["armada", "geo", "dedicated", "cloud",
                                  "reconnect", "edge2cloud"])
def test_pool_parity_steady_state_all_modes(mode):
    """Every baseline mode, no failures: bit-for-bit identical."""
    clients, pool, *_ = _run_pair(
        lambda: realworld_system(seed=6, autoscale=False),
        ["C1", "C2", "C3"], mode, until=WARM + 15_000.0)
    _assert_parity(clients, pool)


def test_pool_parity_fig8_emulation_node_sets():
    """Fig 8 scenario: emulation cities, armada mode."""
    clients, pool, *_ = _run_pair(
        lambda: emulation_system(seed=4),
        ["User_A", "User_B", "User_C"], "armada", until=WARM + 20_000.0)
    _assert_parity(clients, pool)
    assert any(len(pool.samples_of(i)) > 50 for i in range(3))


def test_pool_parity_fig10a_failover_armada_vs_reconnect():
    """Fig 10a: active node dies; armada flips instantly, reconnect
    stalls — pool reproduces both trajectories exactly."""
    for mode in ("armada", "reconnect"):
        clients, pool, *_ = _run_pair(
            lambda: realworld_system(seed=7, autoscale=False),
            ["C1", "C2", "C3"], mode, until=WARM + 20_000.0,
            fail=[("V1", WARM + 8_000.0), ("V2", WARM + 9_000.0)])
        _assert_parity(clients, pool)


def test_pool_parity_fig10b_edge2cloud_churn():
    """Fig 10b: nodes die one by one; edge2cloud baseline degrades to the
    cloud replica."""
    clients, pool, *_ = _run_pair(
        lambda: realworld_system(seed=7, autoscale=False),
        ["C1", "C2", "C3"], "edge2cloud", until=WARM + 20_000.0,
        fail=[("V1", WARM + 8_000.0), ("V2", WARM + 8_500.0),
              ("V3", WARM + 9_000.0), ("D6", WARM + 9_500.0)])
    _assert_parity(clients, pool)
    assert any(pool.active_node(i) == "Cloud" for i in range(3))


def test_pool_parity_total_candidate_loss():
    """Kill EVERY edge node: armada users re-enter initial selection (and
    the seed's extra-probe-chain quirk) — still bit-for-bit."""
    fails = [(n, WARM + 8_000.0 + 200.0 * i) for i, n in
             enumerate(("V1", "V2", "V3", "V4", "V5", "D6"))]
    clients, pool, *_ = _run_pair(
        lambda: realworld_system(seed=7, autoscale=False),
        ["C1", "C2", "C3"], "armada", until=WARM + 20_000.0, fail=fails)
    _assert_parity(clients, pool)


def test_pool_parity_with_autoscaler_demand():
    """Autoscaler reads pool populations through ``active_locs`` — the
    batched capacity probe must see the same demand rows as U scalar
    clients and spawn identically."""
    def make():
        sys_ = realworld_system(seed=3, autoscale=True)
        campus_users(sys_.topo, 8, seed=3)
        return sys_
    ids = [f"U{i}" for i in range(8)]
    clients, pool, sys_s, sys_p = _run_pair(
        make, ids, "armada", until=WARM + 20_000.0, frame_interval=10.0)
    _assert_parity(clients, pool)
    assert sys_s.am.scale_events == sys_p.am.scale_events


# ---------------------------------------------------------------------------
# pure policy functions
# ---------------------------------------------------------------------------

def _sd(cand_ema, active_ema, pend, pend_ema, margin=0.95,
        pend_alive=True, cand_task=None, active=None):
    """switch_decide on one row with scalar-friendly args."""
    ct = np.array([[0, 1, 2]]) if cand_task is None else cand_task
    act = np.array([0]) if active is None else active
    return switch_decide(
        ct, np.asarray(cand_ema, float), act,
        np.array([active_ema], float), np.array([pend]),
        np.array([pend_ema], float), np.array([pend_alive]), margin)


def test_switch_decide_two_round_confirmation():
    # candidate 1 beats active by > margin: round 1 nominates, no switch
    ema = [[100.0, 50.0, np.nan]]
    confirm, target, pend = _sd(ema, 100.0, -1, np.nan)
    assert not confirm[0] and pend[0] == 1
    # round 2 confirms: the pending task's own EMA still clears
    confirm, target, pend = _sd(ema, 100.0, int(pend[0]), 50.0)
    assert confirm[0] and target[0] == 1 and pend[0] == -1
    # a margin miss clears pending
    confirm, _, pend = _sd([[100.0, 97.0, np.nan]], 100.0, 1, 97.0)
    assert not confirm[0] and pend[0] == -1
    # ineligible rows (no EMA data) leave pending untouched
    confirm, _, pend = _sd([[np.nan] * 3], np.nan, 1, np.nan)
    assert not confirm[0] and pend[0] == 1


def test_switch_decide_confirms_nominated_not_fresh_argmin():
    """Starvation fix (ROADMAP, filed from PR 9): round 2 asks whether
    the NOMINATED pending task still beats the active by the margin —
    not whether the instantaneous argmin repeated, and not whether the
    nomination is still a candidate.  With hundreds of near-tied
    candidates load feedback rotates both the argmin and the candidate
    set every tick; under either stricter rule no user can ever leave a
    drowned node."""
    # round 1: slot 1 is the argmin -> nominated
    confirm, target, pend = _sd([[100.0, 50.0, 50.5]], 100.0, -1, np.nan)
    assert not confirm[0] and pend[0] == 1
    # round 2: jitter rotates the argmin to slot 2, but the nominated
    # task 1 still clears the margin -> the switch must confirm to 1
    confirm, target, pend = _sd([[100.0, 50.5, 50.0]], 100.0, 1, 50.5)
    assert confirm[0] and target[0] == 1 and pend[0] == -1
    # a pending that dropped off the candidate list still confirms on
    # its table EMA (candidate rotation must not starve confirmation)
    confirm, target, pend = _sd([[100.0, 50.5, 50.0]], 100.0, 99, 50.0)
    assert confirm[0] and target[0] == 99 and pend[0] == -1
    # a dead pending falls back to a fresh nomination of the argmin
    confirm, target, pend = _sd([[100.0, 50.5, 50.0]], 100.0, 99, 50.0,
                                pend_alive=False)
    assert not confirm[0] and pend[0] == 2
    # a pending that no longer clears the margin is dropped even when a
    # different candidate would qualify (fresh nomination next tick)
    confirm, target, pend = _sd([[100.0, 97.0, 50.0]], 100.0, 1, 97.0)
    assert not confirm[0] and pend[0] == 2
    # a pending with no EMA sample yet cannot confirm; the argmin
    # renominates
    confirm, target, pend = _sd([[100.0, 50.5, 50.0]], 100.0, 99, np.nan)
    assert not confirm[0] and pend[0] == 2


def test_mode_filter_semantics():
    # tasks: 0 volunteer, 1 dedicated, 2 cloud
    cloud = np.array([False, False, True])
    ded = np.array([False, True, True])
    lat = np.array([45.0, 45.2, 39.0])
    lon = np.array([-93.0, -93.2, -77.0])
    wide = np.array([[0, 1, 2]], np.int32)
    ulat, ulon = np.array([45.19]), np.array([-93.19])

    out = mode_filter(wide, np.array([MODE_DEDICATED], np.int8), 3,
                      cloud, ded, lat, lon, ulat, ulon)
    assert out.tolist() == [[1, -1, -1]]      # dedicated, non-cloud only
    out = mode_filter(wide, np.array([MODE_CLOUD], np.int8), 3,
                      cloud, ded, lat, lon, ulat, ulon)
    assert out.tolist() == [[2, -1, -1]]
    # dedicated fallback: no dedicated edge nodes -> whole wide list
    out = mode_filter(np.array([[0, 2]], np.int32),
                      np.array([MODE_DEDICATED], np.int8), 2,
                      cloud, np.array([False, False, True]), lat, lon,
                      ulat, ulon)
    assert out.tolist() == [[0, 2]]
    # geo: nearest node only, armada: rank order trimmed
    out = mode_filter(wide, np.array([1], np.int8), 2,   # MODE_GEO
                      cloud, ded, lat, lon, ulat, ulon)
    assert out.tolist() == [[1, -1]]
    out = mode_filter(wide, np.array([MODE_ARMADA], np.int8), 2,
                      cloud, ded, lat, lon, ulat, ulon)
    assert out.tolist() == [[0, 1]]


def test_failover_pick_prefers_known_ema():
    cand = np.array([[3, 4, 5], [3, 4, -1], [-1, -1, -1]])
    ema = np.array([[np.nan, 20.0, 10.0],
                    [np.nan, np.nan, np.nan],
                    [np.nan, np.nan, np.nan]])
    slot = failover_pick(cand, ema)
    assert slot.tolist() == [2, 0, -1]


def test_policy_functions_match_under_jax_numpy():
    """The per-tick EMA/switch update is xp-generic: jnp results must
    equal numpy's (the hook for fusing into the geo_topk scoring pass)."""
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.default_rng(0)
    u, c = 64, 3
    cand_task = rng.integers(-1, 10, (u, c))
    cand_node = rng.integers(0, 6, (u, c))
    cand_ema = np.where(rng.random((u, c)) < 0.3, np.nan,
                        rng.uniform(10, 100, (u, c)))
    active = rng.integers(-1, 10, u)
    active_ema = np.where(rng.random(u) < 0.3, np.nan,
                          rng.uniform(10, 100, u))
    pending = rng.integers(-1, 10, u)
    pend_ema = np.where(rng.random(u) < 0.3, np.nan,
                        rng.uniform(10, 100, u))
    pend_alive = rng.random(u) < 0.8
    got_np = switch_decide(cand_task, cand_ema, active, active_ema,
                           pending, pend_ema, pend_alive, 0.95, xp=np)
    got_j = switch_decide(jnp.asarray(cand_task), jnp.asarray(cand_ema),
                          jnp.asarray(active), jnp.asarray(active_ema),
                          jnp.asarray(pending), jnp.asarray(pend_ema),
                          jnp.asarray(pend_alive), 0.95, xp=jnp)
    for a, b in zip(got_np, got_j):
        np.testing.assert_array_equal(a, np.asarray(b))
    prev = np.where(rng.random(u) < 0.5, np.nan, rng.uniform(10, 100, u))
    ms = rng.uniform(5, 200, u)
    np.testing.assert_allclose(
        ema_fold(prev, ms, 0.4),
        np.asarray(ema_fold(jnp.asarray(prev), jnp.asarray(ms), 0.4,
                            xp=jnp)), rtol=1e-6)


# ---------------------------------------------------------------------------
# fluid transport (the 100k scale path, exercised small in tier-1)
# ---------------------------------------------------------------------------

def _fluid_system(n_nodes=40, seed=0):
    rng = np.random.default_rng(seed)
    nodes = {f"N{i}": NodeSpec(
        f"N{i}", (44.97 + float(rng.uniform(-.5, .5)),
                  -93.22 + float(rng.uniform(-.5, .5))),
        proc_ms=float(rng.uniform(10, 30)), slots=int(rng.integers(2, 9)))
        for i in range(n_nodes)}
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False)
    sys_.am.services["detect"] = ServiceSpec("detect", detection_image())
    sys_.am.tasks["detect"] = []
    sys_.am.users["detect"] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"detect/t{i}", "detect", captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks["detect"].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def test_fluid_pool_end_to_end_with_failover():
    sys_ = _fluid_system()
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 400),
                     -93.22 + rng.uniform(-.5, .5, 400)], axis=1)
    pool = sys_.make_client_pool("detect", locs=locs, transport="fluid",
                                 frame_interval_ms=500.0)
    sys_.sim.at(0.0, pool.start)
    sys_.sim.run(until=4_100.0)
    from collections import Counter
    cnt = Counter(pool._node_ids[pool.task_node[int(a)]]
                  for a in pool.active if a >= 0)
    victim, n_aff = cnt.most_common(1)[0]
    sys_.fail_node(victim, 4_200.0)
    sys_.sim.run(until=12_000.0)
    assert pool.ticks_run >= 5
    assert pool.requests_sent > 0
    assert np.isfinite(pool.mean_latency())
    assert pool.failovers >= n_aff          # everyone left the dead node
    view = pool._last_view
    assert all(view.tasks[int(a)].captain.alive
               for a in pool.active if a >= 0)


@pytest.mark.slow       # registration smoke, not an identity pin
def test_bench_client_scale_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1, so the
    population-scale path is exercised on every test run."""
    from benchmarks.bench_client_scale import run
    rows = run(smoke=True)
    assert rows and rows[0][1] > 0
    derived = rows[0][2]
    assert "req_per_s=" in derived and "failovers=" in derived
    # incremental refresh is a registered smoke mode: the sparse device
    # program + dirty tracker run (and report) on every tier-1 pass
    by_mode = {name.rsplit("/", 1)[1]: d for name, _, d in rows}
    assert "device_inc" in by_mode
    assert "dirty_frac_mean=" in by_mode["device_inc"]
    assert "dirty_frac_ticks=" in by_mode["device_inc"]
    assert "dirty_frac_mean" not in by_mode["device"]


@pytest.mark.slow
def test_bench_client_scale_mid_sweep():
    from benchmarks.bench_client_scale import _bench_case
    rows = _bench_case(10_000, 100, 6)
    assert rows and rows[0][1] > 0


def test_fluid_rejects_unmodelable_frame_intervals():
    sys_ = _fluid_system(n_nodes=4)
    for bad in (0.0, 5000.0):               # saturating train / floors to 0
        with pytest.raises(ValueError, match="frame_interval_ms"):
            sys_.make_client_pool("detect", locs=np.zeros((2, 2)),
                                  transport="fluid",
                                  frame_interval_ms=bad,
                                  probe_period_ms=2000.0)


def test_captain_fluid_capacity_not_double_counted():
    """Overlapping fluid batches (several pools, one node) must not each
    credit the node a full window of drain capacity."""
    from repro.core.captain import Captain
    from repro.core.sim import Simulator
    sim = Simulator(seed=0)
    spec = NodeSpec("N", (45.0, -93.0), proc_ms=20.0, slots=1)
    cap = Captain(sim, Topology({"N": spec}, {}), spec)
    cap.arrive_batch(100, 1.0, 2000.0, 0.0)    # exactly one window of work
    cap.arrive_batch(100, 1.0, 2000.0, 0.0)    # second pool, same window
    sim.now = 2000.0
    assert abs(cap._fluid_requests() - 100.0) < 1e-6   # one window queued
    sim.now = 6000.0
    assert cap._fluid_requests() == 0.0                # idle drain works


# ---------------------------------------------------------------------------
# simulator truncation signal (satellite bugfix)
# ---------------------------------------------------------------------------

def test_sim_run_reports_truncation():
    from repro.core.sim import Simulator
    sim = Simulator(seed=0)

    def chain():
        sim.after(1.0, chain)
    sim.after(0.0, chain)
    with pytest.warns(RuntimeWarning, match="max_events"):
        n = sim.run(until=1e9, max_events=50)
    assert n == 50 and sim.truncated
    sim2 = Simulator(seed=0)
    sim2.after(1.0, lambda: None)
    sim2.run(until=10.0)
    assert not sim2.truncated
