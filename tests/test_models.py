"""Per-architecture smoke tests (assignment §f): every assigned arch, as a
REDUCED same-family config, runs one forward/train step on CPU — asserting
output shapes and no NaNs — plus decode-vs-full-forward consistency for the
serving path of every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced
from repro.configs import assigned_archs, get_config
from repro.models.api import build_model, make_batch

ARCHS = list(assigned_archs())

# tier-1 keeps one fast representative per model family (plus the paper's
# armada service models, tested separately below); the heavyweight reduced
# configs run under `-m slow` — they dominated tier-1 wall time without
# covering different code paths than their small siblings (minicpm-2b is
# the dense-transformer family's tier-1 representative; qwen3-1.7b and
# llama3-405b are the same family at higher cost)
_HEAVY = {"whisper-large-v3", "xlstm-1.3b", "zamba2-7b", "deepseek-moe-16b",
          "qwen2-vl-2b", "grok-1-314b", "qwen3-14b", "qwen3-1.7b",
          "llama3-405b"}
ARCHS_TIERED = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY
                else a for a in ARCHS]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            model = build_model(cfg)
            params = model.init(jax.random.key(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_train_step_smoke(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, "train", 2, 32, seed=1)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, remat="none"))(params)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), arch
    # at least 99% of param tensors receive gradient signal
    nonzero = sum(bool(jnp.any(l != 0)) for l in leaves)
    assert nonzero >= 0.9 * len(leaves), arch


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_remat_full_matches_none(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, "train", 2, 16, seed=2)
    l1 = model.loss(params, batch, remat="none")
    l2 = model.loss(params, batch, remat="full")
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_prefill_decode_consistency(arch, built):
    """serve_step(prefill(x[:n-1]), x[n-1]) == full_forward(x)[-1]."""
    cfg, model, params = built(arch)
    if cfg.moe is not None:
        # capacity drops make train-forward lossy; serving must be dropless
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
        model = build_model(cfg)
    B, T = 2, 20
    batch = make_batch(cfg, "prefill", B, T, seed=3)
    if cfg.family == "vlm":
        pytest.skip("vlm decode positions exercised in test_serving")
    full = make_batch(cfg, "train", B, T, seed=3)
    toks = full["tokens"]
    pre = dict(batch)
    pre["tokens"] = toks[:, :T - 1]
    pre["lengths"] = jnp.full((B,), T - 1, jnp.int32)
    logits_p, cache = model.prefill(params, pre, max_seq=32)
    dec = {"tokens": toks[:, T - 1:T]}
    logits_d, cache2 = model.decode_step(params, cache, dec)
    hb = dict(full)
    hb["tokens"] = toks
    h, _ = model.hidden_states(params, hb)
    if cfg.tie_embeddings:
        ref = h[:, -1] @ params["embed"].T
    else:
        ref = h[:, -1] @ params["unembed"]
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)
    assert int(cache2["lengths"][0]) == T


@pytest.mark.slow
def test_moe_dispatch_methods_agree():
    cfg = reduced(get_config("deepseek-moe-16b"))
    m_e = build_model(cfg, moe_dispatch="einsum")
    m_g = build_model(cfg, moe_dispatch="gmm")
    params = m_e.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, 32, seed=4)
    l1 = m_e.loss(params, batch, remat="none")
    l2 = m_g.loss(params, batch, remat="none")
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor the einsum/gmm paths drop overflow
    consistently and still produce finite losses."""
    import dataclasses
    cfg = reduced(get_config("deepseek-moe-16b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    for disp in ("einsum", "gmm"):
        m = build_model(cfg, moe_dispatch=disp)
        params = m.init(jax.random.key(0))
        batch = make_batch(cfg, "train", 2, 32, seed=5)
        assert jnp.isfinite(m.loss(params, batch, remat="none"))


@pytest.mark.slow
def test_whisper_uses_encoder_output():
    cfg = reduced(get_config("whisper-large-v3"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, 16, seed=6)
    l1 = model.loss(params, batch, remat="none")
    batch2 = dict(batch)
    batch2["enc_feats"] = batch["enc_feats"] * 3.0 + 1.0
    l2 = model.loss(params, batch2, remat="none")
    assert abs(float(l1) - float(l2)) > 1e-6      # cross-attn is live


@pytest.mark.slow
def test_mrope_positions_change_output():
    cfg = reduced(get_config("qwen2-vl-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, 24, seed=7)
    l1 = model.loss(params, batch, remat="none")
    b2 = dict(batch)
    b2["positions"] = batch["positions"] * 3
    l2 = model.loss(params, b2, remat="none")
    assert abs(float(l1) - float(l2)) > 1e-6


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-7b"])
def test_ssm_long_decode_state_is_constant_size(arch, built):
    """Sub-quadratic archs: decode cache size is independent of history
    length (the property that makes long_500k feasible)."""
    cfg, model, params = built(arch)
    c1 = model.init_cache_abstract(1, 64)
    c2 = model.init_cache_abstract(1, 4096)

    def size(c):
        return sum(np.prod(s.shape) for k, s in c.items()
                   if not k.startswith(("k", "v")))
    assert size(c1) == size(c2)


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_param_count_close_to_config_estimate(arch, built):
    from repro.models.modules import param_count_tree
    cfg, model, params = built(arch)
    full_cfg = get_config(arch)
    est = full_cfg.param_count()
    real = param_count_tree(build_model(full_cfg).param_tree())
    assert 0.5 < real / est < 2.0, (arch, real / est)


@pytest.mark.parametrize("arch", ["armada-detector", "armada-facerec"])
def test_paper_service_models_run(arch):
    """The paper's own workloads (§5) are real runnable JAX models."""
    import jax
    import jax.numpy as jnp
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, cfg.num_patches + 8, seed=9)
    h, _ = model.hidden_states(params, batch)
    assert h.shape == (2, cfg.num_patches + 8, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    # facerec descriptors: (B, vocab_size=128-d) embedding head
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]


def test_decode_fori_matches_scan():
    """decode_step_fori (in-place cache variant, §Perf cell C iter 3) is
    numerically identical to the scan-based decode_step."""
    import jax
    import jax.numpy as jnp
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    full = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 20)))
    _, cache = m.prefill(
        p, {"tokens": full[:, :19],
            "lengths": jnp.asarray([19, 15, 19], jnp.int32)}, max_seq=32)
    l1, c1 = m.decode_step(p, cache, {"tokens": full[:, 19:20]})
    l2, c2 = m.decode_step_fori(p, cache, {"tokens": full[:, 19:20]})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(c1["k"]), np.asarray(c2["k"]))
    assert np.array_equal(np.asarray(c1["lengths"]),
                          np.asarray(c2["lengths"]))
