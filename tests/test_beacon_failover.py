"""Beacon fault domains: fault-injection harness for multi-Beacon handoff.

The paper's robustness story covers control-plane loss, not just node
churn: a user must survive their Beacon dying.  These tests kill and
recover per-region Beacon replicas (``ArmadaSystem.fail_beacon`` /
``recover_beacon``) under the Fig. 8/10 fluid scenarios and pin:

* **decision identity** — the host tick and the fused device tick make
  identical decisions through the whole kill → heartbeat-replay →
  recover → re-home cycle, including a mid-outage candidate snapshot
  proving the handoff actually rerouted users;
* **engine identity** — mid-outage, the sharded engine (ownership map +
  hidden nodes) equals an unsharded engine given the same hidden set,
  on both the numpy and kernel paths (the merged-shard nesting
  argument);
* **jit stability** — after the one-time handoff transient, no fused
  program retraces per tick (and recovery reuses the pre-failure
  traces);
* the guard rails: dead replicas fail loudly, unknown regions raise,
  and ``BeaconChurnModel`` never kills the last live Beacon.
"""
import numpy as np
import pytest

from repro.core.beacon import (ArmadaSystem, BeaconUnavailableError,
                               detection_image)
from repro.core.churn import BeaconChurnModel
from repro.core.selection import SelectionEngine
from tests.test_sharded_selection import (SERVICE, _assert_decisions_equal,
                                          _fluid_system)

PROBE = 2000.0


def _busiest_region(sys_) -> str:
    return sys_.beacons.busiest_region()


def _run_kill_recover(tick, *, n_users=50, seed=0, fail_t=5_900.0,
                      recover_t=10_100.0, until=16_000.0, node_fail=(),
                      discovery_ms=0.0):
    """One Fig 8/10 fluid run with a Beacon killed and recovered mid-run.
    Returns (pool, system, mid-outage candidate snapshots)."""
    sys_ = _fluid_system(seed=seed, shard=3)
    sys_.am.engine.discovery_ms = discovery_ms
    rng = np.random.default_rng(seed + 1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick=tick, shard_border_cap=n_users)
    sys_.sim.at(0.0, pool.start)
    region = _busiest_region(sys_)
    sys_.fail_beacon(region, fail_t)
    sys_.recover_beacon(region, recover_t)
    for node, t in node_fail:
        sys_.fail_node(node, t)
    snaps = {}
    for label, t in (("pre", fail_t - 50.0),
                     ("outage", fail_t + PROBE + 50.0),
                     ("recovered", until - 50.0)):
        sys_.sim.at(t, lambda l=label: snaps.__setitem__(
            l, (pool.cand_task.copy(), pool.active.copy())))
    sys_.sim.run(until=until)
    return pool, sys_, snaps


def test_beacon_kill_recover_host_device_decision_identity():
    """Fig 10 regime + a Beacon kill/recover cycle (with node churn in
    the middle): the fused device tick reproduces the host tick's full
    decision stream, including the mid-outage handoff state."""
    fail = [("N1", 6_200.0), ("N5", 6_300.0)]
    host, hs, hsnap = _run_kill_recover("host", node_fail=fail)
    dev, ds, dsnap = _run_kill_recover("device", node_fail=fail)
    _assert_decisions_equal(dev, host)
    for label in ("pre", "outage", "recovered"):
        np.testing.assert_array_equal(hsnap[label][0], dsnap[label][0],
                                      err_msg=f"cand@{label}")
        np.testing.assert_array_equal(hsnap[label][1], dsnap[label][1],
                                      err_msg=f"active@{label}")
    # the scenario actually exercised the failure machinery
    kinds = [e["kind"] for e in hs.beacons.events]
    assert "beacon_fail" in kinds and "beacon_recover" in kinds
    assert kinds.count("reregister") > 0 and kinds.count("rehome") > 0
    assert hs.beacons.convergence_ms(5_900.0) > 0
    # ... and the handoff visibly moved candidates, then re-homed them
    assert not np.array_equal(hsnap["pre"][0], hsnap["outage"][0])
    assert [e for e in ds.beacons.events] == [e for e in hs.beacons.events]


def test_discovery_window_host_device_identity():
    """Client-side Beacon discovery latency (``discovery_ms``): the
    bootstrap is deferred, handoff-affected users keep their stale
    candidates until the re-discovery window closes, and the host and
    fused device ticks gate the refresh IDENTICALLY — the whole decision
    stream matches through kill -> replay -> recover."""
    host, hs, hsnap = _run_kill_recover("host", discovery_ms=1_500.0)
    dev, ds, dsnap = _run_kill_recover("device", discovery_ms=1_500.0)
    _assert_decisions_equal(dev, host)
    for label in ("pre", "outage", "recovered"):
        np.testing.assert_array_equal(hsnap[label][0], dsnap[label][0],
                                      err_msg=f"cand@{label}")
        np.testing.assert_array_equal(hsnap[label][1], dsnap[label][1],
                                      err_msg=f"active@{label}")
    # the window visibly delayed the handoff: mid-outage candidates
    # differ from an instant-discovery run's (which has already rerouted)
    free, _, fsnap = _run_kill_recover("host")
    assert not np.array_equal(hsnap["outage"][0], fsnap["outage"][0]), \
        "discovery window had no visible effect on the handoff"
    # bootstrap pays the window too: the first tick shifts by one probe
    assert host.ticks_run < free.ticks_run


def test_discovery_defers_bootstrap():
    """``pool.start`` is deferred by ``discovery_ms`` — no user runs (and
    no tick fires) until the client has found its Beacon."""
    sys_ = _fluid_system(seed=0, shard=3)
    sys_.am.engine.discovery_ms = 1_500.0
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 20),
                     -93.22 + rng.uniform(-.5, .5, 20)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="numpy", tick="host")
    sys_.sim.at(0.0, pool.start)
    sys_.sim.run(until=1_400.0)
    assert not pool.running.any() and pool.ticks_run == 0
    sys_.sim.run(until=3_700.0)
    assert pool.running.all() and pool.ticks_run > 0


def test_beacon_outage_keeps_data_plane_alive():
    """Control-plane loss must not stall traffic: users keep their
    actives and frames keep flowing while their Beacon is down."""
    pool, sys_, snaps = _run_kill_recover("host", until=9_000.0,
                                          recover_t=8_900.0)
    cand, active = snaps["outage"]
    assert (active >= 0).all(), "users lost their actives during an outage"
    assert (cand >= 0).any(axis=1).all(), \
        "handoff left users without candidates (border pass should serve)"
    assert np.isfinite(pool.mean_latency())


def test_sharded_engine_matches_unsharded_during_outage():
    """Mid-outage (hidden nodes + ownership map live), the sharded
    engine must equal an unsharded engine over the same hidden set —
    numpy path exactly, kernel path against the unsharded kernel."""
    sys_ = _fluid_system(seed=0, shard=3)
    region = _busiest_region(sys_)
    sys_.fail_beacon(region, 1_000.0)
    sys_.sim.run(until=1_400.0)       # mid-replay: some nodes still hidden
    eng = sys_.am.engine
    assert eng.hidden_nodes and eng._owner, "outage not in flight"
    tasks = sys_.am.tasks[SERVICE]
    rng = np.random.default_rng(7)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 120),
                     -93.22 + rng.uniform(-.5, .5, 120)], axis=1)
    ref = SelectionEngine(top_n=3)
    ref.set_beacon_routing(None, eng.hidden_nodes)
    want = ref.candidate_indices(SERVICE, tasks, locs, "wifi")
    got = eng.candidate_indices(SERVICE, tasks, locs, "wifi")
    np.testing.assert_array_equal(got, want)
    wk = ref.candidate_indices_kernel(SERVICE, tasks, locs, "wifi")
    gk = eng.candidate_indices_kernel(SERVICE, tasks, locs, "wifi")
    np.testing.assert_array_equal(gk, wk)
    # convergence: once every node re-registered, decisions return to the
    # no-failure sharded engine's
    sys_.sim.run(until=3_000.0)
    assert not eng.hidden_nodes
    fresh = SelectionEngine(top_n=3, shard_precision=3)
    want2 = fresh.candidate_indices(SERVICE, tasks, locs, "wifi")
    got2 = eng.candidate_indices(SERVICE, tasks, locs, "wifi")
    np.testing.assert_array_equal(got2, want2)


def test_beacon_handoff_compiles_once_not_per_tick():
    """The kill and the recover each get at most one trace per fused
    program (the handoff transient: shard structure changes); every
    steady tick in between and after reuses the compiled programs."""
    from repro.core import fused_tick
    sys_ = _fluid_system(seed=0, shard=3)
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 50),
                     -93.22 + rng.uniform(-.5, .5, 50)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device", shard_border_cap=50)
    sys_.sim.at(0.0, pool.start)
    region = _busiest_region(sys_)
    sys_.fail_beacon(region, 4_100.0)
    sys_.recover_beacon(region, 12_100.0)

    sys_.sim.run(until=6_050.0)       # first post-kill tick: transient paid
    counts0 = dict(fused_tick.COMPILE_COUNTS)
    sys_.sim.run(until=12_050.0)      # steady outage ticks
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts0.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"handoff retraced per tick during the outage: {delta}"
    sys_.sim.run(until=14_050.0)      # first post-recover tick
    counts1 = dict(fused_tick.COMPILE_COUNTS)
    sys_.sim.run(until=18_050.0)
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts1.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"re-home retraced per tick after recovery: {delta}"
    assert pool.ticks_run >= 8


def test_beacon_guard_rails():
    sys_ = _fluid_system(seed=0, shard=3)
    bs = sys_.beacons
    region = _busiest_region(sys_)
    with pytest.raises(ValueError, match="no live Beacon"):
        bs.fail("zzz")                      # unknown region
    with pytest.raises(ValueError, match="exactly 3 geohash chars"):
        bs.fail("zzzzzz")
    with pytest.raises(ValueError, match="not down"):
        bs.recover(region)
    bs.fail(region)
    with pytest.raises(ValueError, match="no live Beacon"):
        bs.fail(region)                     # already dead
    dead = bs.replicas[bs.region_code(region)]
    with pytest.raises(BeaconUnavailableError, match="down"):
        dead.query_service_indices(SERVICE, [(44.97, -93.22)], "wifi")
    # bootstrap lookups route around the dead replica
    center = dead.region_str
    import repro.core.geohash as geohash
    lat, lon, _, _ = geohash.decode(center)
    assert bs.beacon_for((lat, lon)).alive
    # unsharded systems have no fault domains to kill
    from repro.core.cluster import real_world
    flat = ArmadaSystem(real_world(), seed=0)
    with pytest.raises(RuntimeError, match="shard_precision"):
        flat.fail_beacon("9zv", 100.0)


def test_beacon_churn_model_spares_last_replica():
    sys_ = _fluid_system(seed=0, shard=3)
    churn = BeaconChurnModel(sys_.sim, sys_.beacons, mttf_ms=3_000.0,
                             mttr_ms=2_000.0)
    churn.start()
    sys_.sim.run(until=60_000.0)
    kinds = [e["kind"] for e in churn.events]
    assert kinds.count("beacon_fail") >= 2, "churn model never fired"
    assert kinds.count("beacon_recover") >= 1
    assert len(sys_.beacons.live_regions()) >= 1
    # replay the event log: at no point was every Beacon dead
    live = len(sys_.beacons.replicas)
    low = live
    for e in churn.events:
        live += -1 if e["kind"] == "beacon_fail" else 1
        low = min(low, live)
    assert low >= 1, "spare_last failed: control plane fully lost"


def test_bench_beacon_failover_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1 and
    records a real unavailability window."""
    from benchmarks.bench_beacon_failover import run
    rows = run(smoke=True)
    assert rows
    derived = {name: d for name, _, d in rows}
    unavail = [d for d in derived.values() if "unavail_ms=" in d]
    assert unavail, f"no unavailability window recorded: {derived}"
    ms = float(unavail[0].split("unavail_ms=")[1].split(";")[0])
    # replay stagger is uniform over the bench's 1.5x-probe heartbeat
    assert 0.0 < ms <= 3_000.0
    # the outage visibly displaced decisions, and convergence restored them
    d = unavail[0]
    peak = float(d.split("displaced_peak=")[1].split(";")[0])
    end = float(d.split("displaced_end=")[1].split(";")[0])
    assert peak > 0.0 and end == 0.0
    # the discovery-charged case surfaces its window in unavail_ms:
    # unavail = max(beacon convergence, client re-discovery)
    disc = [d for n, _, d in rows if "/disc" in n]
    assert disc, "smoke profile lost the discovery case"
    dd = disc[0]
    u = float(dd.split("unavail_ms=")[1].split(";")[0])
    conv = float(dd.split("beacon_conv_ms=")[1].split(";")[0])
    dms = float(dd.split("discovery_ms=")[1].split(";")[0])
    assert dms == 500.0 and u == max(conv, dms)
