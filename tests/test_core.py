"""Armada control-plane tests: geohash properties (hypothesis), simulator
determinism, scheduler policies, 2-step selection, probing/load-balancing,
auto-scaling, and multi-connection failover."""
import numpy as np
import pytest

try:                              # hypothesis is a dev-only dependency —
    from hypothesis import given, settings          # requirements-dev.txt
    from hypothesis import strategies as st
except ModuleNotFoundError:       # clean env: deterministic sampling shim
    from tests._hypothesis_fallback import given, settings, st

from repro.core import geohash
from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import campus_users, emulation, real_world
from repro.core.sim import Simulator
from repro.core.spinner import Image

# ---------------------------------------------------------------------------
# geohash (property-based)
# ---------------------------------------------------------------------------

lat_st = st.floats(min_value=-89.9, max_value=89.9, allow_nan=False)
lon_st = st.floats(min_value=-179.9, max_value=179.9, allow_nan=False)


@given(lat=lat_st, lon=lon_st)
@settings(max_examples=200, deadline=None)
def test_geohash_roundtrip_within_cell(lat, lon):
    gh = geohash.encode(lat, lon, precision=8)
    dlat, dlon, elat, elon = geohash.decode(gh)
    assert abs(dlat - lat) <= elat * 1.0001
    assert abs(dlon - lon) <= elon * 1.0001


@given(lat=lat_st, lon=lon_st, p=st.integers(min_value=1, max_value=8))
@settings(max_examples=100, deadline=None)
def test_geohash_prefix_nesting(lat, lon, p):
    """A point's precision-p hash is a prefix of its precision-(p+1) hash."""
    assert geohash.encode(lat, lon, p + 1).startswith(
        geohash.encode(lat, lon, p))


@given(lat=st.floats(min_value=-60, max_value=60),
       lon=st.floats(min_value=-170, max_value=170),
       dlat=st.floats(min_value=-0.001, max_value=0.001),
       dlon=st.floats(min_value=-0.001, max_value=0.001))
@settings(max_examples=100, deadline=None)
def test_geohash_nearby_points_share_short_prefix(lat, lon, dlat, dlon):
    a = geohash.encode(lat, lon, 9)
    b = geohash.encode(lat + dlat, lon + dlon, 9)
    # ~100 m apart: must share at least the 2-char (~600 km) prefix except
    # at cell boundaries, where the haversine distance still bounds it
    if geohash.common_prefix(a, b) < 2:
        assert geohash.distance_km(lat, lon, lat + dlat, lon + dlon) < 1.0


def test_proximity_search_widens_until_min_hits():
    items = [("near", (45.0, -93.0)), ("far", (45.5, -93.5)),
             ("vfar", (48.0, -97.0))]
    got = geohash.proximity_search((45.0, -93.0), items, min_hits=3)
    assert set(got) == {"near", "far", "vfar"}


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def test_sim_event_ordering_and_determinism():
    order = []
    sim = Simulator(seed=0)
    sim.at(10.0, order.append, "b")
    sim.at(5.0, order.append, "a")
    sim.after(20.0, order.append, "c")
    sim.run()
    assert order == ["a", "b", "c"]
    # same-seed runs give identical jitter streams
    s1, s2 = Simulator(seed=7), Simulator(seed=7)
    assert [s1.jitter(10) for _ in range(5)] == \
        [s2.jitter(10) for _ in range(5)]


def test_sim_cancel():
    sim = Simulator()
    hit = []
    ev = sim.at(5.0, hit.append, 1)
    sim.cancel(ev)
    sim.run()
    assert not hit


# ---------------------------------------------------------------------------
# spinner scheduling
# ---------------------------------------------------------------------------

def _system(**kw):
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=3, **kw)
    return sys_


def test_initial_deployment_spreads_replicas():
    sys_ = _system()
    spec = ServiceSpec("svc", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=5)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=20_000)
    nodes = [t.captain.node_id for t in sys_.am.tasks["svc"]]
    # D6 has 4 slots but resource scoring must spread beyond one node
    assert len(set(nodes)) >= 3


def test_docker_aware_policy_prefers_warm_nodes():
    sys_ = _system()
    img = detection_image()
    sys_.captains["V4"].spec.layers.update(l for l, _ in img.layers)
    t = Task("warm/t0", "warm")
    dt_warm = sys_.spinner.deploy_task(t, img, sys_.topo.nodes["V4"].loc)
    assert t.captain.node_id == "V4"          # layers present -> wins
    assert dt_warm < 1000.0                   # no pull, just start


def test_prefetch_accelerates_second_deploy():
    sys_ = _system()
    img = detection_image()
    t1 = Task("s/t1", "s")
    dt1 = sys_.spinner.deploy_task(t1, img, sys_.topo.nodes["D6"].loc)
    sys_.sim.run(until=60_000)                # prefetch completes
    t2 = Task("s/t2", "s")
    dt2 = sys_.spinner.deploy_task(t2, img, sys_.topo.nodes["D6"].loc,
                                   selection="armada")
    assert dt2 < dt1 * 0.2                    # Fig 9a effect


def test_scheduler_respects_exclusion_and_failure():
    sys_ = _system()
    for name in ("V1", "V2", "V3", "V4", "V5"):
        sys_.captains[name].fail()
    cap = sys_.spinner.select_captain(detection_image(),
                                      sys_.topo.nodes["D6"].loc)
    assert cap.node_id == "D6"


# ---------------------------------------------------------------------------
# 2-step selection + load balancing + failover
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def steady_system():
    sys_ = _system()
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=6)
    sys_.beacon.deploy_application(spec)
    sys_.ensure_cloud_replica("detect")
    sys_.sim.run(until=15_000)
    return sys_


def test_candidate_list_is_topn_and_running(steady_system):
    cands = steady_system.am.candidate_list(
        "detect", steady_system.topo.nodes["C1"].loc, "wifi")
    assert 1 <= len(cands) <= steady_system.am.top_n
    assert all(t.status == "running" for t in cands)


def test_probing_selects_min_latency_node():
    sys_ = _system()
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=6)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=15_000)
    c = sys_.make_client("C1", "detect")
    sys_.sim.at(15_000, c.start)
    sys_.sim.run(until=45_000)
    # paper Table 6a: C1's best is V1 at ~38 ms
    assert c.active.captain.node_id == "V1"
    assert 30 < c.mean_latency(since=30_000) < 50


def test_load_balancing_emerges_from_probing():
    """When many clients share one area, probing must spread them."""
    sys_ = _system()
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=6)
    sys_.beacon.deploy_application(spec)
    sys_.am.autoscale_enabled = False
    sys_.sim.run(until=15_000)
    users = campus_users(sys_.topo, 8, seed=11)
    clients = [sys_.make_client(u, "detect", frame_interval_ms=5.0)
               for u in users]
    for i, c in enumerate(clients):
        sys_.sim.at(15_000 + 200 * i, c.start)
    sys_.sim.run(until=60_000)
    nodes = {c.active.captain.node_id for c in clients}
    assert len(nodes) >= 3                     # not herded on one node


def test_multi_connection_failover_zero_downtime():
    sys_ = _system()
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=6)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=15_000)
    c = sys_.make_client("C1", "detect", frame_interval_ms=33.0)
    sys_.sim.at(15_000, c.start)
    sys_.sim.run(until=30_000)
    active = c.active.captain.node_id
    before = len([s for s in c.samples if not s.is_probe])
    sys_.fail_node(active, 30_000)
    sys_.sim.run(until=40_000)
    after = [s for s in c.samples if not s.is_probe and s.t > 30_000]
    assert after, "no frames after failure"
    gap = after[0].t - 30_000
    assert gap < 500.0                          # zero downtime (paper)
    assert c.active.captain.node_id != active
    assert c.active.captain.alive


def test_autoscaler_adds_replicas_under_demand():
    sys_ = _system()
    spec = ServiceSpec("detect", detection_image(),
                       locations=[sys_.topo.nodes["D6"].loc],
                       min_replicas=3)
    sys_.beacon.deploy_application(spec)
    sys_.sim.run(until=15_000)
    n0 = len([t for t in sys_.am.tasks["detect"]
              if t.status in ("running", "deploying")])
    users = campus_users(sys_.topo, 12, seed=13)
    for i, u in enumerate(users):
        c = sys_.make_client(u, "detect", frame_interval_ms=10.0)
        sys_.sim.at(15_000 + i * 100, c.start)
    sys_.sim.run(until=60_000)
    n1 = len([t for t in sys_.am.tasks["detect"]
              if t.status in ("running", "deploying")])
    assert n1 > n0
    assert sys_.am.scale_events
