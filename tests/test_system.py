"""End-to-end behaviour tests: the paper's headline claims, reproduced.

Each test pins one quantitative/qualitative claim from the evaluation
(§6): Table 6a selection, Fig 6 scalability ordering, Fig 9a deployment
speedup, Fig 10a zero-downtime failover, Fig 12/13 consistency ordering.
"""
import numpy as np
import pytest

from benchmarks.common import WARM, mean_latency, realworld_system
from repro.core.cluster import campus_users


@pytest.fixture(scope="module")
def table6a_clients():
    sys_ = realworld_system(seed=1, autoscale=False)
    clients = {}
    for cid in ("C1", "C2", "C3"):
        c = sys_.make_client(cid, "detect")
        clients[cid] = c
        sys_.sim.at(WARM, c.start)
    sys_.sim.run(until=WARM + 30_000)
    return clients


def test_selection_matches_paper_table6a(table6a_clients):
    want = {"C1": "V1", "C2": "V2", "C3": "D6"}
    for cid, c in table6a_clients.items():
        assert c.active.captain.node_id == want[cid]


def test_e2e_latency_within_paper_envelope(table6a_clients):
    paper = {"C1": 38.0, "C2": 35.0, "C3": 42.0}
    for cid, c in table6a_clients.items():
        got = c.mean_latency(since=WARM + 15_000)
        assert abs(got - paper[cid]) / paper[cid] < 0.15, (cid, got)


def test_scalability_ordering_at_high_demand():
    """Fig 6 @ 15 users: armada < geo; armada < dedicated."""
    results = {}
    for mode in ("armada", "geo", "dedicated"):
        sys_ = realworld_system(seed=3, autoscale=(mode == "armada"))
        users = campus_users(sys_.topo, 15, seed=3)
        clients = {}
        for i, uid in enumerate(users):
            c = sys_.make_client(uid, "detect", mode=mode,
                                 frame_interval_ms=33.0)
            clients[uid] = c
            sys_.sim.at(WARM + i * 200.0, c.start)
        sys_.sim.run(until=WARM + 30_000.0)
        results[mode] = mean_latency(clients, since=WARM + 15_000.0)
    assert results["armada"] < results["geo"]
    assert results["armada"] < results["dedicated"]
    # paper: 33% / 52% reductions; accept a generous band
    assert 1 - results["armada"] / results["geo"] > 0.15
    assert 1 - results["armada"] / results["dedicated"] > 0.25


def test_failover_is_instant_vs_reconnect():
    gaps = {}
    for mode in ("armada", "reconnect"):
        sys_ = realworld_system(seed=6, autoscale=False)
        c = sys_.make_client("C1", "detect", mode=mode,
                             frame_interval_ms=33.0)
        sys_.sim.at(WARM, c.start)
        sys_.sim.run(until=WARM + 10_000.0)
        active = c.active.captain.node_id
        sys_.fail_node(active, WARM + 10_000.0)
        sys_.sim.run(until=WARM + 20_000.0)
        post = [s for s in c.samples if not s.is_probe
                and s.t > WARM + 10_000.0]
        gaps[mode] = post[0].t - (WARM + 10_000.0) if post else 1e9
    assert gaps["armada"] < 300.0                    # zero downtime
    assert gaps["reconnect"] > 1_500.0               # ~2 s reconnect stall


def test_armada_deploy_faster_than_random():
    from benchmarks.bench_autoscale import _deploy_times
    assert _deploy_times("armada") < 0.3 * _deploy_times("random")


def test_consistency_ordering():
    """Eventual write << strong write on volunteers; both reads equal."""
    from benchmarks import bench_storage
    rows = {n: v for n, v, _ in bench_storage._micro_rows()}
    assert rows["fig13/write/volunteer"] < 0.5 * rows["fig12/write/volunteer"]
    assert rows["fig12/read/volunteer"] == rows["fig13/read/volunteer"]
    # paper Fig 12b: volunteer strong writes rival/exceed cloud latency
    assert rows["fig12/write/volunteer"] > 0.8 * rows["fig12/write/cloud"]


def test_fail_node_rejects_unknown_and_already_failed():
    """Fault-injection hygiene: an unknown node name raises at schedule
    time (with the known names), and a second failure scheduled while
    the node is already down raises when it fires instead of silently
    re-running the no-op branch — a scenario author who double-kills a
    node almost always meant a different node or forgot the recovery."""
    sys_ = realworld_system(seed=0, autoscale=False)
    with pytest.raises(ValueError, match="unknown node 'nope'"):
        sys_.fail_node("nope", 1_000.0)
    sys_.fail_node("V1", 1_000.0)
    sys_.fail_node("V1", 2_000.0)          # fires while V1 is still down
    with pytest.raises(RuntimeError, match="already failed"):
        sys_.sim.run(until=3_000.0)
    assert not sys_.captains["V1"].alive
    # an explicit recovery re-arms the next failure cleanly
    sys_.captains["V1"].recover()
    sys_.fail_node("V1", 4_000.0)
    sys_.sim.run(until=5_000.0)
    assert not sys_.captains["V1"].alive
