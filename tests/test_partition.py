"""Partition-tolerant control plane: split-brain divergence + reconciliation.

A network partition is a different fault from a replica crash: the cut
replica keeps RUNNING — it accepts registrations and staged deploys from
the Captains on its side, so control-plane state *diverges* — while the
majority re-homes the cut domain's users through the same ownership map
a failure uses.  These tests pin:

* **decision identity** — host tick vs fused device tick through a full
  partition → divergence → heal → reconcile cycle (with a data-locality
  score term active), including mid-partition snapshots and a late-join
  Captain + staged deploys on the minority side;
* **partition semantics** — hidden minority nodes, ownership handoff,
  staged deploys invisible until reconcile, LWW registration merge for
  records that diverged across the cut, conflict-dropped spawns;
* **jit stability** — steady partition ticks and steady post-reconcile
  ticks retrace nothing (the cut and the merge each pay at most one
  transient);
* the data-locality preference itself (numpy + kernel + sharded paths,
  off-by-default) and the guard rails (``PartitionChurnModel`` never
  empties the majority; bad partition/heal calls fail loudly).
"""
import numpy as np
import pytest

from repro.core import geohash
from repro.core.app_manager import Task
from repro.core.beacon import ArmadaSystem
from repro.core.captain import Captain
from repro.core.churn import PartitionChurnModel
from repro.core.cluster import NodeSpec, real_world
from repro.core.selection import SelectionEngine
from tests.test_sharded_selection import (SERVICE, _assert_decisions_equal,
                                          _fluid_system, _tie_tasks)

DATA_LOC = ((44.97, -93.22),)           # metro center: some nodes local


# ---------------------------------------------------------------------------
# full-cycle decision identity (tentpole)
# ---------------------------------------------------------------------------

def _stage_minority_work(sys_, region):
    """Mid-partition activity on the cut side: a Captain joins through
    the minority replica and two replica spawns are staged — one lands
    on the fresh Captain (applies at reconcile), one duplicates an
    existing placement (conflict, dropped at reconcile)."""
    bs = sys_.beacons
    code = bs.region_code(region)
    lat, lon, _, _ = geohash.decode(region)
    spec = NodeSpec("NJ0", (lat, lon), proc_ms=15.0, slots=4)
    sys_.topo.nodes["NJ0"] = spec
    cap = Captain(sys_.sim, sys_.topo, spec)
    sys_.captains["NJ0"] = cap
    bs.register_node(cap)
    rep = bs.replicas[code]
    rep.register_task(Task(f"{SERVICE}/t_join", SERVICE, captain=cap))
    occ = next(n for n in sorted(bs.home)
               if bs.home[n] == code and n != "NJ0")
    rep.register_task(Task(f"{SERVICE}/t_dup", SERVICE,
                           captain=sys_.captains[occ]))


def _run_partition_cycle(tick, *, n_users=50, seed=0, cut_t=5_900.0,
                         heal_t=10_100.0, until=16_000.0):
    sys_ = _fluid_system(seed=seed, shard=3)
    # activate the data-locality score term so identity covers it too
    sys_.am.engine.set_data_locality(SERVICE, DATA_LOC, weight=0.15)
    rng = np.random.default_rng(seed + 1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, n_users),
                     -93.22 + rng.uniform(-.5, .5, n_users)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick=tick, shard_border_cap=n_users)
    sys_.sim.at(0.0, pool.start)
    region = sys_.beacons.busiest_region()
    sys_.partition_beacon(region, cut_t).heal_at(heal_t)
    sys_.sim.at(7_000.0, _stage_minority_work, sys_, region)
    snaps = {}
    for label, t in (("pre", cut_t - 50.0),
                     ("split", cut_t + 2_050.0),
                     ("reconciled", until - 50.0)):
        sys_.sim.at(t, lambda l=label: snaps.__setitem__(
            l, (pool.cand_task.copy(), pool.active.copy())))
    sys_.sim.run(until=until)
    return pool, sys_, snaps


def test_partition_heal_host_device_decision_identity():
    host, hs, hsnap = _run_partition_cycle("host")
    dev, ds, dsnap = _run_partition_cycle("device")
    _assert_decisions_equal(dev, host)
    for label in ("pre", "split", "reconciled"):
        np.testing.assert_array_equal(hsnap[label][0], dsnap[label][0],
                                      err_msg=f"cand@{label}")
        np.testing.assert_array_equal(hsnap[label][1], dsnap[label][1],
                                      err_msg=f"active@{label}")
    assert hs.beacons.events == ds.beacons.events
    # the cut visibly displaced routing, state genuinely diverged, and
    # the merge resolved the staged spawns one-applied one-dropped
    assert not np.array_equal(hsnap["pre"][0], hsnap["split"][0])
    rec = next(e for e in hs.beacons.events
               if e["kind"] == "beacon_reconcile")
    assert rec["divergence"] > 0 and rec["latency_ms"] > 0
    assert rec["staged"] == 1 and rec["conflicts"] == 1
    ids = [t.task_id for t in hs.am.tasks[SERVICE]]
    assert f"{SERVICE}/t_join" in ids and f"{SERVICE}/t_dup" not in ids
    # the data-locality metric is live on this population
    frac = host.data_local_fraction()
    assert np.isfinite(frac) and 0.0 <= frac <= 1.0


def test_partition_keeps_data_plane_alive():
    """Split-brain must not stall traffic on either side: every user
    keeps an active replica and frames keep flowing mid-partition."""
    pool, sys_, snaps = _run_partition_cycle("host", until=9_000.0,
                                             heal_t=8_900.0)
    cand, active = snaps["split"]
    assert (active >= 0).all(), "users lost actives during the partition"
    assert (cand >= 0).any(axis=1).all()
    assert np.isfinite(pool.mean_latency())


# ---------------------------------------------------------------------------
# partition semantics (divergence, staged deploys, reconciliation)
# ---------------------------------------------------------------------------

def test_partition_semantics_and_reconcile():
    sys_ = _fluid_system(seed=0, shard=3)
    bs = sys_.beacons
    sys_.sim.run(until=100.0)
    region = bs.busiest_region()
    code = bs.region_code(region)
    minority = sorted(n for n, h in bs.home.items() if h == code)
    gid = bs.partition(region)
    assert gid >= 1 and bs.partition_of[code] == gid
    # minority nodes hidden from majority selection; users handed off
    assert set(minority) <= set(bs.hidden_nodes())
    own = bs.ownership()
    assert own[code] != code and bs.group_of(own[code]) == 0
    # a bootstrap lookup from inside the cut reaches the cut replica
    lat, lon, _, _ = geohash.decode(region)
    assert bs.beacon_for((lat, lon)).region == code
    # a deploy through the minority replica stages — invisible globally
    rep = bs.replicas[code]
    t = Task("svc2/s0", "svc2", captain=sys_.captains[minority[0]])
    rep.register_task(t)
    assert t not in sys_.am.tasks.get("svc2", [])
    assert t in rep.pending_tasks
    # heal: ownership stays cut during the log exchange (the measurable
    # reconciliation window), then one merge reverts everything
    delay = bs.heal(region)
    assert delay > 0 and code in bs.partition_of
    sys_.sim.run(until=sys_.sim.now + delay + 10.0)
    assert code not in bs.partition_of
    assert not bs.hidden_nodes() and bs.ownership() == {}
    assert all(bs.serving[n] == code for n in minority)
    assert t in sys_.am.tasks["svc2"] and t.status == "running"
    rec = bs.events[-1]
    assert rec["kind"] == "beacon_reconcile"
    assert rec["staged"] == 1 and rec["divergence"] >= len(minority)
    assert rec["latency_ms"] >= delay


def test_partition_lww_merge_drops_stale_adopter_records():
    """Divergent registrations across the cut: nodes adopted by the
    majority during an earlier crash get reclaimed by their recovered
    home replica on the minority side — at heal, last-writer-wins keeps
    the minority's fresher record and drops the adopter's stale one."""
    sys_ = _fluid_system(seed=0, shard=3)
    bs = sys_.beacons
    sys_.sim.run(until=100.0)
    region = bs.busiest_region()
    code = bs.region_code(region)
    minority = sorted(n for n, h in bs.home.items() if h == code)
    bs.fail(region)
    sys_.sim.run(until=2_500.0)         # heartbeat replay: all adopted
    assert all(bs.serving[n] not in (None, code) for n in minority)
    bs.recover(region)
    bs.partition(region)                # cut lands before any re-home
    bs.heal(region)
    sys_.sim.run(until=10_000.0)
    rec = next(e for e in reversed(bs.events)
               if e["kind"] == "beacon_reconcile")
    assert rec["lww"] >= 1
    for n in minority:
        assert bs.serving[n] == code
        holders = [c for c, r in bs.replicas.items()
                   if n in r.registered_nodes]
        assert holders == [code], f"stale adopter record survived: {n}"


def test_partitioned_replica_crash_collapses_to_plain_failure():
    sys_ = _fluid_system(seed=0, shard=3)
    bs = sys_.beacons
    sys_.sim.run(until=100.0)
    region = bs.busiest_region()
    code = bs.region_code(region)
    bs.partition(region)
    rep = bs.replicas[code]
    rep.register_task(Task("svc2/s1", "svc2",
                           captain=next(iter(sys_.captains.values()))))
    assert rep.reg_log and rep.pending_tasks
    bs.fail(region)                     # the divergence log dies with it
    assert code not in bs.partition_of
    assert not rep.reg_log and not rep.pending_tasks
    with pytest.raises(ValueError, match="not partitioned"):
        bs.heal(region)
    sys_.sim.run(until=4_000.0)         # replay lands nodes on adopters
    assert not bs.hidden_nodes()


# ---------------------------------------------------------------------------
# jit stability: at most one transient per cut / per merge
# ---------------------------------------------------------------------------

def test_partition_heal_compiles_once_not_per_tick():
    from repro.core import fused_tick
    sys_ = _fluid_system(seed=0, shard=3)
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 50),
                     -93.22 + rng.uniform(-.5, .5, 50)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="geo_topk", tick="device", shard_border_cap=50)
    sys_.sim.at(0.0, pool.start)
    region = sys_.beacons.busiest_region()
    sys_.partition_beacon(region, 4_100.0).heal_at(12_100.0)

    sys_.sim.run(until=6_050.0)         # first post-cut tick: transient
    counts0 = dict(fused_tick.COMPILE_COUNTS)
    sys_.sim.run(until=12_050.0)        # steady split-brain ticks
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts0.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"partition retraced per tick: {delta}"
    sys_.sim.run(until=14_050.0)        # reconcile transient paid here
    counts1 = dict(fused_tick.COMPILE_COUNTS)
    sys_.sim.run(until=18_050.0)
    delta = {k: fused_tick.COMPILE_COUNTS[k] - counts1.get(k, 0)
             for k in fused_tick.COMPILE_COUNTS}
    assert all(v == 0 for v in delta.values()), \
        f"reconcile retraced per tick: {delta}"
    assert pool.ticks_run >= 8


# ---------------------------------------------------------------------------
# data-locality score preference (selection layer, off by default)
# ---------------------------------------------------------------------------

def test_data_locality_prefers_node_near_cargo():
    """Two replicas in a pure tie (equidistant, same free/net): the
    data-locality term breaks it toward the node within
    DATA_LOCAL_RADIUS_KM of the service's store, identically on the
    numpy, kernel, and sharded paths; clearing it restores the baseline
    argsort order bit-for-bit."""
    specs = [NodeSpec("far", (45.7, -93.0), proc_ms=20.0, slots=2),
             NodeSpec("near", (44.3, -93.0), proc_ms=20.0, slots=2)]
    tasks = _tie_tasks(specs)
    users = [(45.0, -93.0)]
    base = SelectionEngine(top_n=2).candidate_indices(
        "tie", tasks, users, "wifi")
    np.testing.assert_array_equal(base, [[0, 1]])   # tie -> task order
    data_at = ((44.3, -93.0),)
    for precision in (None, 1, 3):
        eng = SelectionEngine(top_n=2, shard_precision=precision)
        eng.set_data_locality("tie", data_at)
        got = eng.candidate_indices("tie", tasks, users, "wifi")
        np.testing.assert_array_equal(got, [[1, 0]],
                                      err_msg=f"numpy p={precision}")
        gk = eng.candidate_indices_kernel("tie", tasks, users, "wifi",
                                          node_pad=8)
        np.testing.assert_array_equal(gk, [[1, 0]],
                                      err_msg=f"kernel p={precision}")
    eng = SelectionEngine(top_n=2)
    eng.set_data_locality("tie", data_at)
    eng.set_data_locality("tie", ())                # placement lost
    np.testing.assert_array_equal(
        eng.candidate_indices("tie", tasks, users, "wifi"), base)


def test_cargo_placements_feed_selection_via_manager():
    """ArmadaSystem wiring: store_register pushes placements into the
    engine; a Cargo death re-publishes without the dead replica."""
    from repro.core.app_manager import ServiceSpec
    from repro.core.beacon import facerec_image
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=9, compute_nodes=["V3", "V4", "V5"],
                        cargo_nodes=["V1", "V2", "D6", "Cloud"])
    spec = ServiceSpec("face", facerec_image(), need_storage=True,
                       locations=[topo.nodes["V3"].loc])
    chosen = sys_.cargo_manager.store_register(spec, initial={"k0": b"x"})
    locs, weight = sys_.am.engine.data_locality["face"]
    assert len(locs) == len(chosen) == 3 and weight > 0
    sys_.fail_cargo(chosen[0].node_id, 10.0)
    sys_.sim.run(until=20.0)
    locs2, _ = sys_.am.engine.data_locality["face"]
    assert len(locs2) == 2
    assert tuple(map(float, chosen[0].spec.loc)) not in locs2


# ---------------------------------------------------------------------------
# stochastic partitions + guard rails
# ---------------------------------------------------------------------------

def test_partition_churn_model_spares_majority():
    sys_ = _fluid_system(seed=0, shard=3)
    churn = PartitionChurnModel(sys_.sim, sys_.beacons, mtbp_ms=3_000.0,
                                heal_ms=2_000.0)
    churn.start()
    sys_.sim.run(until=60_000.0)
    kinds = [e["kind"] for e in churn.events]
    assert kinds.count("partition") >= 2, "partition churn never fired"
    assert kinds.count("heal") >= 1
    # every cut got reconciled by the end (or is still in flight alone)
    assert len(sys_.beacons.partition_of) <= 1
    assert any(e["kind"] == "beacon_reconcile"
               for e in sys_.beacons.events)
    # replay: the majority side was never emptied
    total = len(sys_.beacons.replicas)
    cut = set()
    for e in churn.events:
        if e["kind"] == "partition":
            cut.add(e["region"])
            assert len(cut) < total, "majority emptied by partition churn"
        else:
            cut.discard(e["region"])


def test_partition_guard_rails():
    sys_ = _fluid_system(seed=0, shard=3)
    bs = sys_.beacons
    region = bs.busiest_region()
    with pytest.raises(ValueError, match="no live Beacon"):
        bs.partition("zzz")                 # unknown region
    with pytest.raises(ValueError, match="exactly 3 geohash chars"):
        bs.partition("zzzzzz")
    with pytest.raises(ValueError, match="no region is partitioned"):
        bs.heal()
    with pytest.raises(ValueError, match="not partitioned"):
        bs.heal(region)
    with pytest.raises(ValueError, match="every majority region"):
        bs.partition(list(bs.replicas))     # would cut off everyone
    bs.partition(region)
    with pytest.raises(ValueError, match="already partitioned"):
        bs.partition(region)
    bs.heal(region)
    with pytest.raises(ValueError, match="already reconciling"):
        bs.heal(region)
    # a dead replica cannot be partitioned (it is failed, not cut)
    other = next(bs.region_str(c) for c in sorted(bs.replicas)
                 if c != bs.region_code(region) and bs.replicas[c].alive)
    bs.fail(other)
    with pytest.raises(ValueError, match="no live Beacon"):
        bs.partition(other)
    # schedule-time validation + unsharded systems
    with pytest.raises(ValueError, match="exactly 3 geohash chars"):
        sys_.partition_beacon("zz", 100.0)
    flat = ArmadaSystem(real_world(), seed=0)
    with pytest.raises(RuntimeError, match="shard_precision"):
        flat.partition_beacon("9zv", 100.0)


def _ema_slots_locs():
    rng = np.random.default_rng(2)
    return np.stack([44.97 + rng.uniform(-.5, .5, 16),
                     -93.22 + rng.uniform(-.5, .5, 16)], axis=1)


def test_device_tick_ema_slots_overflow_is_loud():
    """``ClientPool(ema_slots=...)`` reaches the fused driver — a table
    too small for even one candidate refresh overflows loudly (the
    remedy named in the error is actually settable)."""
    import repro.core.fused_tick  # noqa: F401 — jax presence gate
    sys_ = _fluid_system(seed=1, shard=3)
    pool = sys_.make_client_pool(
        SERVICE, locs=_ema_slots_locs(), transport="fluid",
        frame_interval_ms=500.0, selection_backend="geo_topk",
        tick="device", shard_border_cap=16, ema_slots=1)
    sys_.sim.at(0.0, pool.start)
    with pytest.raises(RuntimeError, match="ema_slots"):
        sys_.sim.run(until=4_100.0)


@pytest.mark.slow
def test_device_tick_ema_slots_sized_matches_default():
    """A sized EMA table leaves decisions identical to the default."""
    import repro.core.fused_tick  # noqa: F401 — jax presence gate
    locs = _ema_slots_locs()

    def run(slots):
        s = _fluid_system(seed=1, shard=3)
        p = s.make_client_pool(
            SERVICE, locs=locs, transport="fluid",
            frame_interval_ms=500.0, selection_backend="geo_topk",
            tick="device", shard_border_cap=16, ema_slots=slots)
        s.sim.at(0.0, p.start)
        s.sim.run(until=6_100.0)
        return p
    _assert_decisions_equal(run(64), run(None))


def test_bench_partition_smoke_profile():
    """The registered benchmark's --smoke profile runs in tier-1 and
    records split-brain divergence, reconciliation latency, and the
    data-local failover fraction."""
    from benchmarks.bench_partition import run
    rows = run(smoke=True)
    assert rows
    derived = {name: d for name, _, d in rows}
    rec = [d for d in derived.values() if "reconcile_ms=" in d]
    assert rec, f"no reconciliation metrics recorded: {derived}"
    d = rec[0]
    assert float(d.split("reconcile_ms=")[1].split(";")[0]) > 0.0
    assert float(d.split("divergence=")[1].split(";")[0]) > 0.0
    frac = float(d.split("local_frac_handoff=")[1].split(";")[0])
    assert 0.0 <= frac <= 1.0

# ---------------------------------------------------------------------------
# Beacon-scoped autoscale (Spinner scheduling respects fault domains)
# ---------------------------------------------------------------------------

def test_autoscale_never_lands_on_partitioned_minority():
    """Demand-driven spawns must stay inside the scheduler's own
    reachability group: while a region is cut, its Captains are in
    ``engine.hidden_nodes`` and the majority's autoscale may not deploy
    replicas onto them — even though the overloaded cell's centroid sits
    exactly in the cut region, making its (hidden) Captains the
    geo-nearest placement targets."""
    sys_ = _fluid_system(seed=0, shard=3)
    rng = np.random.default_rng(1)
    locs = np.stack([44.97 + rng.uniform(-.5, .5, 600),
                     -93.22 + rng.uniform(-.5, .5, 600)], axis=1)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, transport="fluid", frame_interval_ms=500.0,
        selection_backend="numpy", tick="host", shard_border_cap=600)
    sys_.sim.at(0.0, pool.start)
    # 600 users on ~24 occupied nodes: every autoscale tick finds
    # overloaded regions and spawns (capacity never catches up — new
    # replicas land on already-counted nodes)
    sys_.am.autoscale_enabled = True
    sys_.am._schedule_autoscale(SERVICE)

    region = sys_.beacons.busiest_region()
    cut_t, heal_t = 4_900.0, 11_100.0
    sys_.partition_beacon(region, cut_t).heal_at(heal_t)
    mid: dict = {}
    sys_.sim.at(heal_t - 100.0, lambda: mid.update(
        hidden=set(sys_.am.engine.hidden_nodes),
        events=list(sys_.am.scale_events)))
    sys_.sim.run(until=14_000.0)

    assert mid["hidden"], "partition never hid the minority's nodes"
    in_window = [e for e in mid["events"] if cut_t < e["t"] < heal_t]
    assert in_window, "no autoscale activity during the partition"
    # deploy_log records node at PLACEMENT time, not readiness
    placed = [e for e in sys_.spinner.deploy_log
              if cut_t < e["t"] < heal_t]
    assert placed, "no replica actually placed during the partition"
    bad = [e["task"] for e in placed if e["node"] in mid["hidden"]]
    assert not bad, f"autoscale deployed onto unreachable minority: {bad}"
    assert pool.ticks_run > 0
