"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus VMEM-budget sanity for the TPU tiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geohash
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_mha_reference
from repro.kernels.geo_topk import tune as geo_tune
from repro.kernels.geo_topk.kernel import (geo_topk_pallas,
                                           geo_topk_tiled_pallas,
                                           vmem_bytes_tiled)
from repro.kernels.geo_topk.kernel import vmem_bytes as geo_vmem
from repro.kernels.geo_topk.ops import geo_topk, pack_inputs
from repro.kernels.geo_topk.ref import geo_topk_reference
from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.moe_gmm.kernel import gmm_pallas
from repro.kernels.moe_gmm.ref import gmm_reference
from repro.kernels.ssm_scan.kernel import ssd_scan_pallas
from repro.kernels.ssm_scan.kernel import vmem_bytes as ssd_vmem
from repro.kernels.ssm_scan.ref import (ssd_chunked_reference,
                                        ssd_decode_step, ssd_sequential)

RNG = np.random.default_rng(42)

# interpret-mode kernel sweeps are priced in seconds per case on CPU:
# tier-1 keeps the float32 parity pin per kernel family and one layout
# case per geo_topk variant; the rest ride the slow marker
BF16_SLOW = pytest.param(jnp.bfloat16, marks=pytest.mark.slow)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, Hq, Hkv, Tq, Tk, D, causal, offset
    (2, 4, 2, 128, 128, 64, True, 0),
    pytest.param((1, 8, 8, 96, 96, 32, True, 0), marks=pytest.mark.slow),
    pytest.param((1, 4, 1, 64, 256, 64, True, 192),
                 marks=pytest.mark.slow),  # chunked prefill w/ offset
    (2, 2, 2, 50, 200, 128, False, 0),     # non-causal (encoder), ragged
    pytest.param((1, 6, 3, 33, 65, 16, True, 0),
                 marks=pytest.mark.slow),  # odd sizes -> padding path
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_flash_attention_matches_reference(case, dtype):
    B, Hq, Hkv, Tq, Tk, D, causal, off = case
    q = jnp.asarray(RNG.normal(size=(B, Hq, Tq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Tk, D)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, q_offset=off,
                                 block_q=32, block_k=32, interpret=True)
    ref = mha_reference(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_sliding_window():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=32,
                                 block_q=32, block_k=32, interpret=True)
    ref = mha_reference(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_flash_attention_vmem_budget():
    # production tile sizes must fit v5e VMEM (~128 MB, use <= half)
    assert fa_kernel.vmem_bytes(128, 128, 128) < 64 * 2**20


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DEC_CASES = [
    (2, 4, 2, 512, 64),
    pytest.param((1, 8, 1, 300, 128), marks=pytest.mark.slow),
    pytest.param((4, 2, 2, 64, 32), marks=pytest.mark.slow),
    pytest.param((3, 12, 4, 100, 16), marks=pytest.mark.slow),
]


@pytest.mark.parametrize("case", DEC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_decode_attention_matches_reference(case, dtype):
    B, Hq, Hkv, S, D = case
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    lens = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    out = decode_attention_pallas(q, k, v, lens, block_s=128, interpret=True)
    ref = decode_mha_reference(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_ignores_padding():
    """Entries past ``lengths`` must not affect the result."""
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 32
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    lens = jnp.asarray([10, 20], jnp.int32)
    out1 = decode_attention_pallas(q, k, v, lens, interpret=True)
    k2 = k.at[:, :, 30:].set(999.0)
    v2 = v.at[:, :, 30:].set(-999.0)
    out2 = decode_attention_pallas(q, k2, v2, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

GMM_CASES = [
    (4, 64, 128, 256),
    pytest.param((2, 100, 96, 130), marks=pytest.mark.slow),
    pytest.param((8, 32, 64, 64), marks=pytest.mark.slow),
    (1, 17, 33, 65),               # ragged: the padding path stays pinned
]


@pytest.mark.parametrize("case", GMM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_gmm_matches_reference(case, dtype):
    E, C, D, F = case
    x = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, D, F)), dtype)
    out = gmm_pallas(x, w, block_c=32, block_f=64, block_d=64,
                     interpret=True)
    ref = gmm_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype) * np.sqrt(D), rtol=5e-2 if dtype == jnp.bfloat16
        else 1e-4)


# ---------------------------------------------------------------------------
# generalized SSD scan (Mamba2 + mLSTM styles)
# ---------------------------------------------------------------------------

def _ssd_inputs(B, T, H, P, N, style, per_head):
    x = jnp.asarray(RNG.normal(size=(B, T, H, P)), jnp.float32)
    if style == "mamba2":
        dt = np.abs(RNG.normal(size=(B, T, H))) * 0.5 + 0.01
        A = -np.abs(RNG.normal(size=(H,))) - 0.1
        g = jnp.asarray(dt * A, jnp.float32)
        s = jnp.asarray(dt, jnp.float32)
    else:  # mlstm
        f = RNG.normal(size=(B, T, H)) + 2.0
        g = jnp.asarray(np.log(1 / (1 + np.exp(-f))), jnp.float32)
        s = jnp.asarray(np.exp(RNG.normal(size=(B, T, H)) * 0.4 - 1),
                        jnp.float32)
    bc_shape = (B, T, H, N) if per_head else (B, T, N)
    Bm = jnp.asarray(RNG.normal(size=bc_shape), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=bc_shape), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    return x, g, s, Bm, Cm, D


@pytest.mark.parametrize("style", ["mamba2", "mlstm"])
@pytest.mark.parametrize("per_head",
                         [False, pytest.param(True,
                                              marks=pytest.mark.slow)])
@pytest.mark.parametrize(
    "shape", [(2, 64, 3, 16, 8, 16),
              pytest.param((1, 100, 2, 32, 16, 32),
                           marks=pytest.mark.slow)])
def test_ssd_chunked_and_pallas_match_sequential(style, per_head, shape):
    B, T, H, P, N, chunk = shape
    x, g, s, Bm, Cm, D = _ssd_inputs(B, T, H, P, N, style, per_head)
    y_seq, h_seq = ssd_sequential(x, g, s, Bm, Cm, D)
    y_chk, _ = ssd_chunked_reference(x, g, s, Bm, Cm, D, chunk=chunk)
    y_pal, h_pal = ssd_scan_pallas(x, g, s, Bm, Cm, D, chunk=chunk,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_seq),
                               atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_seq),
                               atol=5e-3, rtol=1e-3)


def test_ssd_decode_chain_equals_sequential():
    B, T, H, P, N = 2, 32, 2, 8, 8
    x, g, s, Bm, Cm, D = _ssd_inputs(B, T, H, P, N, "mamba2", False)
    y_seq, h_seq = ssd_sequential(x, g, s, Bm, Cm, D)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(T):
        y, h = ssd_decode_step(h, x[:, t], g[:, t], s[:, t], Bm[:, t],
                               Cm[:, t], D)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_seq), atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_seq), atol=5e-3)


def test_ssd_vmem_budget():
    assert ssd_vmem(256, 64, 128) < 64 * 2**20


# ---------------------------------------------------------------------------
# fused geo-selection top-k
# ---------------------------------------------------------------------------

def _geo_inputs(u, n, spread=0.5, seed=0):
    rng = np.random.default_rng(seed)
    base = (44.97, -93.22)
    ulat = base[0] + rng.uniform(-spread, spread, u)
    ulon = base[1] + rng.uniform(-spread, spread, u)
    nlat = base[0] + rng.uniform(-spread, spread, n)
    nlon = base[1] + rng.uniform(-spread, spread, n)
    unet = rng.integers(0, 3, u)
    nnet = rng.integers(0, 3, n)
    nfree = rng.uniform(0, 1, n)
    uc = geohash.encode_batch(ulat, ulon, 9)
    nc = geohash.encode_batch(nlat, nlon, 9)
    return pack_inputs(ulat, ulon, unet, uc, nlat, nlon, nfree, nnet, nc)


GEO_CASES = [
    # U, N, k, block_u — exercise padding on every axis
    (64, 128, 3, 32),
    pytest.param((50, 37, 5, 16),
                 marks=pytest.mark.slow),      # ragged U and N
    pytest.param((8, 3, 3, 8),
                 marks=pytest.mark.slow),      # k == N: all selected
    pytest.param((130, 257, 8, 128), marks=pytest.mark.slow),
]


@pytest.mark.parametrize("case", GEO_CASES)
def test_geo_topk_pallas_matches_oracle(case):
    u, n, k, bu = case
    packed = _geo_inputs(u, n, seed=u + n)
    need = min(4, n)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=k, need=need)
    s_pal, i_pal = geo_topk_pallas(*packed, k=k, need=need, block_u=bu,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_pal), np.asarray(i_ref))


@pytest.mark.parametrize(
    "spread", [pytest.param(0.02, marks=pytest.mark.slow), 5.0])
def test_geo_topk_proximity_filter_consistency(spread):
    """Tight clusters trigger the high-precision filter path; global
    spreads fall through to lower precisions — both must match."""
    packed = _geo_inputs(40, 64, spread=spread, seed=3)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=4, need=4)
    s_pal, i_pal = geo_topk_pallas(*packed, k=4, need=4, block_u=16,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(i_pal), np.asarray(i_ref))
    assert np.isfinite(np.asarray(s_ref)).all()


def test_geo_topk_op_dispatches_to_oracle_on_cpu():
    packed = _geo_inputs(16, 24, seed=11)
    s_op, i_op = geo_topk(packed, k=3)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=3, need=4)
    np.testing.assert_array_equal(np.asarray(i_op), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(s_op), np.asarray(s_ref),
                               atol=1e-6, rtol=1e-6)


def test_geo_topk_vmem_budget():
    # production tile: 128 users x 4096 nodes must fit half a v5e VMEM
    assert geo_vmem(128, 4096) < 64 * 2**20


# ---------------------------------------------------------------------------
# node-tiled geo top-k (past the all-nodes-in-VMEM wall)
# ---------------------------------------------------------------------------

def _geo_inputs_valid(u, n, spread=0.5, seed=0, valid=None):
    rng = np.random.default_rng(seed)
    base = (44.97, -93.22)
    ulat = base[0] + rng.uniform(-spread, spread, u)
    ulon = base[1] + rng.uniform(-spread, spread, u)
    nlat = base[0] + rng.uniform(-spread, spread, n)
    nlon = base[1] + rng.uniform(-spread, spread, n)
    return pack_inputs(ulat, ulon, rng.integers(0, 3, u),
                       geohash.encode_batch(ulat, ulon, 9),
                       nlat, nlon, rng.uniform(0, 1, n),
                       rng.integers(0, 3, n),
                       geohash.encode_batch(nlat, nlon, 9), valid)


TILED_CASES = [
    # U, N, k, block_u, node_tile — N spans multiple tiles, ragged too
    (48, 640, 3, 16, 256),
    pytest.param((20, 1000, 5, 8, 128), marks=pytest.mark.slow),
    pytest.param((8, 257, 4, 8, 128),
                 marks=pytest.mark.slow),  # ragged final tile
    pytest.param((16, 128, 3, 8, 128),
                 marks=pytest.mark.slow),  # single tile degenerates
]


@pytest.mark.parametrize("case", TILED_CASES)
def test_geo_topk_tiled_matches_oracle(case):
    u, n, k, bu, nt = case
    packed = _geo_inputs_valid(u, n, seed=u + n)
    need = min(4, n)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=k, need=need)
    s_t, i_t = geo_topk_tiled_pallas(*packed, k=k, need=need, block_u=bu,
                                     node_tile=nt, interpret=True)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_ref))


def test_geo_topk_tiled_ties_at_tile_boundary():
    """Equal-score nodes straddling a tile edge must resolve to the
    lowest global index, exactly like ``lax.top_k`` over the full row."""
    u, n, nt = 8, 384, 128
    packed = _geo_inputs_valid(u, n, seed=5)
    # clone node 126's full scoring identity across the 128-boundary
    for fld in ("node_lat", "node_lon", "node_free", "node_code20"):
        arr = getattr(packed, fld)
        arr[125:132] = arr[126]
    packed.node_aff[:, 125:132] = packed.node_aff[:, 126:127]
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=6, need=4)
    s_t, i_t = geo_topk_tiled_pallas(*packed, k=6, need=4, block_u=8,
                                     node_tile=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               atol=1e-5)


@pytest.mark.slow
def test_geo_topk_tiled_all_invalid_tiles():
    """Whole-tile invalid spans (churned-out nodes / jit padding) and the
    fully-invalid query both match the reference."""
    u, n, nt = 12, 512, 128
    valid = np.ones(n, np.float32)
    valid[128:256] = 0.0                     # one entirely dead tile
    valid[500:] = 0.0
    packed = _geo_inputs_valid(u, n, seed=9, valid=valid)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=4, need=4)
    s_t, i_t = geo_topk_tiled_pallas(*packed, k=4, need=4, block_u=8,
                                     node_tile=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_ref))

    packed = _geo_inputs_valid(u, n, seed=10,
                               valid=np.zeros(n, np.float32))
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=3, need=4)
    s_t, i_t = geo_topk_tiled_pallas(*packed, k=3, need=4, block_u=8,
                                     node_tile=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_ref))
    assert (np.asarray(s_t) < -1e29).all()


@pytest.mark.slow
def test_geo_topk_tiled_validates_at_64k_nodes():
    """The acceptance regime: N >= 64k — far past the untiled kernel's
    VMEM wall — still matches the reference exactly."""
    u, n = 8, 65536
    packed = _geo_inputs_valid(u, n, seed=3)
    s_ref, i_ref = geo_topk_reference(
        *[jnp.asarray(a) for a in packed], k=8, need=4)
    s_t, i_t = geo_topk_tiled_pallas(*packed, k=8, need=4, block_u=8,
                                     node_tile=8192, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               atol=1e-5, rtol=1e-5)


def test_geo_topk_tiled_vmem_independent_of_n():
    # the tiled budget is a function of the tile, not the fleet size —
    # this is what lifts the N ≲ 16k cap to 100k+ nodes
    assert vmem_bytes_tiled(128, 2048) < 64 * 2**20
    assert vmem_bytes_tiled(256, 8192) < 64 * 2**20
    assert geo_vmem(128, 131072) > 64 * 2**20      # untiled would not fit


@pytest.mark.slow       # registration smoke, not an identity pin
def test_geo_topk_autotune_smoke_end_to_end(monkeypatch, tmp_path):
    """The registered ``bench_autotune --smoke`` profile: a tiny
    interpret-mode sweep must run both layouts, cache a winner, and the
    dispatcher must serve it.  Cache and artifact are sandboxed so the
    smoke winner can't leak into other tests or the working tree."""
    import benchmarks.bench_autotune as ba
    monkeypatch.setattr(ba, "CACHE_PATH", tmp_path / "geo_topk.json")
    geo_tune.clear_cache()
    try:
        rows = ba.run(smoke=True)
        assert rows and any("winner=True" in r[2] for r in rows)
        assert (tmp_path / "geo_topk.json").exists()
        u, n, k = 32, 128, 4
        cfg = geo_tune.get_config(u, n, k)
        assert geo_tune.cache_key(u, n, k) in geo_tune._CACHE
        assert cfg in geo_tune.candidate_configs(u, n, k) + \
            [(32, None), (32, 64)]
        # winner actually dispatches through ops.geo_topk
        packed = _geo_inputs_valid(u, n, seed=1)
        s, i = geo_topk(packed, k=k, force_pallas=True, interpret=True)
        s_ref, i_ref = geo_topk_reference(
            *[jnp.asarray(a) for a in packed], k=k, need=4)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    finally:
        geo_tune.clear_cache()
