"""Client-pool scaling: 100k live users end-to-end through the simulator.

``bench_selection_scale`` showed the selection control plane handles
10k×1k batches; this bench closes the loop — the whole client data plane
(periodic probing, per-candidate EMAs, two-round switches, failover under
churn) runs population-scale through ``ClientPool``'s fluid transport.
Three tick modes are swept:

* ``numpy`` — host tick, float64 numpy selection + policy update;
* ``geo_topk`` — host tick, fused fp32 scoring on device, policy on host;
* ``device`` — the fused device-resident tick (``repro.core.fused_tick``):
  scoring → top-k → EMA fold → switch → failover as ONE jitted program,
  state donated across ticks.

Each row's ``derived`` carries a per-phase wall-time breakdown
(``selection`` / ``policy`` / ``transport`` on host ticks,
``fused_tick`` / ``transport`` on the device tick) so fusion wins are
attributable in ``artifacts/bench/results.json``; the full sweep appends
speedup rows for the headline 100k × 1k profile (device vs both host
ticks — the ≥3× target from ROADMAP's "Pool jnp tick fusion" item is
measured against the numpy tick).

Default sweep ends at the headline 100k users × 1k nodes run (probing +
frames + volunteer churn); ``run(smoke=True)`` (or ``--smoke`` on the
CLI) is a seconds-scale profile exercised by tier-1 tests.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.churn import ChurnModel
from repro.core.cluster import NodeSpec, Topology

_METRO = (44.97, -93.22)
SERVICE = "detect"


def _system(n_nodes: int, seed: int) -> ArmadaSystem:
    """Metro-area fleet with one running replica per node.

    Tasks are registered directly (the ``ensure_cloud_replica`` idiom)
    instead of through Spinner deploys — the bench measures the client
    data plane, not image pulls.
    """
    rng = np.random.default_rng(seed)
    nets = ("wifi", "ethernet", "lte")
    nodes = {}
    for i in range(n_nodes):
        nodes[f"N{i}"] = NodeSpec(
            f"N{i}",
            (_METRO[0] + float(rng.uniform(-0.5, 0.5)),
             _METRO[1] + float(rng.uniform(-0.5, 0.5))),
            proc_ms=float(rng.uniform(10, 30)),
            slots=int(rng.integers(4, 17)),
            dedicated=bool(rng.random() < 0.2),
            net_type=nets[int(rng.integers(len(nets)))])
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def _bench_case(n_users: int, n_nodes: int, n_ticks: int,
                seed: int = 0, probe_period: float = 2000.0,
                frame_interval: float = 1000.0,
                mode: str = "geo_topk"):
    """``mode``: ``numpy``/``geo_topk`` (host tick, backend named) or
    ``device`` (fused device-resident tick)."""
    sys_ = _system(n_nodes, seed)
    rng = np.random.default_rng(seed + 1)
    locs = np.stack(
        [_METRO[0] + rng.uniform(-0.5, 0.5, n_users),
         _METRO[1] + rng.uniform(-0.5, 0.5, n_users)], axis=1)
    tick = "device" if mode == "device" else "host"
    backend = "geo_topk" if mode == "device" else mode
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, nets="wifi", transport="fluid",
        probe_period_ms=probe_period, frame_interval_ms=frame_interval,
        selection_backend=backend, tick=tick, record_samples=False)
    sys_.sim.at(0.0, pool.start)
    # volunteer churn: non-dedicated nodes fail/recover throughout the run
    churn = ChurnModel(sys_.sim, sys_.captains,
                       volunteer_mttf_ms=40 * probe_period,
                       mttr_ms=5 * probe_period)
    churn.start()

    horizon = n_ticks * probe_period
    t0 = time.perf_counter()
    sys_.sim.run(until=horizon)
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert not sys_.sim.truncated
    assert pool.ticks_run >= n_ticks - 1, pool.ticks_run
    per_tick = wall_ms / max(pool.ticks_run, 1)
    req_per_s = pool.requests_sent / (wall_ms / 1e3)
    leaves = sum(1 for e in churn.events if e["kind"] == "leave")
    phases = ";".join(
        f"phase_{k}_ms={v / max(pool.ticks_run, 1):.1f}"
        for k, v in sorted(pool.phase_ms.items()))
    tag = f"client_scale/u{n_users}_n{n_nodes}/{mode}"
    return [(tag, per_tick,
             f"ticks={pool.ticks_run};reqs={pool.requests_sent};"
             f"req_per_s={req_per_s:.0f};node_failures={leaves};"
             f"failovers={pool.failovers};"
             f"mean_frame_ms={pool.mean_latency():.1f};{phases}")]


def run(smoke: bool = False):
    if smoke:
        # seconds-scale tier-1 profile: small enough that jit compilation,
        # not the swept population, is the dominant cost
        sweep = [(256, 64, 4, "numpy"),
                 (256, 64, 4, "device")]
    else:
        # numpy wins at small N (no jit round-trip); the fused geo_topk
        # oracle takes over once U x N scoring dominates the tick, and
        # the device-resident tick removes the remaining host round-trips
        sweep = [(10_000, 100, 10, "numpy"),
                 (10_000, 1_000, 10, "numpy"),
                 (10_000, 1_000, 10, "geo_topk"),
                 (100_000, 1_000, 15, "numpy"),
                 (100_000, 1_000, 15, "geo_topk"),
                 (100_000, 1_000, 15, "device")]
    rows = []
    for n_users, n_nodes, n_ticks, mode in sweep:
        rows.extend(_bench_case(n_users, n_nodes, n_ticks, mode=mode))
    return rows


def derive(us_by_name):
    """Headline speedups (device tick vs both host ticks), recomputed by
    the runner over the merged result set so ``--only`` partial runs can
    never pair a fresh measurement with a stale one."""
    pre = "client_scale/u100000_n1000/"
    rows = []
    dev = us_by_name.get(pre + "device")
    for base in ("numpy", "geo_topk"):
        b = us_by_name.get(pre + base)
        if b and dev and b == b and dev == dev:
            rows.append((f"{pre}speedup_device_vs_{base}",
                         float("nan"), f"speedup={b / dev:.2f}x"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale profile (small U/N)")
    args = ap.parse_args()
    print("name,ms_per_tick,derived")
    rows = run(smoke=args.smoke)
    for name, ms, derived in rows:
        print(f"{name},{ms:.1f},{derived}")
    for name, ms, derived in derive({n: m * 1e3 for n, m, _ in rows}):
        print(f"{name},{ms:.1f},{derived}")
