"""Client-pool scaling: 100k live users end-to-end through the simulator.

``bench_selection_scale`` showed the selection control plane handles
10k×1k batches; this bench closes the loop — the whole client data plane
(periodic probing, per-candidate EMAs, two-round switches, failover under
churn) runs population-scale through ``ClientPool``'s fluid transport.
Three tick modes are swept:

* ``numpy`` — host tick, float64 numpy selection + policy update;
* ``geo_topk`` — host tick, fused fp32 scoring on device, policy on host;
* ``device`` — the fused device-resident tick (``repro.core.fused_tick``):
  scoring → top-k → EMA fold → switch → failover as ONE jitted program,
  state donated across ticks.

Each row's ``derived`` carries a per-phase wall-time breakdown
(``selection`` / ``policy`` / ``transport`` on host ticks,
``fused_tick`` / ``transport`` on the device tick) so fusion wins are
attributable in ``artifacts/bench/results.json``; the full sweep appends
speedup rows for the headline 100k × 1k profile (device vs both host
ticks — the ≥3× target from ROADMAP's "Pool jnp tick fusion" item is
measured against the numpy tick).

Default sweep ends at the headline 100k users × 1k nodes run (probing +
frames + volunteer churn), then a steady-state comparison pair
(``device_full`` vs ``device_inc``) under identical gentle churn that
isolates what incremental candidate refresh (``refresh_period_ms``)
buys: the ``speedup_incremental`` derived row is the ISSUE's ≥5×
target, and both rows carry per-tick dirty-fraction columns.
``run(smoke=True)`` (or ``--smoke`` on the CLI) is a seconds-scale
profile exercised by tier-1 tests and includes a ``device_inc`` case.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.churn import ChurnModel
from repro.core.cluster import NodeSpec, Topology

_METRO = (44.97, -93.22)
SERVICE = "detect"


def _system(n_nodes: int, seed: int) -> ArmadaSystem:
    """Metro-area fleet with one running replica per node.

    Tasks are registered directly (the ``ensure_cloud_replica`` idiom)
    instead of through Spinner deploys — the bench measures the client
    data plane, not image pulls.
    """
    rng = np.random.default_rng(seed)
    nets = ("wifi", "ethernet", "lte")
    nodes = {}
    for i in range(n_nodes):
        nodes[f"N{i}"] = NodeSpec(
            f"N{i}",
            (_METRO[0] + float(rng.uniform(-0.5, 0.5)),
             _METRO[1] + float(rng.uniform(-0.5, 0.5))),
            proc_ms=float(rng.uniform(10, 30)),
            slots=int(rng.integers(4, 17)),
            dedicated=bool(rng.random() < 0.2),
            net_type=nets[int(rng.integers(len(nets)))])
    topo = Topology(nodes, {})
    sys_ = ArmadaSystem(topo, seed=seed, trace_enabled=False,
                        include_cloud_compute=False)
    sys_.am.services[SERVICE] = ServiceSpec(SERVICE, detection_image())
    sys_.am.tasks[SERVICE] = []
    sys_.am.users[SERVICE] = []
    for i, cap in enumerate(sys_.captains.values()):
        t = Task(f"{SERVICE}/t{i}", SERVICE, captain=cap, status="running",
                 ready_at=0.0)
        cap.tasks[t.task_id] = t
        sys_.am.tasks[SERVICE].append(t)
    sys_.am.autoscale_enabled = False
    return sys_


def _bench_case(n_users: int, n_nodes: int, n_ticks: int,
                seed: int = 0, probe_period: float = 2000.0,
                frame_interval: float = 1000.0,
                mode: str = "geo_topk", mttf_factor: float = 40.0,
                warm_ticks: int = 0):
    """``mode``: ``numpy``/``geo_topk`` (host tick, backend named),
    ``device`` (fused device-resident tick), or the steady-state
    comparison pair ``device_full`` / ``device_inc`` (identical fused
    tick, the latter with incremental candidate refresh:
    ``refresh_period_ms`` at 20 probe periods, ``refresh_cap`` U/8).
    ``warm_ticks`` excludes jit compilation + tracker ramp-up from the
    timed window so the pair measures steady-state per-tick cost."""
    sys_ = _system(n_nodes, seed)
    rng = np.random.default_rng(seed + 1)
    locs = np.stack(
        [_METRO[0] + rng.uniform(-0.5, 0.5, n_users),
         _METRO[1] + rng.uniform(-0.5, 0.5, n_users)], axis=1)
    tick = "device" if mode.startswith("device") else "host"
    backend = "geo_topk" if mode.startswith("device") else mode
    kw = {}
    if mode == "device_inc":
        kw["refresh_period_ms"] = 20 * probe_period
        kw["refresh_cap"] = max(128, n_users // 8)
    pool = sys_.make_client_pool(
        SERVICE, locs=locs, nets="wifi", transport="fluid",
        probe_period_ms=probe_period, frame_interval_ms=frame_interval,
        selection_backend=backend, tick=tick, record_samples=False, **kw)
    sys_.sim.at(0.0, pool.start)
    # volunteer churn: non-dedicated nodes fail/recover throughout the run
    churn = ChurnModel(sys_.sim, sys_.captains,
                       volunteer_mttf_ms=mttf_factor * probe_period,
                       mttr_ms=5 * probe_period)
    churn.start()

    if warm_ticks:
        sys_.sim.run(until=warm_ticks * probe_period)
    ticks0, dirty0 = pool.ticks_run, len(pool.dirty_counts or ())
    horizon = (warm_ticks + n_ticks) * probe_period
    t0 = time.perf_counter()
    sys_.sim.run(until=horizon)
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert not sys_.sim.truncated
    timed = pool.ticks_run - ticks0
    assert timed >= n_ticks - 1, timed
    per_tick = wall_ms / max(timed, 1)
    req_per_s = pool.requests_sent / (wall_ms / 1e3)
    leaves = sum(1 for e in churn.events if e["kind"] == "leave")
    phases = ";".join(
        f"phase_{k}_ms={v / max(pool.ticks_run, 1):.1f}"
        for k, v in sorted(pool.phase_ms.items()))
    dirty = ""
    counts = pool.dirty_counts
    if counts is not None:
        counts = counts[dirty0:]
        fracs = [c / n_users for c in counts]
        mean = sum(fracs) / max(len(fracs), 1)
        dirty = (f";dirty_frac_mean={mean:.4f};dirty_frac_ticks=" +
                 "|".join(f"{f:.4f}" for f in fracs))
    tag = f"client_scale/u{n_users}_n{n_nodes}/{mode}"
    return [(tag, per_tick,
             f"ticks={pool.ticks_run};reqs={pool.requests_sent};"
             f"req_per_s={req_per_s:.0f};node_failures={leaves};"
             f"failovers={pool.failovers};"
             f"mean_frame_ms={pool.mean_latency():.1f};{phases}{dirty}")]


def run(smoke: bool = False):
    if smoke:
        # seconds-scale tier-1 profile: small enough that jit compilation,
        # not the swept population, is the dominant cost (device_inc
        # registers the incremental-refresh mode so --smoke exercises the
        # sparse program + tracker end-to-end)
        sweep = [(256, 64, 4, "numpy", {}),
                 (256, 64, 4, "device", {}),
                 (256, 64, 4, "device_inc", {})]
    else:
        # numpy wins at small N (no jit round-trip); the fused geo_topk
        # oracle takes over once U x N scoring dominates the tick, and
        # the device-resident tick removes the remaining host round-trips
        pair = {"mttf_factor": 400.0, "warm_ticks": 3}
        sweep = [(10_000, 100, 10, "numpy", {}),
                 (10_000, 1_000, 10, "numpy", {}),
                 (10_000, 1_000, 10, "geo_topk", {}),
                 (100_000, 1_000, 15, "numpy", {}),
                 (100_000, 1_000, 15, "geo_topk", {}),
                 (100_000, 1_000, 15, "device", {}),
                 # steady-state incremental pair: identical gentle churn
                 # (mttf 400 probe periods — a few node events per run,
                 # not a fleet-wide storm), jit warmup excluded, only the
                 # refresh strategy differs
                 (100_000, 1_000, 15, "device_full", pair),
                 (100_000, 1_000, 15, "device_inc", pair)]
    rows = []
    for n_users, n_nodes, n_ticks, mode, kw in sweep:
        rows.extend(_bench_case(n_users, n_nodes, n_ticks, mode=mode, **kw))
    return rows


def derive(us_by_name):
    """Headline speedups (device tick vs both host ticks), recomputed by
    the runner over the merged result set so ``--only`` partial runs can
    never pair a fresh measurement with a stale one."""
    pre = "client_scale/u100000_n1000/"
    rows = []
    dev = us_by_name.get(pre + "device")
    for base in ("numpy", "geo_topk"):
        b = us_by_name.get(pre + base)
        if b and dev and b == b and dev == dev:
            rows.append((f"{pre}speedup_device_vs_{base}",
                         None, f"speedup={b / dev:.2f}x"))
    inc = us_by_name.get(pre + "device_inc")
    full = us_by_name.get(pre + "device_full")
    if inc and full and inc == inc and full == full:
        rows.append((f"{pre}speedup_incremental",
                     None, f"speedup={full / inc:.2f}x"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale profile (small U/N)")
    args = ap.parse_args()
    print("name,ms_per_tick,derived")
    rows = run(smoke=args.smoke)
    for name, ms, derived in rows:
        print(f"{name},{ms:.1f},{derived}")
    for name, ms, derived in derive({n: m * 1e3 for n, m, _ in rows}):
        print(f"{name},{'' if ms is None else f'{ms:.1f}'},{derived}")
