"""Table 6 — latency-sensitive service selection.

Reproduces both halves: (a) real-world C1-C3 against V1-V5/D6/Cloud and
(b) emulation User_A/B/C against A/B/C/Cloud.  The derived column reports
the selected node; the paper's selections are C1→V1, C2→V2, C3→D6 and
User_A→A, User_B→B, User_C→A.
"""
from __future__ import annotations

from benchmarks.common import (MEASURE, WARM, emulation_system, mean_latency,
                               realworld_system, run_clients)

PAPER_CHOICE = {"C1": "V1", "C2": "V2", "C3": "D6",
                "User_A": "A", "User_B": "B", "User_C": "A"}


def run():
    rows = []
    sys_ = realworld_system(seed=1, autoscale=False)
    clients = run_clients(sys_, ["C1", "C2", "C3"], "armada")
    for cid, c in clients.items():
        node = c.active.captain.node_id
        rows.append((f"table6a/{cid}", c.mean_latency(since=WARM + 10_000),
                     f"selected={node};paper={PAPER_CHOICE[cid]};"
                     f"match={node == PAPER_CHOICE[cid]}"))
    sys_ = emulation_system(seed=1)
    clients = run_clients(sys_, ["User_A", "User_B", "User_C"], "armada")
    for cid, c in clients.items():
        node = c.active.captain.node_id
        rows.append((f"table6b/{cid}", c.mean_latency(since=WARM + 10_000),
                     f"selected={node};paper={PAPER_CHOICE[cid]};"
                     f"match={node == PAPER_CHOICE[cid]}"))
    return rows
