"""Figure 9 — fast auto-scaling and Captain registration.

(a) task deployment time under Armada's docker-aware + prefetch policy vs
random and anti-affinity selection (paper: Armada fastest).
(b) Captain registration latency vs K3s/K8s (paper: 57%/86% faster).
"""
from __future__ import annotations

from repro.core.app_manager import ServiceSpec, Task
from repro.core.beacon import ArmadaSystem, detection_image
from repro.core.cluster import real_world
from repro.core.spinner import (K3S_REGISTRATION_MS, K8S_REGISTRATION_MS,
                                REGISTRATION_MS)


def _deploy_times(selection: str, n_tasks: int = 8, seed: int = 5):
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=seed)
    spec = ServiceSpec("detect", detection_image(),
                       locations=[topo.nodes["D6"].loc], min_replicas=3)
    sys_.am.deploy_service(spec, selection=selection)
    sys_.sim.run(until=60_000.0)
    # auto-scale burst: deploy more replicas under the given policy
    times = []
    for i in range(n_tasks):
        t = Task(f"scale/{selection}/{i}", "detect")
        dt = sys_.spinner.deploy_task(t, spec.image,
                                      topo.nodes["D6"].loc,
                                      selection=selection)
        if dt is not None:
            times.append(dt)
        sys_.sim.run(until=sys_.sim.now + 3_000.0)
    return sum(times) / len(times) if times else float("nan")


def run():
    rows = []
    for sel in ("armada", "random", "anti-affinity"):
        rows.append((f"fig9a/deploy/{sel}", _deploy_times(sel), ""))
    rows.append(("fig9b/register/armada", REGISTRATION_MS,
                 f"vs_k3s={100 * (1 - REGISTRATION_MS / K3S_REGISTRATION_MS):.0f}%;paper=57%"))
    rows.append(("fig9b/register/k3s", K3S_REGISTRATION_MS, ""))
    rows.append(("fig9b/register/k8s", K8S_REGISTRATION_MS,
                 f"vs_k8s={100 * (1 - REGISTRATION_MS / K8S_REGISTRATION_MS):.0f}%;paper=86%"))
    return rows
