"""Storage layer benches — Table 7, Figure 11, Figures 12/13.

Face-recognition Cargo workloads: 1000 labeled descriptors
(<ID 8B, 128×8B vector>), read-only / write-only / read-followed-by-write,
strong vs eventual consistency, dedicated vs volunteer vs cloud Cargos.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.beacon import ArmadaSystem
from repro.core.cluster import real_world
from repro.core.storage.cargo import Cargo

N_OPS = 200


def _system(cargo_nodes):
    topo = real_world()
    sys_ = ArmadaSystem(topo, seed=8, compute_nodes=["V3", "V4", "V5"],
                        cargo_nodes=cargo_nodes)
    return sys_


def _provision(sys_, service="facerec", n_records=1000):
    group = list(sys_.cargos.values())
    initial = {f"face{i}": b"x" * (8 + 128 * 8) for i in range(n_records)}
    for c in group:
        c.provision(service, group, initial)
    return group


def _measure(sys_, cargo: Cargo, requester: str, workload: str,
             consistency: str, n=N_OPS) -> float:
    out: List[float] = []

    def read_done(val, ms):
        out.append(ms)

    def write_done(ms):
        out.append(ms)

    t = sys_.sim.now
    for i in range(n):
        if workload == "read":
            sys_.sim.at(t, cargo.read, "facerec", f"face{i % 1000}",
                        requester, read_done)
        elif workload == "write":
            sys_.sim.at(t, cargo.write, "facerec", f"new{i}", b"y" * 1032,
                        requester, consistency, write_done)
        else:  # read-modify-write
            def _rmw(i=i, t=t):
                def after_read(val, ms1):
                    cargo.write("facerec", f"rmw{i}", b"z" * 1032,
                                requester, consistency,
                                lambda ms2: out.append(ms1 + ms2))
                cargo.read("facerec", f"face{i % 1000}", requester,
                           after_read)
            sys_.sim.at(t, _rmw)
        t += 40.0
    sys_.sim.run(until=t + 5_000.0)
    return sum(out) / len(out) if out else float("nan")


def run():
    rows = []

    # ---- Table 7: cargo selection matrix (tasks on V3/V4/V5)
    sys_ = _system(["V1", "V2", "D6", "Cloud"])
    _provision(sys_)
    paper = {"V3": "V1", "V4": "V2", "V5": "D6"}
    for task_node in ("V3", "V4", "V5"):
        lat = {}
        for cname, cargo in sys_.cargos.items():
            lat[cname] = _measure(sys_, cargo, task_node, "read", "eventual",
                                  n=50)
        best = min(lat, key=lat.get)
        rows.append((f"table7/task_{task_node}", lat[best],
                     f"selected={best};paper={paper[task_node]};"
                     f"all=" + ",".join(f"{k}:{v:.0f}" for k, v in
                                        sorted(lat.items()))))

    # ---- Fig 11: storage failover (task on V5, D6 cargo dies)
    sys_ = _system(["V1", "V2", "D6", "Cloud"])
    _provision(sys_)
    pre = _measure(sys_, sys_.cargos["D6"], "V5", "read", "eventual", n=50)
    sys_.cargos["D6"].fail()
    # immediate switch to next-best cargo (V2 per Table 7 neighborhood)
    alive = {k: _measure(sys_, c, "V5", "read", "eventual", n=20)
             for k, c in sys_.cargos.items() if c.alive and k != "Cloud"}
    nxt = min(alive, key=alive.get)
    cloud = _measure(sys_, sys_.cargos["Cloud"], "V5", "read", "eventual",
                     n=50)
    rows.append(("fig11/before_fail", pre, "cargo=D6"))
    rows.append(("fig11/after_fail", alive[nxt],
                 f"switched_to={nxt};paper=V2"))
    rows.append(("fig11/cloud_backup", cloud, "baseline"))

    # ---- Fig 12/13: consistency x workload x cargo class.  Volunteer
    # replicas propagate over residential links (the paper's Fig 12b point:
    # strong-consistency volunteer writes can exceed cloud latency).
    classes = {"dedicated": ["D6"], "volunteer": ["V1", "V2", "V5"],
               "cloud": ["Cloud"]}
    for cls, cargo_nodes in classes.items():
        for consistency in ("strong", "eventual"):
            sys_ = _system(sorted(set(cargo_nodes)))
            _provision(sys_)
            target = sys_.cargos[cargo_nodes[0]]
            for wl in ("read", "write", "rmw"):
                ms = _measure(sys_, target, "V3", wl, consistency)
                fig = "fig12" if consistency == "strong" else "fig13"
                rows.append((f"{fig}/{wl}/{cls}", ms,
                             f"consistency={consistency}"))
    return rows
